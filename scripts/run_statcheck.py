#!/usr/bin/env python3
"""Static-contract gate over the serve stack (CI: static-contracts job).

Runs the three ``repro.statcheck`` layers and exits nonzero on any
finding, printing each as ``[rule] program: message [offending eqn]``:

1. AST host-path lint (stdlib-only — runs even without jax installed;
   the CI lint job calls ``--lint-only``).
2. Trace-time jaxpr contracts per cache family: the ISSUE-5 pool-relayout
   tripwire on the decode step, no host callbacks inside jit, the Eq. 3
   fold on the precision-free factored-bias path, the pow2 recompile-key
   bound — plus a built-in NEGATIVE test proving ``cache_layout="legacy"``
   still trips the transpose rule (skip with ``--skip-negative``).
3. Mesh/HLO checks (``--mesh``, needs >= 4 devices — forces 4 host
   devices when real ones are absent): real collectives in the sharded
   decode HLO, state axes in the Rules vocabulary, no silent pool
   degradation.

Examples::

    PYTHONPATH=src python scripts/run_statcheck.py
    PYTHONPATH=src python scripts/run_statcheck.py --families dense,ring
    PYTHONPATH=src python scripts/run_statcheck.py --layout legacy  # fails
    python scripts/run_statcheck.py --lint-only     # no jax needed
    PYTHONPATH=src python scripts/run_statcheck.py --mesh

The default ``--impl pallas_interpret`` is load-bearing: the legacy
layout's Θ(pool) transpose lives in the Pallas layout adapters, so
interpret mode is what lets CPU CI see the exact jaxpr the TPU path
would run (see statcheck/README.md).
"""
from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_FAMILIES = "dense,moe,ring,ssm,pairformer"


def run_lint() -> list:
    from repro.statcheck.hostlint import lint_tree
    return lint_tree(REPO)


def run_contract_checks(families, layout, impl, self_test) -> list:
    from repro.statcheck.contracts import run_contracts
    return run_contracts(families, cache_layout=layout, impl=impl,
                         self_test=self_test)


def run_mesh_checks(impl: str) -> list:
    """The serve_sharded collective assert as a statcheck rule: a (2, 2)
    mesh-sharded dense backend must compile real collectives."""
    import jax
    assert len(jax.devices()) >= 4, \
        f"mesh checks need >= 4 devices, got {len(jax.devices())}"
    from repro.configs import smoke_config
    from repro.dist.sharding import Rules
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve.backend import TokenDecodeBackend
    from repro.statcheck.mesh_rules import check_backend_mesh

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = smoke_config("stablelm_12b").replace(attn_impl=impl)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    be = TokenDecodeBackend(model, params, max_len=32, n_slots=4,
                            page_size=4, mesh=mesh, rules=Rules())
    return check_backend_mesh(be, program="dense/decode@(2,2)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--families", default=DEFAULT_FAMILIES,
                    help=f"comma-separated (default {DEFAULT_FAMILIES})")
    ap.add_argument("--layout", default="kernel",
                    choices=("kernel", "legacy"),
                    help="cache layout to check (legacy exists to watch "
                    "the tripwire fire)")
    ap.add_argument("--impl", default="pallas_interpret",
                    help="attn_impl for the traced programs (default "
                    "pallas_interpret: the layout adapters the tripwire "
                    "watches live on the Pallas path)")
    ap.add_argument("--lint-only", action="store_true",
                    help="AST host-path lint only (no jax import)")
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--skip-negative", action="store_true",
                    help="skip the built-in legacy-tripwire self test")
    ap.add_argument("--mesh", action="store_true",
                    help="also compile the sharded decode on a (2,2) "
                    "mesh and check collectives (needs >= 4 devices)")
    args = ap.parse_args(argv)

    findings = []
    if not args.no_lint:
        findings += run_lint()
    if not args.lint_only:
        families = [f for f in args.families.split(",") if f]
        findings += run_contract_checks(families, args.layout, args.impl,
                                        self_test=not args.skip_negative)
        if args.mesh:
            findings += run_mesh_checks(args.impl)

    for f in findings:
        print(f, file=sys.stderr)
    if findings:
        print(f"statcheck: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    scope = "lint" if args.lint_only else \
        f"lint+contracts[{args.families};layout={args.layout}]" \
        if not args.no_lint else f"contracts[{args.families}]"
    print(f"statcheck passed ({scope})")
    return 0


if __name__ == "__main__":
    # forcing host devices must happen before jax initializes; only when
    # the mesh checks actually need them (mirrors examples/serve_sharded)
    if "--mesh" in sys.argv and "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4")
    sys.exit(main())
