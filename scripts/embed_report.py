"""Embed the generated roofline report into EXPERIMENTS.md placeholders.

    PYTHONPATH=src python scripts/embed_report.py
"""
import re
import subprocess
import sys

rep = subprocess.run(
    [sys.executable, "-m", "repro.analysis.report", "results/dryrun"],
    capture_output=True, text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/nix/store"},
).stdout

try:
    base = subprocess.run(
        [sys.executable, "-m", "repro.analysis.report", "results/dryrun_baseline"],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/nix/store"},
    ).stdout
except Exception:
    base = ""

sections = re.split(r"^## ", rep, flags=re.M)
tables = {}
for sec in sections:
    if sec.startswith("Roofline"):
        tables["roofline"] = "## " + sec.strip()
    elif sec.startswith("Multi-pod"):
        tables["multipod"] = "## " + sec.strip()
    elif sec.startswith("Collective"):
        tables["coll"] = "## " + sec.strip()

base_roof = ""
for sec in re.split(r"^## ", base, flags=re.M):
    if sec.startswith("Roofline"):
        base_roof = sec.strip().split("\n", 1)[1]

with open("EXPERIMENTS.md") as f:
    doc = f.read()

dry = (tables.get("multipod", "") + "\n\n" + tables.get("coll", ""))
roof = (tables.get("roofline", "")
        + "\n\n### Paper-faithful baseline (pre-§Perf fixes, archived in "
        "results/dryrun_baseline/)\n\n" + base_roof)

doc = doc.replace("<!-- DRYRUN_TABLES -->", dry)
doc = doc.replace("<!-- ROOFLINE_TABLE -->", roof)
with open("EXPERIMENTS.md", "w") as f:
    f.write(doc)
print("embedded", {k: len(v) for k, v in tables.items()})
