#!/usr/bin/env python3
"""CI benchmark-regression gate over the BENCH_*.json smoke artifacts.

CI has always *uploaded* BENCH_kernels.json / BENCH_serve.json but never
checked them, so a perf regression in the paper's headline A/B (dense-bias
vs FlashBias factored-bias attention) or in serve decode throughput would
merge silently. This script fails the job when a gated metric drops more
than ``--tolerance`` (default 30%) below its committed baseline:

1. kernels: ``dense_vs_factored.speedup`` from BENCH_kernels.json — a
   dimensionless ratio of two jitted paths timed on the same machine, so
   it transfers across runner hardware far better than absolute timings
   (still within ~±20%: commit the low end of observed values).
2. serve: contiguous decode tokens/s at the highest measured occupancy
   from BENCH_serve.json ``points``. This is an absolute number: after a
   runner-hardware change, refresh the committed value (see below).
3. serve: ``lazy_vs_whole.ratio`` — lazy page growth must sustain
   whole-request-reservation decode throughput at occupancy 4. The two
   engines are timed interleaved (same load profile), so this ratio is
   noise-robust and needs no baseline.
4. serve: ``layout_vs_legacy.ratio`` — the kernel-native cache layout
   (ISSUE 5) must be at least as fast as the legacy canonical layout it
   replaced (>= 1.0 within tolerance). Interleaved like the lazy A/B, so
   no baseline is needed.
5. serve: ``chunked_prefill.ratio`` — p99 decode-step latency under a
   concurrent long-prompt arrival, whole-prompt admission over chunked
   (ISSUE 7). Gated at a FIXED structural floor of 1.2 (not
   tolerance-scaled): chunked admission amortizing the arrival sits > 2,
   a degeneration into a monolithic prefill stall sits ~1.0.
6. serve: ``prefix_sharing.ratio`` — shared-prefix admission throughput,
   prefix cache on over off, at the 64-requests x 512-token-prefix point
   (ISSUE 9). Gated at a FIXED structural floor of 2.0: page sharing
   deletes ~8/9 of the prefill compute there (> 3 observed), while an
   admission path that silently stops matching sits ~1.0.
7. serve: ``guard_overhead.ratio`` — decode throughput with the ISSUE 10
   non-finite emission guards on over off. Gated at a FIXED floor of
   0.95: default-on fault containment may cost at most 5% of decode
   throughput. Interleaved, so no baseline is needed.
8. neural (``--neural``, opt-in): the Table 6 Pairformer inference A/B
   from BENCH_neural.json — dense-path time / FlashBias-neural-path time,
   a same-machine ratio gated against a committed conservative baseline
   (the neural path ran ungated since the bench landed, so a factor-MLP
   regression would have merged silently).
9. pairformer (``--pairformer``, opt-in): the ISSUE 6 batched-serve A/B
   from BENCH_pairformer.json. Two gates: the headline
   ``factored_vs_dense.ratio`` (factored factor-cache step vs the official
   recompute-from-z dataflow, interleaved, >= 1.0 within tolerance — the
   paper's Sec. 4.4 claim) and ``cached_ratio`` (factored vs the cached
   dense-bias variant) against a committed baseline as a factored-path
   regression tripwire.

Every loaded BENCH file is schema-validated first (``SCHEMAS``): each gate
reads a fixed key path, and a bench that silently stops emitting one — a
renamed field, an empty sweep — fails the run immediately instead of
passing vacuously. The opt-in gates only run when their flag is passed
(CI passes them explicitly); default invocations keep the core kernels +
serve gates.
``--serve-only`` drops the kernels gate entirely — the mesh-serve CI job
runs the serve bench without a kernels sweep artifact.

Note on the kernels headline: ``dense_vs_factored`` is the LARGEST point
of the seq-length sweep (``dense_vs_factored_sweep``) — the paper-scale
regime where bias IO dominates. Gating a small-N point would gate the
regime where the factored path legitimately loses.

Baselines live in ``benchmarks/baselines/*.baseline.json``. Refresh them
from the current BENCH files with::

    python scripts/check_bench.py --update-baseline

Run the smoke benchmarks first, on a quiet machine (or the CI runner class
the gate will run on), and eyeball the diff before committing: a baseline
captured during a load burst weakens the gate; one captured on faster
hardware than CI will flake it. The committed serve baseline is
deliberately conservative (low end of observed) — the gate exists to catch
integer-factor regressions (e.g. a factored path silently materializing
the dense bias), not 10% drift on shared runners.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE_DIR = os.path.join("benchmarks", "baselines")
KERNELS_BASELINE = "BENCH_kernels.baseline.json"
SERVE_BASELINE = "BENCH_serve.baseline.json"
NEURAL_BASELINE = "BENCH_neural.baseline.json"
PAIRFORMER_BASELINE = "BENCH_pairformer.baseline.json"


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# Required key paths per BENCH suite, validated up front: "a.b" descends
# dicts, "a[].b" requires the key on every element of a non-empty list,
# "rows[name=X].k" requires a row dict named X carrying k. The gates below
# read exactly these paths — a bench refactor that silently drops one
# (renames "ratio", stops emitting sweep points) must fail the gate
# loudly at load time, not pass vacuously or die in a KeyError mid-check.
SCHEMAS: dict[str, tuple[str, ...]] = {
    "kernels": (
        "dense_vs_factored.speedup",
        "dense_vs_factored_sweep",
    ),
    "serve": (
        "points[].occupancy",
        "points[].decode_tokens_per_s",
        "lazy_vs_whole.ratio",
        "layout_vs_legacy.ratio",
        "chunked_prefill.ratio",
        "prefix_sharing.ratio",
        "prefix_sharing.hit_rate",
        "guard_overhead.ratio",
    ),
    "neural": (
        "rows[name=table6_infer_dense_pairbias].us_per_call",
        "rows[name=table6_infer_flashbias_neural].us_per_call",
    ),
    "pairformer": (
        "factored_vs_dense.n_res",
        "factored_vs_dense.ratio",
        "factored_vs_dense.cached_ratio",
    ),
}


def _step_into(nodes: list, step: str) -> list | None:
    """Resolve one path step against every current node; None = missing."""
    out: list = []
    for node in nodes:
        if step.endswith("[]"):
            items = node.get(step[:-2]) if isinstance(node, dict) else None
            if not isinstance(items, list) or not items:
                return None
            out.extend(items)
        elif "[name=" in step:
            key, _, sel = step.partition("[name=")
            sel = sel.rstrip("]")
            items = node.get(key) if isinstance(node, dict) else None
            rows = [
                r
                for r in (items if isinstance(items, list) else [])
                if isinstance(r, dict) and r.get("name") == sel
            ]
            if not rows:
                return None
            out.extend(rows)
        else:
            if not isinstance(node, dict) or step not in node:
                return None
            out.append(node[step])
    return out


def schema_errors(suite: str, bench: dict) -> list[str]:
    """Which required key paths of ``suite`` are missing from ``bench``."""
    errors = []
    for path in SCHEMAS[suite]:
        nodes: list | None = [bench]
        for step in path.split("."):
            nodes = _step_into(nodes, step)
            if nodes is None:
                errors.append(f"{suite}: missing required key path '{path}'")
                break
    return errors


def kernels_speedup(bench: dict) -> float:
    """Factored-vs-dense speedup of the same attention workload."""
    return float(bench["dense_vs_factored"]["speedup"])


def serve_decode_point(bench: dict) -> tuple[int, float]:
    """(occupancy, decode tokens/s) of the highest-occupancy point."""
    point = max(bench["points"], key=lambda p: p["occupancy"])
    return int(point["occupancy"]), float(point["decode_tokens_per_s"])


def lazy_vs_whole_ratio(bench: dict) -> float:
    """Interleaved lazy/whole decode throughput ratio (ISSUE 4)."""
    return float(bench["lazy_vs_whole"]["ratio"])


def layout_vs_legacy_ratio(bench: dict) -> float:
    """Interleaved kernel-layout/legacy decode throughput ratio (ISSUE 5)."""
    return float(bench["layout_vs_legacy"]["ratio"])


def chunked_prefill_ratio(bench: dict) -> float:
    """Interleaved whole/chunked p99 decode-step latency ratio (ISSUE 7):
    tail latency under concurrent long-prompt admission. >> 1 when
    chunked prefill amortizes the arrival, ~1.0 when it degenerates into
    a monolithic prefill stall."""
    return float(bench["chunked_prefill"]["ratio"])


def prefix_sharing_ratio(bench: dict) -> float:
    """Interleaved cached/uncached shared-prefix admission throughput
    ratio (ISSUE 9): >= 2 when prefix hits skip the shared pages'
    prefill chunks, ~1.0 when admission stops matching."""
    return float(bench["prefix_sharing"]["ratio"])


def guard_overhead_ratio(bench: dict) -> float:
    """Interleaved guarded/unguarded decode throughput ratio (ISSUE 10):
    ~1.0 when the non-finite emission guard stays amortized behind the
    commit sync, below the floor when guarding starts costing real
    decode throughput."""
    return float(bench["guard_overhead"]["ratio"])


def neural_speedup(bench: dict) -> float:
    """Dense-path / FlashBias-neural-path time of the Table 6 inference
    A/B (same machine, same call) from the BENCH_neural row dump."""
    rows = {r["name"]: r for r in bench["rows"]}
    dense = float(rows["table6_infer_dense_pairbias"]["us_per_call"])
    flash = float(rows["table6_infer_flashbias_neural"]["us_per_call"])
    return dense / flash


def pairformer_headline(bench: dict) -> dict:
    """Largest-n_res factored-vs-dense point of the batched-serve sweep
    (ISSUE 6): ``ratio`` vs the official recompute dataflow, gated at
    1.0; ``cached_ratio`` vs the cached dense bias, gated on baseline."""
    return bench["factored_vs_dense"]


def check(
    name: str,
    current: float,
    floor: float,
    detail: str,
    failures: list,
) -> None:
    status = "ok" if current >= floor else "FAIL"
    print(f"[{status}] {name}: {current:.3f} (floor {floor:.3f}; {detail})")
    if current < floor:
        failures.append(name)


def update_baselines(
    kernels: dict,
    serve: dict,
    baseline_dir: str,
    neural: dict | None = None,
    pairformer: dict | None = None,
) -> None:
    os.makedirs(baseline_dir, exist_ok=True)
    occ, tps = serve_decode_point(serve)
    payloads = {
        KERNELS_BASELINE: {"speedup": kernels_speedup(kernels)},
        SERVE_BASELINE: {"occupancy": occ, "decode_tokens_per_s": tps},
    }
    if neural is not None:
        payloads[NEURAL_BASELINE] = {"speedup": neural_speedup(neural)}
    if pairformer is not None:
        payloads[PAIRFORMER_BASELINE] = {
            "cached_ratio": float(pairformer_headline(pairformer)["cached_ratio"])
        }
    for fname, payload in payloads.items():
        path = os.path.join(baseline_dir, fname)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {path}: {payload}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", default="BENCH_kernels.json")
    ap.add_argument("--serve", default="BENCH_serve.json")
    ap.add_argument(
        "--neural",
        default=None,
        help="BENCH_neural.json path; enables the Table 6 speedup gate",
    )
    ap.add_argument(
        "--pairformer",
        default=None,
        help="BENCH_pairformer.json path; enables the batched-serve gates",
    )
    ap.add_argument(
        "--serve-only",
        action="store_true",
        help="gate only the BENCH_serve.json metrics (the mesh-serve CI "
        "job runs the serve bench without the kernels sweep)",
    )
    ap.add_argument("--baseline-dir", default=DEFAULT_BASELINE_DIR)
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.30,
        help="allowed fractional drop below baseline (default 0.30)",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the committed baselines from the current BENCH files",
    )
    args = ap.parse_args(argv)

    kernels = None if args.serve_only else _load(args.kernels)
    serve = _load(args.serve)
    neural = _load(args.neural) if args.neural else None
    pairformer = _load(args.pairformer) if args.pairformer else None

    suites = (
        ("kernels", kernels),
        ("serve", serve),
        ("neural", neural),
        ("pairformer", pairformer),
    )
    schema_failures = [
        err
        for suite, bench in suites
        if bench is not None
        for err in schema_errors(suite, bench)
    ]
    if schema_failures:
        for err in schema_failures:
            print(f"[FAIL] schema: {err}", file=sys.stderr)
        print(
            "BENCH schema validation FAILED: a bench stopped emitting a "
            "gated key — fix the bench (or the schema, if the rename is "
            "intentional) before trusting any gate below",
            file=sys.stderr,
        )
        return 1

    if args.update_baseline:
        assert kernels is not None, "--update-baseline needs the kernels file"
        update_baselines(
            kernels, serve, args.baseline_dir, neural=neural, pairformer=pairformer
        )
        return 0

    sb = _load(os.path.join(args.baseline_dir, SERVE_BASELINE))
    band = 1.0 - args.tolerance
    failures: list = []

    if kernels is not None:
        kb = _load(os.path.join(args.baseline_dir, KERNELS_BASELINE))
        check(
            "kernels dense-vs-factored speedup",
            kernels_speedup(kernels),
            band * float(kb["speedup"]),
            f"baseline {float(kb['speedup']):.3f}, tol {args.tolerance:.0%}",
            failures,
        )
    occ, tps = serve_decode_point(serve)
    if occ != int(sb["occupancy"]):
        print(
            f"[FAIL] serve occupancy mismatch: bench measured occupancy "
            f"{occ}, baseline holds occupancy {sb['occupancy']} — not "
            "comparable; re-run --update-baseline after changing the "
            "bench occupancies",
            file=sys.stderr,
        )
        failures.append("serve occupancy mismatch")
    check(
        f"serve decode tok/s @ occupancy {occ}",
        tps,
        band * float(sb["decode_tokens_per_s"]),
        f"baseline {float(sb['decode_tokens_per_s']):.1f} @ occupancy "
        f"{sb['occupancy']}, tol {args.tolerance:.0%}",
        failures,
    )
    check(
        "serve lazy-vs-whole decode ratio",
        lazy_vs_whole_ratio(serve),
        band,
        f"interleaved A/B, no baseline, tol {args.tolerance:.0%}",
        failures,
    )
    check(
        "serve kernel-layout-vs-legacy decode ratio",
        layout_vs_legacy_ratio(serve),
        band,
        f"interleaved A/B, no baseline, tol {args.tolerance:.0%}",
        failures,
    )
    # fixed structural floor, NOT tolerance-scaled: the ratio sits > 2
    # when chunked admission amortizes the long-prompt stall and ~1.0
    # when it degenerates into a monolithic prefill — the gate separates
    # those regimes, it does not band a drifting measurement
    check(
        "serve chunked-prefill p99 stall ratio",
        chunked_prefill_ratio(serve),
        1.2,
        "interleaved A/B, structural floor 1.2",
        failures,
    )
    # fixed structural floor like chunked_prefill: sharing working sits
    # > 3 at the 64 x 512 point, admission silently not matching ~1.0
    check(
        "serve shared-prefix admission ratio",
        prefix_sharing_ratio(serve),
        2.0,
        "interleaved A/B, structural floor 2.0",
        failures,
    )
    # fixed floor: the guards must cost <= 5% decode throughput (the
    # price of default-on fault containment), independent of the runner
    # tolerance band — a noisy runner cancels out of the interleaved A/B
    check(
        "serve guarded-vs-unguarded decode ratio",
        guard_overhead_ratio(serve),
        0.95,
        "interleaved A/B, fixed floor 0.95 (guards cost <= 5%)",
        failures,
    )
    if neural is not None:
        nb = _load(os.path.join(args.baseline_dir, NEURAL_BASELINE))
        check(
            "neural dense-vs-flashbias inference speedup",
            neural_speedup(neural),
            band * float(nb["speedup"]),
            f"baseline {float(nb['speedup']):.3f}, tol {args.tolerance:.0%}",
            failures,
        )
    if pairformer is not None:
        head = pairformer_headline(pairformer)
        check(
            f"pairformer factored-vs-dense serve-step ratio "
            f"@ n_res {head['n_res']}",
            float(head["ratio"]),
            band,
            f"interleaved A/B vs official recompute path, no baseline, "
            f"tol {args.tolerance:.0%}",
            failures,
        )
        pb = _load(os.path.join(args.baseline_dir, PAIRFORMER_BASELINE))
        check(
            f"pairformer factored-vs-cached-bias ratio @ n_res "
            f"{head['n_res']}",
            float(head["cached_ratio"]),
            band * float(pb["cached_ratio"]),
            f"baseline {float(pb['cached_ratio']):.3f}, "
            f"tol {args.tolerance:.0%}",
            failures,
        )

    if failures:
        print(f"benchmark regression gate FAILED: {failures}", file=sys.stderr)
        print(
            "If this is expected (new runner hardware, intentional trade), "
            "refresh with: python scripts/check_bench.py --update-baseline",
            file=sys.stderr,
        )
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
