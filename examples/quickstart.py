"""FlashBias quickstart: the paper's Eq. 3 in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Shows, on one attention call:
1. a dense ALiBi bias and its exact rank-2 factorization (Example 3.4),
2. that factored FlashBias attention == dense-bias attention,
3. the Eq. 3 concat identity (biased attention IS standard attention over
   C+R channels),
4. the IO model's predicted HBM saving (Example 3.9).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as A
from repro.core import bias as B
from repro.core.lowrank import IOModel

B_, N, H, D = 2, 128, 8, 64
key = jax.random.PRNGKey(0)
q, k, v = (jax.random.normal(kk, (B_, N, H, D))
           for kk in jax.random.split(key, 3))

# 1. exact decomposition: b[h,i,j] = slope_h * (j-i) = phi_q @ phi_k^T, R=2
phi_q, phi_k = B.alibi_factors(N, N, H)
dense = B.alibi_dense(N, N, H)
recon = jnp.einsum("hnr,mr->hnm", phi_q, phi_k)
print(f"1. ALiBi factorization error: {jnp.abs(recon - dense).max():.2e} "
      f"(rank {phi_q.shape[-1]})")

# 2. FlashBias attention == dense-bias attention
pq4 = B.broadcast_factors(phi_q, B_, N, H)
pk4 = B.broadcast_factors(phi_k, B_, N, H)
o_dense = A.attention(q, k, v, bias=dense[None], mask=A.MaskSpec("causal"),
                      impl="dense")
o_flash = A.attention(q, k, v, phi_q=pq4, phi_k=pk4,
                      mask=A.MaskSpec("causal"), impl="chunked",
                      chunk_size=32)
print(f"2. FlashBias vs dense-bias output error: "
      f"{jnp.abs(o_dense - o_flash).max():.2e}")

# 3. Eq. 3: concat factors onto q/k -> standard attention
pk1 = B.broadcast_factors(phi_k, B_, N, 1)
q_aug, k_aug = A.flashbias_concat_qk(q, k, pq4, pk1)
o_concat = A.attention(q_aug, k_aug, v, mask=A.MaskSpec("causal"),
                       impl="dense", scale=1.0 / np.sqrt(D))
print(f"3. Eq.3 concat identity error: "
      f"{jnp.abs(o_concat - o_dense).max():.2e} "
      f"(channels {D} -> {q_aug.shape[-1]})")

# 4. the paper's IO model: why this is fast
io = IOModel(n=65536, m=65536, c=64, rank=64, sram=100 * 1024 // 2)
print(f"4. Example 3.9 HBM-access ratio (dense-bias / FlashBias): "
      f"{io.speedup_over_dense_bias():.1f}x")
print("   bias storage: dense", 65536 * 65536 * 2, "B -> factored",
      2 * 65536 * 64 * 2, "B (Thm 3.2)")
