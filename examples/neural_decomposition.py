"""Paper Table 1(c) + App. G: NEURAL decomposition of biases that have no
closed-form factorization — gravity 1/d^2 and spherical (haversine) distance.

Token-wise factor MLPs (3 linear layers + tanh, App. H Table 12) are trained
with Eq. 5 to approximate f(x_q, x_k) ~= phi_q(x_q) phi_k(x_k)^T.

    PYTHONPATH=src python examples/neural_decomposition.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import decomp

key = jax.random.PRNGKey(0)


def gravity(xq, xk):
    d2 = jnp.sum((xq[:, None] - xk[None]) ** 2, -1)
    return 1.0 / (d2 + 0.01)          # paper adds 0.01 for stability


def spherical(xq, xk):
    lat1, lon1 = xq[:, None, 0], xq[:, None, 1]
    lat2, lon2 = xk[None, :, 0], xk[None, :, 1]
    h = (jnp.sin((lat1 - lat2) / 2) ** 2
         + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin((lon1 - lon2) / 2) ** 2)
    return 2 * jnp.arcsin(jnp.sqrt(jnp.clip(h, 0.0, 1.0)))


for name, fn, lo, hi in (("gravity", gravity, 0.0, 1.0),
                         ("spherical", spherical, -1.5, 1.5)):
    params = decomp.neural_decomp_init(key, 2, 2, hidden=64, heads=1, rank=32)

    def sample(k, fn=fn, lo=lo, hi=hi):
        xq = jax.random.uniform(k, (64, 2), minval=lo, maxval=hi)
        return xq, xq, fn(xq, xq)[None]

    fitted, losses = decomp.fit_neural_decomposition(
        key, params, sample, steps=400, lr=3e-3)
    xq, xk, target = sample(jax.random.PRNGKey(99))
    pred = decomp.predicted_bias(fitted, xq, xk)[0]
    rel = float(jnp.linalg.norm(pred - target[0])
                / jnp.linalg.norm(target[0]))
    print(f"{name:10s} bias: Eq.5 loss {float(losses[0]):.4f} -> "
          f"{float(losses[-1]):.4f}; held-out rel err {rel:.3f} (R=32)")
print("(the fitted factors then ride with q/k exactly like the exact "
      "decompositions — see examples/quickstart.py)")
