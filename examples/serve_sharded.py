"""Mesh-sharded serve stack + chunked prefill (ISSUE 7) on a (2, 2) mesh.

Runs the continuous-batching engine with a ``(data, model)`` device mesh:
the backend traces its jitted admit/chunk/decode programs under
``use_mesh_rules`` (TP-sharded heads, DP-sharded slot rows) and places KV
page pools along ``kv_heads`` — while the page allocator and page tables
stay host-side. Admission is chunked: prompts land a few tokens per engine
step, interleaved with decode, so late long arrivals never stall the
in-flight batch.

The example then PROVES the sharding is real, not cosmetic: it lowers the
decode step against the live sharded state and asserts the compiled HLO
contains cross-device collectives, and that outputs are bit-identical to
a single-device engine.

Runs anywhere — 4 real devices, or 4 forced host devices:

    JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_sharded.py
"""
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.dist.sharding import Rules
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import SamplingParams, ServeEngine

assert len(jax.devices()) >= 4, \
    f"need >= 4 devices for a (2, 2) mesh, got {len(jax.devices())}"
mesh = jax.make_mesh((2, 2), ("data", "model"))

cfg = smoke_config("stablelm_12b")
model = get_model(cfg)
params = init_params(model.template(), jax.random.PRNGKey(0))

PROMPTS = [13, 7, 18, 5, 26, 9]


def run(mesh=None):
    engine = ServeEngine(model, params, max_len=64, n_slots=4,
                         prefill_chunk=4, page_size=4, pages_per_slot=16,
                         mesh=mesh, rules=Rules() if mesh else None)
    rng = np.random.default_rng(0)
    rids = [engine.submit(
        rng.integers(0, cfg.vocab, (n,)).astype(np.int32), 8,
        sampling=SamplingParams(0.0, 0, seed=i))
        for i, n in enumerate(PROMPTS)]
    engine.run()
    return engine, [engine.result(r) for r in rids]


engine, outs = run(mesh)
print(f"[sharded] {cfg.name} on mesh {dict(mesh.shape)}: "
      f"{len(PROMPTS)} requests, chunked prefill (chunk=4)")
print("[sharded] first request:", outs[0].tolist())

# real collectives: lower the decode step against the LIVE sharded state
be = engine.backend
lowered = jax.jit(be._with_mesh(model.decode),
                  static_argnames=("max_pages",)).lower(
    params, be._cache, be._last_tok, max_pages=be.page_cap({}))
txt = lowered.compile().as_text()
colls = sorted(op for op in ("all-reduce", "all-gather", "reduce-scatter")
               if op in txt)
assert colls, "sharded decode compiled without any cross-device collective"
print("[sharded] decode collectives:", ", ".join(colls))

_, outs1 = run(mesh=None)
assert all(a.size == b.size and (a == b).all()
           for a, b in zip(outs, outs1)), "mesh run diverged"
print("[sharded] outputs bit-identical to the single-device engine")
