"""End-to-end driver: train a GPT-2-family LM with FlashBias-ALiBi.

    PYTHONPATH=src python examples/train_lm_alibi.py            # ~10M demo
    PYTHONPATH=src python examples/train_lm_alibi.py --full     # ~100M model

The paper's Sec. 4.2 setting: decoder-only, causal mask + ALiBi, the bias
consumed through the exact rank-2 decomposition (identical losses to dense
ALiBi — verified at step 0). Fault-tolerant loop: checkpoints land in
--ckpt-dir and a rerun resumes from the last one.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.gpt2_alibi_15b import CONFIG
from repro.data import LMBatches
from repro.models import get_model
from repro.models.common import count_params, init_params
from repro.optim import AdamW, cosine
from repro.train import TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/flashbias_lm_ckpt")
    args = ap.parse_args()

    if args.full:   # ~100M: 12 layers x 768, vocab 50257
        cfg = CONFIG.replace(n_layers=12, d_model=768, n_heads=12,
                             n_kv_heads=12, d_ff=3072, head_dim=64,
                             tp=1, remat="none", dtype="float32",
                             grad_accum=1)
    else:           # ~10M demo
        cfg = CONFIG.replace(n_layers=6, d_model=256, n_heads=8,
                             n_kv_heads=8, d_ff=1024, head_dim=32,
                             vocab=8192, tp=1, remat="none",
                             dtype="float32", grad_accum=1)

    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    n_params = count_params(model.template())
    print(f"model: {cfg.name} derivative, {n_params / 1e6:.1f}M params, "
          f"FlashBias-ALiBi (exact R=2)")

    # sanity: FlashBias loss == dense-ALiBi loss at init (exact decomposition)
    data = LMBatches(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=0)
    b0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    l_fb = model.loss(params, b0)
    l_dense = get_model(cfg.replace(bias_mode="dense")).loss(params, b0)
    print(f"exactness check: flashbias loss {float(l_fb):.5f} == "
          f"dense-bias loss {float(l_dense):.5f} "
          f"(delta {abs(float(l_fb) - float(l_dense)):.2e})")

    opt = AdamW(lr_fn=cosine(3e-3, args.steps // 10, args.steps))
    step = make_train_step(model.loss, opt)
    loop = TrainLoop(step, lambda s: {k: jnp.asarray(v)
                                      for k, v in data.batch(s).items()},
                     ckpt_dir=args.ckpt_dir, ckpt_every=50,
                     log_path=os.path.join(args.ckpt_dir, "log.jsonl"))
    os.makedirs(args.ckpt_dir, exist_ok=True)
    params, opt_state, info = loop.run(params, opt.init(params), args.steps)
    print("run info:", info)

    import json
    with open(os.path.join(args.ckpt_dir, "log.jsonl")) as f:
        losses = [json.loads(line)["loss"] for line in f]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"loss: first-{k} avg {sum(losses[:k]) / k:.4f} -> "
              f"last-{k} avg {sum(losses[-k:]) / k:.4f}")


if __name__ == "__main__":
    main()
