"""Batched Pairformer serving through the backend-abstracted engine
(FlashBias Sec. 4.4): a request is ONE COMPLEX — a float (n_res, d) residue
feature array — and its budget counts refinement iterations, not tokens.

Admission runs the trunk once per complex (triangle updates + pair
transitions), factorises each layer's pair-projected attention bias
(truncated SVD at the configured rank, Sec. 4.3; pass ``factors=`` for the
Eq. 5 factor MLPs), and caches the rank-R factors per slot — the
Pairformer analogue of a KV cache. Every engine step then refines the
single representation of EVERY live complex in one jitted call, streaming
Theta(N R) factor bytes per slot instead of the N^2 dense bias, with
per-slot n_res masking over the padded batch. Results are bit-identical to
serving each complex alone (tests/test_pair_serve.py).

    PYTHONPATH=src python examples/serve_pairformer.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import ServeEngine

cfg = smoke_config("pairformer_lite")
model = get_model(cfg)
params = init_params(model.template(), jax.random.PRNGKey(0))
engine = ServeEngine(model, params, max_len=24, n_slots=2)
rng = np.random.default_rng(0)

# 5 variable-size complexes through 2 slots; the urgent one (priority 1)
# overtakes the queue at admission time
sizes = [14, 9, 21, 11, 17]
budgets = [4, 6, 3, 5, 4]
rids = [engine.submit(
    rng.standard_normal((n, cfg.d_model)).astype(np.float32), b,
    priority=1 if i == 3 else 0)
    for i, (n, b) in enumerate(zip(sizes, budgets))]

t0 = time.monotonic()
engine.run()
dt = time.monotonic() - t0

cache = engine.backend._cache
kinds = ", ".join(f"{k}:{tuple(v.shape)}" for k, v in cache.items()
                  if k != "length")
n_steps = sum(budgets)
print(f"{cfg.name}: {len(rids)} complexes / 2 slots, "
      f"{n_steps} refinement steps in {dt:.2f}s")
print(f"factor cache  {kinds}")
for rid, n in zip(rids, sizes):
    s = engine.result(rid)                      # final (n_res, d_model) rep
    print(f"  complex rid={rid} n_res={n:2d} -> single rep {s.shape}, "
          f"|s|_rms={float(np.sqrt((s ** 2).mean())):.4f}")
