"""Continuous batching across the three cache kinds:

- stablelm (GQA, full KV slot segments, flash-decoding path),
- hymba    (sliding-window RING cache + constant SSM state),
- mamba2   (pure constant-size SSM state — no KV growth at all).

Ragged prompts arrive at different times; freed slots are refilled from
the FIFO queue while the other slots keep decoding — one jitted decode
program per model serves the whole arrival pattern.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import ServeEngine

for arch in ("stablelm_12b", "hymba_15b", "mamba2_130m"):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=96, n_slots=2, prefill_len=24)
    rng = np.random.default_rng(0)

    # 5 ragged requests through 2 slots: the queue drains as slots free up
    rids = [engine.submit(
        rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32), 12)
        for n in rng.integers(4, 25, (3,))]
    t0 = time.monotonic()
    engine.step()                                  # admits the first wave
    rids += [engine.submit(
        rng.integers(0, cfg.vocab, (int(n),)).astype(np.int32), 12)
        for n in rng.integers(4, 25, (2,))]        # late arrivals
    engine.run()
    dt = time.monotonic() - t0

    n_tok = sum(engine.result(r).size for r in rids)
    cache = model.init_cache(2, 96)
    kinds = ", ".join(f"{k}:{tuple(v.shape)}" for k, v in cache.items()
                      if k != "length")
    print(f"{cfg.name:18s} {n_tok / dt:7.1f} tok/s "
          f"({len(rids)} reqs / 2 slots) | cache {kinds}")
    print(f"{'':18s} sample: {engine.result(rids[0])[:12]}")
