"""Batched serving across the three cache kinds:

- stablelm (GQA, full KV cache, flash-decoding path),
- hymba    (sliding-window RING cache + constant SSM state),
- mamba2   (pure constant-size SSM state — no KV growth at all).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import ServeEngine

for arch in ("stablelm_12b", "hymba_15b", "mamba2_130m"):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=96)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, (4, 16)).astype(np.int32)
    t0 = time.monotonic()
    out = engine.generate(prompts, 24)
    dt = time.monotonic() - t0
    cache = model.init_cache(4, 96)
    kinds = ", ".join(f"{k}:{tuple(v.shape)}" for k, v in cache.items()
                      if k != "length")
    print(f"{cfg.name:18s} {4 * 24 / dt:7.1f} tok/s | cache {kinds}")
    print(f"{'':18s} sample: {out[0][:12]}")
