"""Paper Sec. 4.4: Transformer PDE solver with learnable spatial-distance
bias, trained end-to-end with FlashBias (the configuration where the dense
path OOMs at 32k points — Table 5).

    PYTHONPATH=src python examples/pde_solver.py [--points 512] [--steps 80]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.pde_solver import SMOKE
from repro.data import PDEBatches
from repro.models import pde as pde_mod
from repro.models.common import init_params
from repro.optim import AdamW, cosine
from repro.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=512)
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    cfg = SMOKE.replace(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128)
    params = init_params(pde_mod.pde_template(cfg), jax.random.PRNGKey(0))
    data = PDEBatches(n_points=args.points, global_batch=2, seed=0)

    # bias-path memory: dense (paper baseline) vs FlashBias factors
    n, h = args.points, cfg.n_heads
    print(f"N={n} points; dense bias would be {h * n * n * 4 / 1e6:.1f} MB "
          f"per layer; FlashBias factors are {2 * n * h * 9 * 4 / 1e3:.1f} KB")

    # exactness vs dense on a small batch
    b0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    out_fb = pde_mod.forward(params, b0["coords"], cfg)
    out_d = pde_mod.forward(params, b0["coords"],
                            cfg.replace(bias_mode="dense"))
    print(f"exact decomposition check: max |fb - dense| = "
          f"{float(jnp.abs(out_fb - out_d).max()):.2e}")

    opt = AdamW(lr_fn=cosine(1e-2, 5, args.steps), weight_decay=0.0)
    step = make_train_step(
        lambda p, b: pde_mod.regression_loss(p, b, cfg), opt)
    st = opt.init(params)
    losses = []
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {losses[-1]:.5f}")
    print(f"loss {losses[0]:.5f} -> {losses[-1]:.5f} "
          f"(trained THROUGH the factored bias — the dense path would "
          f"store an (H,N,N) gradient)")


if __name__ == "__main__":
    main()
