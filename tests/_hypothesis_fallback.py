"""Stand-ins for ``hypothesis`` when it isn't installed.

Property tests decorated with ``@given`` become pytest skips; everything
else in the importing module (parametrized example tests) keeps running,
so the suite degrades instead of erroring at collection. Install the
``dev`` extra (``pip install -e .[dev]``) for the real thing.
"""
import pytest

_SKIP = pytest.mark.skip(reason="hypothesis not installed")


class _AnyStrategy:
    """Absorbs any ``st.<name>(...)`` use at decoration time, including
    chained strategies (``.filter``/``.map``/``@st.composite``)."""

    def __getattr__(self, name):
        return self

    def __call__(self, *args, **kwargs):
        return self


st = _AnyStrategy()


def settings(*args, **kwargs):
    return lambda fn: fn


def given(*args, **kwargs):
    return lambda fn: _SKIP(fn)
