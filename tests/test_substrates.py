"""Substrate tests: data determinism, optimizer math, schedules, checkpoint
atomicity + elastic restore, train-loop crash/restart continuity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import LMBatches, PDEBatches
from repro.optim import AdamW, constant, cosine, wsd


class TestData:
    def test_deterministic_and_stateless(self):
        d = LMBatches(vocab=100, seq_len=16, global_batch=4, seed=7)
        b1, b2 = d.batch(5), d.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = d.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_shards_partition_global_batch(self):
        d = LMBatches(vocab=100, seq_len=8, global_batch=8, seed=0)
        s0 = LMBatches(vocab=100, seq_len=8, global_batch=8, seed=0,
                       shard=(0, 2))
        s1 = LMBatches(vocab=100, seq_len=8, global_batch=8, seed=0,
                       shard=(1, 2))
        assert s0.batch(0)["tokens"].shape[0] == 4
        assert not np.array_equal(s0.batch(0)["tokens"],
                                  s1.batch(0)["tokens"])

    def test_labels_are_next_tokens(self):
        d = LMBatches(vocab=1000, seq_len=32, global_batch=2, seed=1,
                      p_noise=0.0)
        b = d.batch(0)
        # with stride-c sequences, labels continue the pattern
        diff = (b["labels"][:, :-1] == b["tokens"][:, 1:]).mean()
        assert diff == 1.0

    def test_pde_targets_are_functions_of_coords(self):
        d = PDEBatches(n_points=32, global_batch=2, seed=0)
        b1, b2 = d.batch(3), d.batch(3)
        np.testing.assert_array_equal(b1["targets"], b2["targets"])


class TestOptimizer:
    def test_adamw_first_step_is_lr_sized(self):
        """Bias-corrected Adam: |first update| == lr for any gradient."""
        opt = AdamW(lr_fn=constant(1e-2), weight_decay=0.0, clip_norm=1e9)
        p = {"w": jnp.ones((4, 4))}
        g = {"w": jnp.full((4, 4), 0.37)}
        st = opt.init(p)
        p2, _, _ = opt.update(g, st, p)
        np.testing.assert_allclose(p["w"] - p2["w"], 1e-2, rtol=1e-4)

    def test_clip_norm_applied(self):
        opt = AdamW(lr_fn=constant(1.0), clip_norm=1.0, weight_decay=0.0)
        p = {"w": jnp.zeros((3,))}
        g = {"w": jnp.array([3.0, 4.0, 0.0])}     # norm 5 -> scaled to 1
        _, _, m = opt.update(g, opt.init(p), p)
        np.testing.assert_allclose(m["grad_norm"], 5.0, rtol=1e-5)

    def test_error_feedback_is_lossless_in_expectation(self):
        """Compression residual carries: two identical grads accumulate to
        the same mu as uncompressed (up to bf16 rounding of the *pair*)."""
        opt_c = AdamW(lr_fn=constant(0.0), compress_grads=True,
                      weight_decay=0.0)
        p = {"w": jnp.zeros((1000,))}
        g = {"w": jnp.full((1000,), 1e-3)}        # bf16-unfriendly value
        st = opt_c.init(p)
        tot = jnp.zeros((1000,))
        for _ in range(4):
            _, st, _ = opt_c.update(g, st, p)
        # err buffer keeps what compression dropped; mu integrates the rest:
        # sum over steps of compressed == 4*g - residual
        drift = float(jnp.abs(st.err["w"]).max())
        assert drift < 1e-4                       # residual bounded, not lost

    def test_weight_decay_skips_vectors(self):
        opt = AdamW(lr_fn=constant(0.1), weight_decay=0.5, clip_norm=1e9)
        p = {"m": jnp.ones((2, 2)), "v": jnp.ones((2,))}
        g = {"m": jnp.zeros((2, 2)), "v": jnp.zeros((2,))}
        p2, _, _ = opt.update(g, opt.init(p), p)
        assert float(p2["m"][0, 0]) < 1.0         # decayed
        assert float(p2["v"][0]) == 1.0           # not decayed


class TestSchedules:
    def test_cosine_shape(self):
        f = cosine(1.0, warmup=10, total=110)
        assert float(f(0)) == 0.0
        assert abs(float(f(10)) - 1.0) < 1e-6
        assert float(f(110)) < 0.2

    def test_wsd_three_phases(self):
        f = wsd(1.0, warmup=10, stable=80, decay=10)
        assert float(f(5)) == 0.5                  # warmup
        assert float(f(50)) == 1.0                 # stable
        assert float(f(99)) < 1.0                  # decay
        assert float(f(200)) <= 0.011              # floor


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 3, tree, extras={"step": 3})
        out, extras = restore_checkpoint(str(tmp_path), None, tree)
        assert extras["step"] == 3
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_keep_n_prunes(self, tmp_path):
        tree = {"x": jnp.zeros((2,))}
        for s in range(6):
            save_checkpoint(str(tmp_path), s, tree, keep_n=2)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [4, 5]

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((2,))})
        with pytest.raises(AssertionError):
            restore_checkpoint(str(tmp_path), None, {"b": jnp.zeros((2,))})

    def test_elastic_restore_to_other_sharding(self, tmp_path):
        """Save unsharded, restore with explicit (single-device) sharding —
        the mesh-elastic path: leaves are global, placement is restore-time."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("x",))
        tree = {"w": jnp.arange(8, dtype=jnp.float32)}
        save_checkpoint(str(tmp_path), 0, tree)
        shd = {"w": NamedSharding(mesh, P("x"))}
        out, _ = restore_checkpoint(str(tmp_path), None, tree, shardings=shd)
        assert out["w"].sharding == shd["w"]
        np.testing.assert_array_equal(out["w"], tree["w"])


class TestTrainLoopFaultTolerance:
    def test_restart_resumes_from_checkpoint(self, tmp_path):
        from repro.train import TrainLoop, make_train_step
        opt = AdamW(lr_fn=constant(1e-2))

        def loss_fn(p, batch):
            return jnp.sum((p["w"] - batch["t"]) ** 2)

        params = {"w": jnp.zeros((3,))}
        step_fn = make_train_step(loss_fn, opt)
        data_fn = lambda s: {"t": jnp.ones((3,)) * (s % 5)}
        ck = str(tmp_path / "ck")
        loop = TrainLoop(step_fn, data_fn, ckpt_dir=ck, ckpt_every=4)
        p1, o1, info1 = loop.run(params, opt.init(params), 8)
        assert info1["final_step"] == 8
        # fresh state; loop must restore step 7's checkpoint and continue
        p2, o2, info2 = loop.run({"w": jnp.full((3,), 99.0)},
                                 opt.init(params), 12)
        assert info2["final_step"] == 12
        assert float(jnp.abs(p2["w"]).max()) < 10   # not the fresh 99s

    def test_straggler_watchdog_counts(self, tmp_path):
        import time as _t
        from repro.train import TrainLoop
        calls = {"n": 0}

        def slow_step(p, o, b):
            if calls["n"] == 7:
                _t.sleep(0.25)
            calls["n"] += 1
            return p, o, {"loss": jnp.zeros(())}

        flagged = []
        loop = TrainLoop(slow_step, lambda s: {}, straggler_factor=3.0,
                         on_straggler=lambda s, r: flagged.append(s))
        loop.run({"w": jnp.zeros(())}, None, 10)
        assert flagged == [7]

    def test_straggler_watchdog_adapts_to_regime_change(self, tmp_path):
        """A PERMANENT step-time increase (longer seqs, degraded node) is a
        new baseline, not an endless straggler: after a few consecutive
        flags the window re-admits durations and the median catches up."""
        import time as _t
        from repro.train import TrainLoop
        calls = {"n": 0}

        def step_fn(p, o, b):
            _t.sleep(0.02 if calls["n"] < 5 else 0.1)
            calls["n"] += 1
            return p, o, {"loss": jnp.zeros(())}

        flagged = []
        loop = TrainLoop(step_fn, lambda s: {}, straggler_factor=3.0,
                         on_straggler=lambda s, r: flagged.append(s))
        loop.run({"w": jnp.zeros(())}, None, 20)
        assert 5 in flagged                      # the jump itself is seen
        assert not any(s >= 15 for s in flagged)  # but the baseline adapts
