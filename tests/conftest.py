"""Shared test fixtures. NOTE: no XLA device-count flag here — smoke tests
and benches must see 1 CPU device (only launch/dryrun.py forces 512)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
