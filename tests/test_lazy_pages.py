"""Lazy page growth + mid-flight preemption (ISSUE 4).

PagePool grow/watermark accounting, grow-on-boundary page-table growth,
free-list reuse after preemption, the scheduler's requeue-at-head and
shortest-prompt-first toggle, and the load-bearing determinism invariant:
a request preempted mid-decode (pages freed, re-queued, re-prefilled from
prompt + generated-so-far) produces bit-identical greedy output to the same
request run alone — for the full-KV (paged, auto-preempted on pool
exhaustion) and ring-KV (constant-size cache, explicitly preempted)
families."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import FIFOScheduler, PagePool, Request, SamplingParams, ServeEngine
from repro.serve.lifecycle import AdmissionRejected


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _alone(model, params, prompt, budget, sampling=None, **kw):
    eng = ServeEngine(model, params, **kw)
    rid = eng.submit(prompt, budget, sampling=sampling)
    eng.run()
    return eng.result(rid)


# ---------------------------------------------------------------------------
# PagePool growth accounting
# ---------------------------------------------------------------------------

class TestPagePoolGrowth:
    def test_grow_is_alloc_with_separate_accounting(self):
        pool = PagePool(6, 8)
        a = pool.alloc(2)
        g = pool.grow(1)
        assert a == [0, 1] and g == [2]
        assert pool.n_used == 3 and pool.n_grown == 1
        with pytest.raises(MemoryError):
            pool.grow(4)                   # grow gates like alloc
        assert pool.n_grown == 1           # failed grow accounts nothing

    def test_watermark_tracks_peak_not_current(self):
        pool = PagePool(8, 4)
        a = pool.alloc(3)
        assert pool.watermark == 3
        b = pool.grow(2)
        assert pool.watermark == 5
        pool.free(a + b)
        assert pool.n_used == 0 and pool.watermark == 5
        pool.alloc(2)
        assert pool.watermark == 5         # below peak: unchanged

    def test_freed_pages_reused_lowest_first_after_growth(self):
        pool = PagePool(6, 4)
        a = pool.alloc(2)                  # [0, 1]
        pool.alloc(2)                      # [2, 3]
        pool.free(a)
        assert pool.grow(3) == [0, 1, 4]   # holes first, then fresh


# ---------------------------------------------------------------------------
# Scheduler: requeue-at-head + shortest-prompt-first toggle
# ---------------------------------------------------------------------------

class TestScheduler:
    def _reqs(self, lens):
        return [Request(i, np.arange(1, n + 1), 4) for i, n in
                enumerate(lens)]

    def test_fifo_keeps_arrival_order(self):
        s = FIFOScheduler()
        for r in self._reqs((9, 3, 6)):
            s.add(r)
        assert [r.rid for r in s.take(3)] == [0, 1, 2]

    def test_spf_picks_shortest_prompt_stable(self):
        s = FIFOScheduler(policy="spf")
        for r in self._reqs((9, 3, 6, 3)):
            s.add(r)
        assert s.peek().rid == 1
        assert [r.rid for r in s.take(4)] == [1, 3, 2, 0]

    def test_requeued_resume_ahead_of_arrivals_in_rid_order(self):
        for policy in ("fifo", "spf"):
            s = FIFOScheduler(policy=policy)
            r0, r1, r2, r3 = self._reqs((9, 3, 6, 2))
            s.add(r2)
            s.add(r3)
            s.add_front(r1)                # preempted later arrival first..
            s.add_front(r0)                # ..then an earlier one
            assert s.peek().rid == 0       # arrival order within the front
            order = [r.rid for r in s.take(4)]
            assert order[:2] == [0, 1], (policy, order)


# ---------------------------------------------------------------------------
# Engine: grow-on-boundary, watermark, free-list reuse
# ---------------------------------------------------------------------------

def test_grow_on_boundary_allocates_one_page_per_crossing():
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=2, prefill_len=6,
                      page_size=8)
    rid = eng.submit(_prompts(cfg, (5,), seed=1)[0], 20)
    eng.step()                             # admit: prompt pages only
    (slot,) = eng._live.keys()
    seen = {len(eng._slot_pages[slot])}
    assert seen == {1}                     # ceil(5 / 8)
    while not eng.is_done(rid):
        eng.step()
        if slot in eng._slot_pages:
            seen.add(len(eng._slot_pages[slot]))
    # final length 5 + 20 - 1 = 24 -> three pages, grown one at a time
    assert seen == {1, 2, 3}
    assert eng.page_stats()["grown"] == 2
    assert eng.page_stats()["watermark"] == 3
    assert eng._pool.n_free == eng.n_pages


def test_preemption_frees_pages_for_lowest_index_reuse():
    """r0 ([page 0]) grows while the pool is dry: r1 (pages [1, 2], later
    arrival = lower priority) is preempted, its pages return to the free
    list immediately, and r0's growth takes the lowest freed index."""
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=2, prefill_len=10,
                      page_size=8, n_pages=3)
    p0, p1 = _prompts(cfg, (7, 9), seed=6)
    r0 = eng.submit(p0, 6)
    r1 = eng.submit(p1, 6)
    eng.step()                             # admit both: 1 + 2 pages, dry
    assert eng._slot_pages == {0: [0], 1: [1, 2]}
    eng.step()                             # r0 crosses 8: grow -> preempt r1
    assert eng.n_preemptions == 1 and not eng.is_done(r1)
    assert eng._slot_pages == {0: [0, 1]}  # lowest freed page reused
    eng.run()
    for rid, p in ((r0, p0), (r1, p1)):
        np.testing.assert_array_equal(
            eng.result(rid),
            _alone(model, params, p, 6, max_len=32, n_slots=2,
                   prefill_len=10, page_size=8, n_pages=3))
    assert eng._pool.n_free == eng.n_pages


def test_whole_reservation_mode_never_grows_or_preempts():
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 10, "page_size": 8}
    prompts = _prompts(cfg, (7, 9), seed=2)
    eng = ServeEngine(model, params, page_reservation="whole", **kw)
    out_whole = eng.generate(prompts, 8)
    assert eng.page_stats()["grown"] == 0
    assert eng.page_stats()["preemptions"] == 0
    lazy = ServeEngine(model, params, **kw)
    np.testing.assert_array_equal(out_whole, lazy.generate(prompts, 8))
    assert lazy.page_stats()["grown"] > 0


def test_lazy_admits_where_whole_reservation_starves():
    """Two requests whose full footprints (2 pages each) cannot coexist in
    a 3-page pool: whole-request reservation serializes them (occupancy
    never exceeds 1) while lazy growth runs them concurrently."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 6, "page_size": 8, "n_pages": 3}
    prompts = _prompts(cfg, (4, 4), seed=3)

    def max_occ(reservation):
        eng = ServeEngine(model, params, page_reservation=reservation, **kw)
        rids = [eng.submit(p, 12) for p in prompts]
        occ = 0
        while eng.occupancy or len(eng.scheduler):
            eng.step()
            occ = max(occ, eng.occupancy)
        for rid, p in zip(rids, prompts):
            np.testing.assert_array_equal(
                eng.result(rid), _alone(model, params, p, 12, **kw))
        return occ

    assert max_occ("whole") == 1
    assert max_occ("lazy") == 2


# ---------------------------------------------------------------------------
# Preemption parity: preempted greedy output == single-request output
# ---------------------------------------------------------------------------

def test_preempted_equals_alone_full_kv_auto():
    """Full-KV family, pool-exhaustion path: the engine preempts on its own
    when growth finds the pool dry. Every staggered request — including a
    sampled one resuming from its PRNG key snapshot — must reproduce its
    alone-run output exactly, and the drained pool must be whole."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 10, "page_size": 8, "n_pages": 3}
    prompts = _prompts(cfg, (7, 9, 5), seed=6)
    budgets = [6, 6, 8]
    samplings = [None, None, SamplingParams(temperature=0.7, top_k=5,
                                            seed=42)]
    eng = ServeEngine(model, params, **kw)
    rids = [eng.submit(prompts[0], budgets[0]),
            eng.submit(prompts[1], budgets[1])]
    eng.step()
    rids.append(eng.submit(prompts[2], budgets[2],
                           sampling=samplings[2]))   # mid-flight arrival
    eng.run()
    assert eng.n_preemptions >= 1          # the pool is too small not to
    for rid, p, b, sp in zip(rids, prompts, budgets, samplings):
        alone = _alone(model, params, p, b, sampling=sp, **kw)
        np.testing.assert_array_equal(eng.result(rid), alone)
    assert eng._pool.n_free == eng.n_pages


@pytest.mark.parametrize("arch", ["stablelm_12b", "hymba_15b"])
def test_preempted_equals_alone_explicit(arch):
    """Explicit mid-flight preemption parity for the full-KV (stablelm)
    and ring-KV (hymba: sliding-window ring + SSM state) families. The
    ring/SSM caches hold no pages, so ``preempt`` is driven by hand —
    snapshotting, re-queuing and re-prefilling follow the same path."""
    cfg, model, params = _model(arch)
    kw = {"max_len": 48, "n_slots": 2, "prefill_len": 11}
    prompts = _prompts(cfg, (5, 9, 7), seed=3)
    budgets = [10, 8, 6]
    eng = ServeEngine(model, params, **kw)
    r0 = eng.submit(prompts[0], budgets[0])
    r1 = eng.submit(prompts[1], budgets[1])
    eng.step()
    eng.step()
    assert eng.preempt(r0) == r0           # victim by rid, mid-decode
    r2 = eng.submit(prompts[2], budgets[2])
    eng.step()
    assert eng.preempt() is not None       # default victim: highest rid
    eng.run()
    for rid, p, b in zip((r0, r1, r2), prompts, budgets):
        alone = _alone(model, params, p, b, **kw)
        np.testing.assert_array_equal(eng.result(rid), alone,
                                      err_msg=f"{arch} rid {rid}")


def test_resumed_overlength_prompt_rides_a_solo_wave():
    """A resumed prompt that outgrew the pinned ``prefill_len`` must not
    drag co-admitted requests onto its longer padding: padded prompt
    length feeds MoE expert capacity, so a mixed wave would break the
    wave-independence contract for the OTHER requests. The engine admits
    over-length resumes solo; a fresh arrival sharing the queue must still
    reproduce its alone-run output — checked on the MoE family, the one
    that can actually tell."""
    cfg, model, params = _model("granite_moe_3b_a800m")
    kw = {"max_len": 48, "n_slots": 2, "prefill_len": 8}
    prompts = _prompts(cfg, (5, 6), seed=11)
    eng = ServeEngine(model, params, **kw)
    r0 = eng.submit(prompts[0], 12)
    for _ in range(5):                     # r0 generates 5 tokens
        eng.step()
    assert eng.preempt(r0) == r0           # resumed prompt 10 > prefill_len
    r1 = eng.submit(prompts[1], 4)         # fresh arrival shares the queue
    eng.run()
    assert eng.scheduler.peek() is None
    np.testing.assert_array_equal(
        eng.result(r1), _alone(model, params, prompts[1], 4, **kw))
    assert eng.result(r0).size == 12


def test_submit_errors_state_their_actual_bound():
    """The contiguous admission error names the slot-segment bound (and
    the paged escape hatch); the paged error names the page-table/pool
    bound — not the removed PR-2 ``prompt + budget <= max_len`` contract."""
    cfg, model, params = _model("stablelm_12b")
    long_prompt = _prompts(cfg, (40,), seed=4)[0]
    eng_c = ServeEngine(model, params, max_len=48, n_slots=2)
    with pytest.raises(AdmissionRejected, match=r"contiguous mode.*max_len=48"):
        eng_c.submit(long_prompt, 40)
    eng_p = ServeEngine(model, params, max_len=48, n_slots=2, page_size=16,
                        n_pages=8)
    with pytest.raises(AdmissionRejected, match=r"paged mode.*page-table"):
        eng_p.submit(_prompts(cfg, (100,), seed=5)[0], 100)
