"""Fault tolerance (ISSUE 10): request lifecycle, guard + quarantine
containment, deterministic fault injection, crash-safe engine
checkpoint/restore, and randomized chaos drills.

The invariants under test: a fault never crashes the engine or leaks
pool pages; a quarantined request retries BIT-IDENTICALLY from its
preemption snapshot (greedy and sampled) up to ``max_retries`` and then
fails with a structured error; a poisoned slot's written prefix pages
leave the index; ``snapshot_engine``/``restore_engine`` round-trips the
whole host state through JSON and resumes every cache family
bit-identically.

The CI ``chaos`` job re-runs this file under a FAULT_SEED matrix; the
randomized drill below keys its plan and traffic off that seed.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import (
    CANCELLED, FAILED, OK, QUEUED, REJECTED, RUNNING, TERMINAL_STATUSES,
    TIMED_OUT, AdmissionRejected, EngineStalled, FaultPlan, FaultSpec,
    RequestNotLive, RequestRecord, SamplingParams, ServeEngine)

FAULT_SEED = int(os.environ.get("FAULT_SEED", "0"))


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec units
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_parse_grammar_and_roundtrip(self):
        plan = FaultPlan.parse("nan@12/0, alloc@5x3, step@20, delay@1/2x2")
        assert plan.specs == (FaultSpec("nan", 12, slot=0),
                              FaultSpec("alloc", 5, count=3),
                              FaultSpec("step", 20),
                              FaultSpec("delay", 1, slot=2, count=2))
        assert FaultPlan.parse(plan.spec_str()).specs == plan.specs
        assert FaultPlan.parse(None).specs == ()
        assert FaultPlan.parse("  ").specs == ()

    def test_bad_specs_rejected(self):
        with pytest.raises(ValueError, match="bad fault spec"):
            FaultPlan.parse("nan12")
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultPlan.parse("oom@3")
        with pytest.raises(ValueError, match="count >= 1"):
            FaultSpec("nan", 2, count=0)

    def test_queries_follow_tick_and_log_firings(self):
        plan = FaultPlan.parse("alloc@2x2,nan@3/1,delay@9")
        plan.tick(1)
        assert not plan.alloc_fails() and plan.nan_slots() == []
        plan.tick(2)
        assert plan.alloc_fails() and not plan.step_fails()
        plan.tick(3)
        assert plan.alloc_fails() and plan.nan_slots() == [1]
        plan.tick(4)
        assert not plan.alloc_fails()
        assert plan.fired == [(2, "alloc", -1), (3, "alloc", -1),
                              (3, "nan", 1)]

    def test_random_plans_are_seed_deterministic(self):
        a = FaultPlan.random(7, n_steps=40, n_slots=4)
        b = FaultPlan.random(7, n_steps=40, n_slots=4)
        assert a.specs == b.specs
        assert a.specs != FaultPlan.random(8, n_steps=40, n_slots=4).specs


def test_request_record_is_an_ndarray_with_status():
    rec = RequestRecord(np.arange(4, dtype=np.int32), status=FAILED,
                        error={"kind": "guard"})
    np.testing.assert_array_equal(rec, [0, 1, 2, 3])
    assert rec.status == FAILED and rec.error == {"kind": "guard"}
    assert rec.size == 4 and isinstance(rec.tokens, np.ndarray)
    ok = RequestRecord(np.zeros(2, np.int32))
    assert ok.status == OK and ok.error is None


# ---------------------------------------------------------------------------
# Request lifecycle: statuses, cancel, deadlines, rejection
# ---------------------------------------------------------------------------

class TestLifecycle:
    def test_status_walk_and_counts(self):
        cfg, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=32, n_slots=2)
        rid = eng.submit(_prompts(cfg, (5,))[0], 4)
        assert eng.status(rid) == QUEUED
        eng.step()
        assert eng.status(rid) == RUNNING
        eng.run()
        rec = eng.result(rid)
        assert eng.status(rid) == OK and eng.is_done(rid)
        assert rec.status == OK and rec.error is None and rec.size == 4
        assert eng.status_counts() == {OK: 1}

    def test_unknown_rid_is_typed(self):
        _, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=32, n_slots=1)
        for fn in (eng.result, eng.status, eng.is_done, eng.cancel):
            with pytest.raises(RequestNotLive, match="unknown request"):
                fn(99)

    def test_cancel_queued_and_live(self):
        cfg, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=48, n_slots=1,
                          page_size=4, n_pages=12)
        a, b = (eng.submit(p, 20) for p in _prompts(cfg, (5, 6)))
        eng.step()                           # a live, b queued behind it
        assert eng.status(a) == RUNNING and eng.status(b) == QUEUED
        assert eng.cancel(b) is True         # queued cancel: just dequeue
        assert eng.status(b) == CANCELLED and eng.result(b).size == 0
        assert eng.cancel(a) is True         # live cancel: frees the slot
        assert eng.status(a) == CANCELLED and eng.occupancy == 0
        assert eng.result(a).size >= 1       # partial output retained
        assert eng._pool.n_free == eng.n_pages   # pages drained
        assert eng.cancel(a) is False        # terminal: too late
        eng.run()                            # drains trivially
        assert eng.status_counts() == {CANCELLED: 2}

    def test_deadline_times_out_live_and_queued(self):
        cfg, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=48, n_slots=1)
        pa, pb = _prompts(cfg, (5, 6))
        a = eng.submit(pa, 30, deadline_steps=3)
        b = eng.submit(pb, 4, deadline_steps=2)     # starves behind a
        eng.run()
        ra, rb = eng.result(a), eng.result(b)
        assert ra.status == TIMED_OUT and 1 <= ra.size < 30  # partial kept
        assert ra.error["kind"] == "deadline"
        assert rb.status == TIMED_OUT and rb.size == 0
        assert eng.occupancy == 0

    def test_rejection_strict_raises_lax_records(self):
        cfg, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=16, n_slots=1)
        long = _prompts(cfg, (14,))[0]
        with pytest.raises(AdmissionRejected, match="contiguous mode"):
            eng.submit(long, 10)
        rid = eng.submit(long, 10, strict=False)
        rec = eng.result(rid)
        assert rec.status == REJECTED and rec.size == 0
        assert rec.error["kind"] == "admission"
        assert "contiguous mode" in rec.error["detail"]
        assert len(eng.scheduler) == 0       # never queued
        ok = eng.submit(long[:4], 3, strict=False)   # rid sequence intact
        assert ok == rid + 1
        eng.run()
        assert eng.result(ok).status == OK

    def test_submit_knob_validation(self):
        _, model, params = _model("stablelm_12b")
        eng = ServeEngine(model, params, max_len=16, n_slots=1)
        with pytest.raises(ValueError, match="deadline_steps"):
            eng.submit(np.arange(3), 2, deadline_steps=0)
        with pytest.raises(ValueError, match="max_retries"):
            eng.submit(np.arange(3), 2, max_retries=-1)
        with pytest.raises(ValueError, match="stall_limit"):
            ServeEngine(model, params, max_len=16, stall_limit=0)


def test_stall_guard_raises_with_diagnostics():
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=1,
                      faults=FaultPlan.parse("delay@0x500"), stall_limit=5)
    eng.submit(_prompts(cfg, (5,))[0], 3)
    with pytest.raises(EngineStalled, match="no progress for 5"):
        eng.run()


def test_admission_delay_fault_only_defers():
    cfg, model, params = _model("stablelm_12b")
    plan = FaultPlan.parse("delay@0x3")
    eng = ServeEngine(model, params, max_len=32, n_slots=1, faults=plan)
    rid = eng.submit(_prompts(cfg, (5,))[0], 3)
    eng.run()
    assert eng.result(rid).status == OK
    assert [f[1] for f in plan.fired] == ["delay"] * 3


# ---------------------------------------------------------------------------
# Guard trips, quarantine, containment
# ---------------------------------------------------------------------------

def _paged(model, params, faults=None, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("n_slots", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 24)
    return ServeEngine(model, params, faults=faults, **kw)


class TestQuarantine:
    def test_nan_injection_retries_bit_identically(self):
        """One injected NaN step: the poisoned request quarantines, its
        snapshot resumes, and EVERY output — greedy and sampled — equals
        the fault-free run's."""
        cfg, model, params = _model("stablelm_12b")
        prompts = _prompts(cfg, (6, 9))
        samplings = [SamplingParams(0.0, 0, seed=0),
                     SamplingParams(1.0, 0, seed=1)]

        def run(faults):
            eng = _paged(model, params, faults=faults)
            rids = [eng.submit(p, 8, sampling=s)
                    for p, s in zip(prompts, samplings)]
            eng.run()
            return eng, [eng.result(r) for r in rids]

        ref_eng, ref = run(None)
        plan = FaultPlan.parse("nan@3/0")
        eng, got = run(plan)
        assert (3, "nan", 0) in plan.fired
        assert eng.n_quarantines == 1
        assert eng.page_stats()["quarantines"] == 1
        for g, w in zip(got, ref):
            assert g.status == OK
            np.testing.assert_array_equal(g, w)
        assert eng._pool.n_free == eng.n_pages      # nothing leaked

    def test_retries_exhaust_to_failed_with_structured_error(self):
        cfg, model, params = _model("stablelm_12b")
        prompts = _prompts(cfg, (6, 9))
        # slot 0 is poisoned for many consecutive steps: every retry
        # re-faults until max_retries is spent
        plan = FaultPlan.parse("nan@2/0x30")
        eng = _paged(model, params, faults=plan)
        a = eng.submit(prompts[0], 8, max_retries=1)
        b = eng.submit(prompts[1], 8)
        eng.run()
        ra = eng.result(a)
        assert ra.status == FAILED
        assert ra.error["kind"] == "guard" and ra.error["retries"] == 1
        assert "non-finite" in ra.error["detail"]
        assert eng.result(b).status == OK           # neighbor unharmed
        assert eng.n_quarantines == 2               # initial trip + retry
        assert eng._pool.n_free == eng.n_pages

    def test_zero_retries_fails_on_first_trip(self):
        cfg, model, params = _model("stablelm_12b")
        plan = FaultPlan.parse("nan@2/0")
        eng = _paged(model, params, faults=plan, n_slots=1)
        rid = eng.submit(_prompts(cfg, (6,))[0], 6, max_retries=0)
        eng.run()
        assert eng.result(rid).status == FAILED
        assert eng.n_quarantines == 1 and eng.n_preemptions == 0

    def test_guards_off_lets_poison_through(self):
        cfg, model, params = _model("stablelm_12b")
        plan = FaultPlan.parse("nan@2/0")
        eng = _paged(model, params, faults=plan, n_slots=1, guards=False)
        rid = eng.submit(_prompts(cfg, (6,))[0], 6)
        eng.run()
        rec = eng.result(rid)
        assert rec.status == OK and eng.n_quarantines == 0   # undetected

    def test_quarantine_invalidates_written_prefix_pages(self):
        """A poisoned slot's landed prompt pages must leave the index —
        a chain key commits to TOKENS, so a poisoned page would keep
        serving future matches forever if its entry survived."""
        cfg, model, params = _model("stablelm_12b")
        prompt = _prompts(cfg, (16,), seed=5)[0]     # 4 full pages
        # chunk 4: the prompt lands over steps 0-3, decode starts at 4 —
        # step 6 poisons mid-decode, and the window closes before the
        # clean resubmission below
        plan = FaultPlan.parse("nan@6/0x2")
        eng = ServeEngine(model, params, max_len=64, n_slots=1,
                          page_size=4, n_pages=24, prefill_chunk=4,
                          prefix_cache=True, faults=plan)
        rid = eng.submit(prompt, 6, max_retries=0)
        eng.run()
        assert eng.result(rid).status == FAILED
        stats = eng.page_stats()
        assert stats["prefix"]["invalidated"] >= 4   # the prompt chain
        assert stats["prefix"]["entries"] == 0
        assert eng._pool.n_free == eng.n_pages       # index refs released
        # the engine still serves: the same prompt re-lands cleanly
        rid2 = eng.submit(prompt, 6)
        eng.run()
        assert eng.result(rid2).status == OK
        assert eng.page_stats()["prefix"]["entries"] == 4


class TestContainment:
    def test_step_fault_contained_and_bit_identical(self):
        cfg, model, params = _model("stablelm_12b")
        prompts = _prompts(cfg, (6, 9))

        def run(faults):
            eng = _paged(model, params, faults=faults)
            rids = [eng.submit(p, 8) for p in prompts]
            eng.run()
            return eng, [eng.result(r) for r in rids]

        _, ref = run(None)
        plan = FaultPlan.parse("step@2x3")
        eng, got = run(plan)
        assert eng.n_faults_contained == 3
        assert [f[1] for f in plan.fired] == ["step"] * 3
        for g, w in zip(got, ref):
            assert g.status == OK
            np.testing.assert_array_equal(g, w)

    def test_alloc_fault_preempts_instead_of_crashing(self):
        cfg, model, params = _model("stablelm_12b")
        prompts = _prompts(cfg, (6, 7))

        def run(faults):
            eng = _paged(model, params, faults=faults, n_pages=16)
            rids = [eng.submit(p, 12) for p in prompts]
            eng.run()
            return eng, [eng.result(r) for r in rids]

        _, ref = run(None)
        # lazy growth first fires when a slot's length crosses its prompt
        # pages; blanket the window so the injection must hit one
        plan = FaultPlan.parse("alloc@1x8")
        eng, got = run(plan)
        assert any(k == "alloc" for _, k, _ in plan.fired)
        assert eng.n_faults_contained >= 1
        assert eng.n_preemptions >= 1
        for g, w in zip(got, ref):
            assert g.status == OK
            np.testing.assert_array_equal(g, w)
        assert eng._pool.n_free == eng.n_pages


# ---------------------------------------------------------------------------
# Crash-safe checkpoint / restore
# ---------------------------------------------------------------------------

def _roundtrip(make_engine, submit_all, steps_before):
    """Reference run; crash a twin mid-flight at ``steps_before`` steps;
    restore its JSON snapshot onto a fresh engine; everything must finish
    OK and bit-identical. The snapshot must also be NON-mutating: the
    source engine keeps running to the same outputs."""
    ref = make_engine()
    rids = submit_all(ref)
    ref.run()
    want = [ref.result(r) for r in rids]

    src = make_engine()
    assert submit_all(src) == rids
    for _ in range(steps_before):
        src.step()
    state = json.loads(json.dumps(src.snapshot_engine()))

    dst = make_engine()
    dst.restore_engine(state)
    dst.run()
    for rid, w in zip(rids, want):
        got = dst.result(rid)
        assert got.status == OK
        np.testing.assert_array_equal(got, w)

    src.run()                                    # snapshot didn't perturb
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(src.result(rid), w)
    return state


def _mixed_submitter(prompts, budgets):
    """Alternating greedy / sampled submissions — one round trip proves
    both the deterministic path and the PRNG-chain path."""
    def go(eng):
        return [eng.submit(p, b, sampling=SamplingParams(
                    float(i % 2), 0, seed=i))
                for i, (p, b) in enumerate(zip(prompts, budgets))]
    return go


_CKPT_FAMILIES = [
    ("full", "stablelm_12b",
     dict(max_len=64, n_slots=2, prefill_len=12)),
    ("ring", "hymba_15b",
     dict(max_len=48, n_slots=2, prefill_len=12)),
    ("ssm", "mamba2_130m",
     dict(max_len=48, n_slots=2, prefill_len=12)),
]


@pytest.mark.parametrize("name,arch,kw", _CKPT_FAMILIES,
                         ids=[c[0] for c in _CKPT_FAMILIES])
def test_checkpoint_restore_bit_identical(name, arch, kw):
    cfg, model, params = _model(arch)
    prompts = _prompts(cfg, (5, 9, 12, 7), seed=3)
    _roundtrip(lambda: ServeEngine(model, params, **kw),
               _mixed_submitter(prompts, (6, 8, 5, 7)), steps_before=3)


def test_checkpoint_restore_paged_chunked_prefix_mid_plan():
    """The hardest token case in one drill: paged + chunked + prefix
    cache, snapshotted while one prompt is MID-ChunkPlan and two requests
    are actively sharing prefix pages."""
    cfg, model, params = _model("stablelm_12b")
    rng = np.random.RandomState(9)
    head = rng.randint(0, cfg.vocab, (16,)).astype(np.int32)
    prompts = [
        np.concatenate([head, rng.randint(0, cfg.vocab, (8,)).astype(np.int32)]),
        np.concatenate([head, rng.randint(0, cfg.vocab, (4,)).astype(np.int32)]),
        rng.randint(0, cfg.vocab, (26,)).astype(np.int32),   # mid-plan one
    ]
    state = _roundtrip(
        lambda: ServeEngine(model, params, max_len=64, n_slots=2,
                            page_size=4, n_pages=32, prefill_chunk=4,
                            prefix_cache=True),
        _mixed_submitter(prompts, (6, 7, 5)), steps_before=3)
    # the snapshot really did catch live work (prompts of 24+ tokens at
    # chunk 4 cannot have landed in 3 steps)
    assert len(state["live"]) + len(state["queue"]["front"]) \
        + len(state["queue"]["arrivals"]) >= 1


def test_checkpoint_restore_pairformer():
    cfg, model, params = _model("pairformer_lite")
    rng = np.random.RandomState(2)
    feats = [rng.standard_normal((n, 64)).astype(np.float32)
             for n in (12, 7, 9)]

    def submit_all(eng):
        return [eng.submit(f, b) for f, b in zip(feats, (3, 5, 4))]

    _roundtrip(lambda: ServeEngine(model, params, max_len=16, n_slots=2),
               submit_all, steps_before=2)


def test_checkpoint_preserves_lifecycle_records():
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=1)
    prompts = _prompts(cfg, (5, 6, 30))
    done = eng.submit(prompts[0], 3)
    eng.run()
    live = eng.submit(prompts[1], 20)
    dead = eng.submit(prompts[2], 4, strict=False)   # REJECTED: 30+4 > 32
    cancelled = eng.submit(prompts[0], 5)
    eng.step()
    eng.cancel(cancelled)
    state = json.loads(json.dumps(eng.snapshot_engine()))

    dst = ServeEngine(model, params, max_len=32, n_slots=1)
    dst.restore_engine(state)
    assert dst.status(done) == OK
    np.testing.assert_array_equal(dst.result(done), eng.result(done))
    assert dst.status(dead) == REJECTED
    assert dst.result(dead).error["kind"] == "admission"
    assert dst.status(cancelled) == CANCELLED
    dst.run()
    assert dst.status(live) == OK and dst.result(live).size == 20
    # rid sequence continues where the snapshot left off
    assert dst.submit(prompts[0], 1) == cancelled + 1


def test_restore_refuses_mismatch_and_reuse():
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=2)
    state = eng.snapshot_engine()
    assert state["version"] == 1

    other = ServeEngine(model, params, max_len=48, n_slots=2)
    with pytest.raises(ValueError, match="config mismatch.*max_len"):
        other.restore_engine(state)

    used = ServeEngine(model, params, max_len=32, n_slots=2)
    used.submit(_prompts(cfg, (4,))[0], 2)
    with pytest.raises(ValueError, match="fresh engine"):
        used.restore_engine(state)

    bad = dict(state, version=99)
    fresh = ServeEngine(model, params, max_len=32, n_slots=2)
    with pytest.raises(ValueError, match="snapshot version"):
        fresh.restore_engine(bad)


# ---------------------------------------------------------------------------
# Randomized chaos drill (seeded; CI re-runs under a FAULT_SEED matrix)
# ---------------------------------------------------------------------------

def test_randomized_chaos_conserves_pool_and_terminates():
    """~60 engine steps of seeded random traffic (mixed greedy/sampled,
    staggered arrivals) interleaved with random cancels, forced preempts
    and a random fault plan over every kind. Afterwards: every request
    reached a terminal status, no slot stayed occupied, and every pool
    page is accounted for — held only by the prefix index, refcount
    exactly 1 (refcounts drained, nothing leaked)."""
    cfg, model, params = _model("stablelm_12b")
    plan = FaultPlan.random(FAULT_SEED, n_steps=50, n_slots=3, n_faults=6)
    eng = ServeEngine(model, params, max_len=64, n_slots=3, page_size=4,
                      n_pages=28, prefill_chunk=4, prefix_cache=True,
                      faults=plan, stall_limit=300)
    rng = np.random.RandomState(FAULT_SEED + 1000)
    rids = []
    for _ in range(60):
        if len(rids) < 12 and rng.rand() < 0.4:
            prompt = rng.randint(0, cfg.vocab,
                                 (int(rng.randint(3, 20)),)).astype(np.int32)
            rids.append(eng.submit(
                prompt, int(rng.randint(2, 10)),
                sampling=SamplingParams(float(rng.rand() < 0.5), 0,
                                        seed=len(rids)),
                max_retries=2,
                deadline_steps=None if rng.rand() < 0.7 else 40))
        if rids and rng.rand() < 0.1:
            victim = rids[int(rng.randint(len(rids)))]
            if eng.status(victim) not in TERMINAL_STATUSES:
                assert eng.cancel(victim) is True
        if eng.occupancy and rng.rand() < 0.1:
            eng.preempt()
        eng.step()
    eng.run()

    assert len(rids) > 0 and eng.occupancy == 0
    counts = eng.status_counts()
    assert sum(counts.values()) == len(rids)
    assert set(counts) <= TERMINAL_STATUSES
    for rid in rids:
        rec = eng.result(rid)
        if rec.status == OK:
            assert rec.size >= 1
    # page conservation: the only remaining holders are index entries
    pool, prefix = eng._pool, eng.backend._prefix
    assert not eng._slot_pages
    index_pages = {e.page for e in prefix._entries.values()}
    assert pool.n_used == len(index_pages)
    for page in index_pages:
        assert pool.refcount(page) == 1
