"""Continuous-batching engine: staggered arrivals/finishes must reproduce
each request's single-request output exactly (per-slot computation is
batch-row independent and the sampler key chain is per-request); plus the
eos-fill regression, the sampling layer, and the FIFO scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import (
    FIFOScheduler,
    Request,
    SamplingParams,
    ServeEngine,
    sample_tokens,
)

PF = 12           # pinned prefill_len: request outputs must not depend on
                  # wave composition, so the one wave-dependent shape is fixed


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _alone(model, params, prompt, budget, sampling=None, **kw):
    eng = ServeEngine(model, params, **kw)
    rid = eng.submit(prompt, budget, sampling=sampling)
    eng.run()
    return eng.result(rid)


def _prompts(cfg, lens, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def test_staggered_arrivals_match_single_request_runs():
    """4 ragged requests through 2 slots, arriving and finishing at
    different steps (budgets differ); one uses temperature+top-k sampling.
    Every output must equal the same request run alone."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 64, "n_slots": 2, "prefill_len": PF}
    prompts = _prompts(cfg, (5, 9, 7, 12))
    budgets = [8, 5, 10, 6]
    samplings = [None, None, None,
                 SamplingParams(temperature=0.7, top_k=5, seed=42)]

    eng = ServeEngine(model, params, **kw)
    r0 = eng.submit(prompts[0], budgets[0])
    r1 = eng.submit(prompts[1], budgets[1])
    eng.step()
    eng.step()
    r2 = eng.submit(prompts[2], budgets[2])          # mid-flight arrival
    eng.step()
    r3 = eng.submit(prompts[3], budgets[3], sampling=samplings[3])
    eng.run()

    for i, rid in enumerate((r0, r1, r2, r3)):
        got = eng.result(rid)
        assert got.size == budgets[i]
        alone = _alone(model, params, prompts[i], budgets[i],
                       sampling=samplings[i], **kw)
        np.testing.assert_array_equal(got, alone, err_msg=f"request {i}")


@pytest.mark.parametrize("arch", ["hymba_15b", "mamba2_130m"])
def test_ring_and_ssm_cache_staggered_parity(arch):
    """The slot discipline must hold for every cache kind: hymba = ring KV
    (window < max_len) + SSM state, mamba2 = pure constant-size SSM."""
    cfg, model, params = _model(arch)
    kw = {"max_len": 48, "n_slots": 2, "prefill_len": 11}
    prompts = _prompts(cfg, (4, 11, 7), seed=2)
    budgets = [7, 4, 6]

    eng = ServeEngine(model, params, **kw)
    rids = [eng.submit(prompts[0], budgets[0]),
            eng.submit(prompts[1], budgets[1])]
    eng.step()
    eng.step()
    rids.append(eng.submit(prompts[2], budgets[2]))
    eng.run()

    for i, rid in enumerate(rids):
        alone = _alone(model, params, prompts[i], budgets[i], **kw)
        np.testing.assert_array_equal(eng.result(rid), alone,
                                      err_msg=f"{arch} request {i}")


def test_early_eos_pads_output_with_eos_id():
    """Regression (ISSUE 2): the old engine initialized the output buffer
    with 0 — a valid token id — so early-finished rows read as if they had
    generated token 0 forever."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 64, "n_slots": 2, "prefill_len": PF}
    prompts = _prompts(cfg, (6, 8), seed=3)

    eng = ServeEngine(model, params, **kw)
    ref = eng.generate(prompts, 8)
    # pick an eos that request 0 emits mid-stream and request 1 never does
    pos = next(i for i in range(1, 8) if ref[0, i] not in ref[1])
    eos = int(ref[0, pos])
    assert eos != 0, "need a nonzero eos for the regression to bite"

    eng2 = ServeEngine(model, params, eos_id=eos, **kw)
    out = eng2.generate(prompts, 8)
    np.testing.assert_array_equal(out[0, :pos + 1], ref[0, :pos + 1])
    assert (out[0, pos:] == eos).all()      # eos kept + eos-padded, not 0
    np.testing.assert_array_equal(out[1], ref[1])

    # the freed slot is re-admissible: a queued request takes it over
    eng3 = ServeEngine(model, params, eos_id=eos, **kw)
    rids = [eng3.submit(p, 8) for p in prompts + prompts]  # 4 reqs, 2 slots
    eng3.run()
    np.testing.assert_array_equal(eng3.result(rids[2]), eng3.result(rids[0]))


@pytest.mark.parametrize("arch", ["stablelm_12b", "hymba_15b", "mamba2_130m"])
def test_ragged_prefill_matches_unpadded_ground_truth(arch):
    """The ragged machinery itself (last-valid logits gather, SSM dt=0
    freeze + conv-tail gather, per-request ring fill) must agree with an
    UNPADDED prefill of each prompt — not merely with another padded run
    through the same code path."""
    cfg, model, params = _model(arch)
    max_len = 48
    # hymba: one prompt LONGER than the window (32) so the per-request
    # ring-gather path is exercised, not just the pad-to-window branch
    lens = [5, 35, 20] if arch == "hymba_15b" else [5, 13, 9]
    prompts = _prompts(cfg, lens, seed=5)
    padded = np.zeros((3, max(lens)), np.int32)
    for i, p in enumerate(prompts):
        padded[i, :p.size] = p
    lg, cache = model.prefill(params, {"tokens": jnp.asarray(padded)},
                              max_len=max_len,
                              lengths=jnp.asarray(lens, jnp.int32))
    for i, p in enumerate(prompts):
        lg1, c1 = model.prefill(params, {"tokens": jnp.asarray(p[None])},
                                max_len=max_len)
        np.testing.assert_allclose(lg[i:i + 1], lg1, atol=1e-4,
                                   err_msg=f"{arch} prefill logits row {i}")
        # decoding one token from each cache must also agree (checks that
        # the cache state — KV rows / ring slots / SSM state / conv tails —
        # froze at the right position, not just the logits gather)
        nxt = jnp.argmax(lg1[:, -1], -1).astype(jnp.int32)[:, None]
        d0, _ = model.decode(params, c1, nxt)
        ci = jax.tree.map(lambda x: x[i:i + 1] if x.ndim == 1
                          else x[:, i:i + 1], cache)
        d1, _ = model.decode(params, ci, nxt)
        np.testing.assert_allclose(d1, d0, atol=1e-4,
                                   err_msg=f"{arch} decode-after row {i}")


def test_ring_and_ssm_accept_prompts_longer_than_max_len():
    """Ring-KV keeps the last `window` keys and SSM state is constant-size,
    so submit() must not cap their prompts at the slot segment length."""
    for arch in ("hymba_15b", "mamba2_130m"):
        cfg, model, params = _model(arch)
        eng = ServeEngine(model, params, max_len=40, n_slots=2)
        rng = np.random.RandomState(6)
        long_prompt = rng.randint(0, cfg.vocab, (55,)).astype(np.int32)
        rid = eng.submit(long_prompt, 4)
        eng.run()
        assert eng.result(rid).size == 4


def test_generate_accepts_ragged_prompt_lists():
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=64, n_slots=2, prefill_len=PF)
    prompts = _prompts(cfg, (3, 12, 5), seed=4)
    out = eng.generate(prompts, 6)
    assert out.shape == (3, 6) and out.dtype == np.int32
    alone = _alone(model, params, prompts[1], 6, max_len=64, n_slots=2,
                   prefill_len=PF)
    np.testing.assert_array_equal(out[1], alone)


class TestSampling:
    def test_greedy_topk1_and_vocab_mask(self):
        logits = jnp.asarray([[0.1, 3.0, 2.0, 9.0],
                              [5.0, -1.0, 0.0, 7.0]])
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        zeros = jnp.zeros((2,))
        # temperature 0 -> argmax
        tok, _ = sample_tokens(logits, zeros, jnp.zeros((2,), jnp.int32),
                               keys, 4)
        np.testing.assert_array_equal(tok, [3, 3])
        # top_k=1 with temperature > 0 -> still argmax
        tok, _ = sample_tokens(logits, zeros + 1.0,
                               jnp.ones((2,), jnp.int32), keys, 4)
        np.testing.assert_array_equal(tok, [3, 3])
        # TP-padded vocab rows (id >= vocab) can never be emitted
        tok, _ = sample_tokens(logits, zeros, jnp.zeros((2,), jnp.int32),
                               keys, 3)
        np.testing.assert_array_equal(tok, [1, 0])

    def test_key_chain_is_per_slot_and_reproducible(self):
        logits = jnp.ones((2, 16))
        temps = jnp.full((2,), 1.0)
        topks = jnp.zeros((2,), jnp.int32)
        keys = jnp.stack([jax.random.PRNGKey(7), jax.random.PRNGKey(7)])
        t1, k1 = sample_tokens(logits, temps, topks, keys, 16)
        t2, _ = sample_tokens(logits, temps, topks, k1, 16)
        # same seed in both slots -> identical streams slot-wise
        assert int(t1[0]) == int(t1[1]) and int(t2[0]) == int(t2[1])
        # chain advances
        r1, _ = sample_tokens(logits, temps, topks, keys, 16)
        np.testing.assert_array_equal(t1, r1)   # same key -> same draw


def test_fifo_scheduler_order_and_take():
    sched = FIFOScheduler()
    for i in range(5):
        sched.add(Request(i, np.array([1, 2], np.int32), 4))
    assert len(sched) == 5
    wave = sched.take(2)
    assert [r.rid for r in wave] == [0, 1]
    assert [r.rid for r in sched.take(10)] == [2, 3, 4]
    assert sched.take(3) == [] and len(sched) == 0
