"""Attention execution paths agree: dense == chunked == Eq.3 concat == kernel
wrapper, across masks, GQA, factored bias, dense bias, kv_length."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: @given tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

import repro.core.attention as A
import repro.core.bias as bias_mod
from repro.core.attention import MaskSpec
from repro.kernels import ref


def _mk(key, b, n, m, h, kvh, d):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, m, kvh, d))
    v = jax.random.normal(ks[2], (b, m, kvh, d))
    return q, k, v


@pytest.mark.parametrize("kvh", [1, 2, 8])
@pytest.mark.parametrize("mask", ["none", "causal", "local"])
def test_dense_vs_chunked(kvh, mask):
    q, k, v = _mk(jax.random.PRNGKey(0), 2, 40, 40, 8, kvh, 16)
    ms = MaskSpec(mask, 12 if mask == "local" else 0)
    o1 = A.attention(q, k, v, mask=ms, impl="dense")
    o2 = A.attention(q, k, v, mask=ms, impl="chunked", chunk_size=16)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


def test_factored_bias_equals_dense_bias():
    h = 8
    q, k, v = _mk(jax.random.PRNGKey(1), 2, 32, 32, h, 4, 16)
    pq, pk = bias_mod.alibi_factors(32, 32, h)
    o_f = A.attention(q, k, v, impl="chunked", chunk_size=8,
                      phi_q=bias_mod.broadcast_factors(pq, 2, 32, h),
                      phi_k=bias_mod.broadcast_factors(pk, 2, 32, h))
    o_d = A.attention(q, k, v, impl="dense",
                      bias=bias_mod.alibi_dense(32, 32, h)[None])
    np.testing.assert_allclose(o_f, o_d, atol=2e-5)


def test_eq3_concat_identity():
    """The paper's core identity (Eq. 3): biased attention == standard
    attention over C+R channels."""
    h, d = 4, 16
    q, k, v = _mk(jax.random.PRNGKey(2), 2, 24, 24, h, 2, d)
    pq, pk = bias_mod.alibi_factors(24, 24, h)
    pq4 = bias_mod.broadcast_factors(pq, 2, 24, h)
    pk1 = bias_mod.broadcast_factors(pk, 2, 24, 1)
    q_aug, k_aug = A.flashbias_concat_qk(q, k, pq4, pk1)
    assert q_aug.shape[-1] == d + 2
    o_concat = A.attention(q_aug, k_aug, v, impl="dense",
                           scale=1.0 / np.sqrt(d))
    o_bias = A.attention(q, k, v, impl="dense",
                         bias=bias_mod.alibi_dense(24, 24, h)[None])
    np.testing.assert_allclose(o_concat, o_bias, atol=2e-5)


def test_kv_length_masks_tail():
    q, k, v = _mk(jax.random.PRNGKey(3), 2, 4, 32, 4, 4, 8)
    o_len = A.attention(q, k, v, impl="chunked", chunk_size=8,
                        kv_length=jnp.array([20, 32]))
    o_trunc0 = A.attention(q[:1], k[:1, :20], v[:1, :20], impl="dense")
    np.testing.assert_allclose(o_len[0], o_trunc0[0], atol=2e-5)


def test_q_offset_decode_row():
    """Row t of full causal attention == decode with q_offset=t."""
    q, k, v = _mk(jax.random.PRNGKey(4), 1, 16, 16, 2, 2, 8)
    full = A.attention(q, k, v, mask=MaskSpec("causal"), impl="dense")
    row = A.attention(q[:, 10:11], k, v, mask=MaskSpec("causal"),
                      impl="chunked", chunk_size=4, q_offset=10)
    np.testing.assert_allclose(row[:, 0], full[:, 10], atol=2e-5)


def test_multiplicative_extension():
    """App. I Eq. 17: channel expansion computes softmax((qk^T) o b) v."""
    h, d, n = 2, 8, 12
    q, k, v = _mk(jax.random.PRNGKey(5), 1, n, n, h, h, d)
    pq, pk = bias_mod.cos_relpos_factors(n, n)
    pq4 = bias_mod.broadcast_factors(pq, 1, n, h)
    pk4 = bias_mod.broadcast_factors(pk, 1, n, h)
    o = A.multiplicative_flashbias_attention(q, k, v, pq4, pk4)
    bm = bias_mod.cos_relpos_dense(n, n)
    s = jnp.einsum("bnhd,bmhd->bhnm", q, k) / np.sqrt(d) * bm[None, None]
    o_ref = jnp.einsum("bhnm,bmhd->bnhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(o, o_ref, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 24), h=st.integers(1, 4), d=st.sampled_from([4, 8]),
       chunk=st.sampled_from([4, 8, 16]))
def test_property_chunked_matches_oracle(n, h, d, chunk):
    """Chunked online-softmax == dense oracle for random shapes/chunks."""
    key = jax.random.PRNGKey(n * 100 + h * 10 + d)
    q, k, v = _mk(key, 1, n, n, h, h, d)
    o1 = A.attention(q, k, v, mask=MaskSpec("causal"), impl="chunked",
                     chunk_size=chunk)
    o2 = ref.mha_reference(q, k, v, mask_kind="causal")
    np.testing.assert_allclose(o1, o2, atol=3e-5)


def test_softmax_invariance_property():
    """Adding any rank-1 bias constant over keys leaves outputs unchanged
    (softmax shift invariance) — a system invariant FlashBias must respect."""
    h, n = 2, 16
    q, k, v = _mk(jax.random.PRNGKey(6), 1, n, n, h, h, 8)
    pq = jnp.ones((1, n, h, 1)) * 3.7            # constant-per-query bias
    pk = jnp.ones((1, n, h, 1))
    o_b = A.attention(q, k, v, impl="chunked", chunk_size=4,
                      phi_q=pq, phi_k=pk)
    o_0 = A.attention(q, k, v, impl="chunked", chunk_size=4)
    np.testing.assert_allclose(o_b, o_0, atol=2e-5)
