"""Batched Pairformer serving (ISSUE 6, paper Sec. 4.4): a request is one
complex, admission caches its pair-bias factors per slot, every step is one
refinement iteration over the padded slot batch. The contract under test:

- batched == solo, bitwise: per-slot computation is batch-row independent
  and padding is pinned at max_len, so a complex's result is identical
  whether it shares the batch with strangers or runs alone;
- the factor cache is admission-frozen: steps reuse phi_q/phi_k untouched
  (the Pairformer analogue of the LM KV cache);
- the cached-dense and official-recompute dense dataflows are the same
  math (BENCH_pairformer's baselines measure representation cost only);
- priority classes order admission and pick preemption victims, and the
  all-default case is bit-identical to the classless engine.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models import pairformer as pf_mod
from repro.models.common import init_params, stack_layers
from repro.serve import FIFOScheduler, PairBatchBackend, Request, ServeEngine
from repro.serve.lifecycle import AdmissionRejected

MAX_LEN = 16      # pinned residue padding: results must not depend on wave
                  # composition, so the one wave-dependent shape is fixed


def _model(**overrides):
    cfg = smoke_config("pairformer_lite")
    if overrides:
        cfg = cfg.replace(**overrides)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _complexes(lens, f=64, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.standard_normal((n, f)).astype(np.float32) for n in lens]


def _alone(model, params, feats, budget, **kw):
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=1, **kw)
    rid = eng.submit(feats, budget)
    eng.run()
    return eng.result(rid)


def test_batched_matches_single_complex_runs():
    """5 variable-length complexes through 2 slots, arriving mid-flight and
    finishing at different steps (budgets differ). Every result must be
    bit-equal to the same complex served alone."""
    cfg, model, params = _model()
    complexes = _complexes((12, 7, 16, 9, 5))
    budgets = [3, 5, 2, 4, 3]

    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=2)
    r0 = eng.submit(complexes[0], budgets[0])
    r1 = eng.submit(complexes[1], budgets[1])
    eng.step()
    r2 = eng.submit(complexes[2], budgets[2])        # mid-flight arrivals
    eng.step()
    r3 = eng.submit(complexes[3], budgets[3])
    r4 = eng.submit(complexes[4], budgets[4])
    eng.run()
    assert eng.occupancy == 0 and eng.page_stats() == {}

    for i, rid in enumerate((r0, r1, r2, r3, r4)):
        assert eng.is_done(rid)
        got = eng.result(rid)
        assert got.shape == (complexes[i].shape[0], cfg.d_model)
        ref = _alone(model, params, complexes[i], budgets[i])
        np.testing.assert_array_equal(got, ref)


def test_factor_cache_frozen_across_steps():
    """Admission writes the per-layer SVD factors once; refinement steps
    reuse them bitwise-untouched while the single rep advances — the
    factor cache never recomputes (that IS the serving claim)."""
    _, model, params = _model()
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=2)
    for c in _complexes((11, 8)):
        eng.submit(c, 6)
    eng.admit()
    cache = eng.backend._cache
    assert "phi_q" in cache and "phi_k" in cache      # svd factor mode
    phi_q0 = np.asarray(cache["phi_q"]).copy()
    phi_k0 = np.asarray(cache["phi_k"]).copy()
    s_prev = np.asarray(cache["s"]).copy()
    for _ in range(3):
        eng.decode()
        cache = eng.backend._cache
        np.testing.assert_array_equal(np.asarray(cache["phi_q"]), phi_q0)
        np.testing.assert_array_equal(np.asarray(cache["phi_k"]), phi_k0)
        s_now = np.asarray(cache["s"])
        assert np.isfinite(s_now).all()
        assert not np.array_equal(s_now, s_prev)      # rep is refined
        s_prev = s_now.copy()


def test_dense_cached_and_recompute_paths_agree():
    """``bias_mode="dense"`` (bias cached at admission) and
    ``"dense_recompute"`` (the official AF3 dataflow: z cached, bias
    re-projected per step) are the same math in a different place — the
    bench's two dense baselines must price the SAME numbers."""
    _, model_c, params = _model(bias_mode="dense")
    _, model_r, _ = _model(bias_mode="dense_recompute")
    feats = _complexes((13,), seed=3)[0]
    got_c = _alone(model_c, params, feats, 4)
    got_r = _alone(model_r, params, feats, 4)
    np.testing.assert_array_equal(got_c, got_r)


def test_full_rank_svd_matches_dense_serve():
    """Sec. 4.3: with rank >= n_res the truncated SVD is exact, so the
    factored serve path reproduces the dense-bias serve path."""
    _, model_f, params = _model()                    # svd, bias_rank=8
    _, model_d, _ = _model(bias_mode="dense")
    feats = _complexes((7,), seed=4)[0]              # n_res 7 < rank 8
    got_f = _alone(model_f, params, feats, 3)
    got_d = _alone(model_d, params, feats, 3)
    np.testing.assert_allclose(got_f, got_d, atol=1e-4)


def test_factor_mlp_cache_serves_batched():
    """Eq. 5 factor-MLP mode: fitted (here randomly initialised — the
    contract is structural) factor params ride ``factors=`` into the
    engine; the cache holds MLP factors at the full configured rank and
    batched results still match solo bitwise."""
    cfg, model, params = _model()
    fp = init_params(stack_layers(pf_mod.factor_mlp_template(cfg, hidden=16),
                                  cfg.n_layers), jax.random.PRNGKey(5))
    complexes = _complexes((10, 6), seed=6)
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=2, factors=fp)
    rids = [eng.submit(c, 3) for c in complexes]
    eng.run()
    assert eng.backend._cache["phi_q"].shape[-1] == cfg.bias_rank
    for c, rid in zip(complexes, rids):
        ref = _alone(model, params, c, 3, factors=fp)
        np.testing.assert_array_equal(eng.result(rid), ref)


def test_pair_request_validation():
    _, model, params = _model()
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=2)
    with pytest.raises(AdmissionRejected):           # int prompt payload
        eng.submit(np.arange(5, dtype=np.int32), 3)
    with pytest.raises(AdmissionRejected):           # exceeds max_len
        eng.submit(np.zeros((MAX_LEN + 1, 64), np.float32), 3)
    with pytest.raises(TypeError):                   # token-emitting API
        eng.generate([np.zeros((4, 64), np.float32)], 3)
    assert isinstance(eng.backend, PairBatchBackend)


def test_on_token_streams_per_refinement_step():
    """The pair backend emits no tokens, so ``submit(on_token=...)``
    drains the per-step (n_res, d_model) state instead: one callback per
    refinement iteration, and the final drained state IS the result."""
    cfg, model, params = _model()
    feats = _complexes((9,), seed=9)[0]
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=1)
    steps = []
    rid = eng.submit(feats, 4, on_token=steps.append)
    eng.run()
    assert len(steps) == 4                            # one per iteration
    assert all(s.shape == (9, cfg.d_model) for s in steps)
    assert not np.array_equal(steps[0], steps[-1])    # rep is refined
    np.testing.assert_array_equal(steps[-1], eng.result(rid))


def test_priority_classes_order_admission():
    """Higher class admits first regardless of arrival; within a class the
    policy is untouched FIFO — and with all-default priorities the order
    is bit-identical to the classless scheduler."""
    sched = FIFOScheduler()
    feats = np.zeros((4, 8), np.float32)
    for rid, pri in enumerate((0, 5, 0, 5, -1)):
        sched.add(Request(rid, feats, 1, priority=pri))
    assert [r.rid for r in sched.take(5)] == [1, 3, 0, 2, 4]

    sched = FIFOScheduler()                           # all-default: FIFO
    for rid in range(4):
        sched.add(Request(rid, feats, 1))
    assert [r.rid for r in sched.take(4)] == [0, 1, 2, 3]

    sched = FIFOScheduler(policy="spf")               # class outranks length
    sched.add(Request(0, np.zeros((2, 8), np.float32), 1, priority=0))
    sched.add(Request(1, np.zeros((9, 8), np.float32), 1, priority=1))
    sched.add(Request(2, np.zeros((4, 8), np.float32), 1, priority=1))
    assert [r.rid for r in sched.take(3)] == [2, 1, 0]


def test_add_front_orders_resumed_requests_by_class():
    """Preempted requests resume ahead of every arrival; within the front
    queue higher classes stay ahead and earlier rids break ties."""
    sched = FIFOScheduler()
    feats = np.zeros((4, 8), np.float32)
    sched.add(Request(9, feats, 1, priority=7))       # queued arrival
    sched.add_front(Request(2, feats, 1, priority=0))
    sched.add_front(Request(1, feats, 1, priority=3))
    sched.add_front(Request(3, feats, 1, priority=3))
    assert [r.rid for r in sched.take(4)] == [1, 3, 2, 9]


def test_preemption_victim_is_lowest_class_then_latest():
    """The engine evicts the lowest class first, latest arrival within it;
    the preempted complex restarts with its full budget and its final
    result still matches the solo run (nothing incremental was lost)."""
    _, model, params = _model()
    complexes = _complexes((9, 11, 6), seed=7)
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=3)
    rids = [eng.submit(c, 4, priority=p)
            for c, p in zip(complexes, (2, 0, 1))]
    eng.admit()
    eng.decode()
    assert eng.preempt() == rids[1]                   # class 0 evicts first
    assert eng.preempt() == rids[2]                   # then class 1
    assert eng.n_preemptions == 2 and eng.occupancy == 1
    eng.run()
    for c, rid in zip(complexes, rids):
        np.testing.assert_array_equal(eng.result(rid),
                                      _alone(model, params, c, 4))


def test_default_priority_victim_matches_pre_class_engine():
    """All-default priorities: the victim is the latest-arrived live
    request, exactly the pre-class policy."""
    _, model, params = _model()
    eng = ServeEngine(model, params, max_len=MAX_LEN, n_slots=2)
    rids = [eng.submit(c, 3) for c in _complexes((8, 5), seed=8)]
    eng.admit()
    assert eng.preempt() == rids[1]
