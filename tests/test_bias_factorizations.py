"""Exact decompositions (Table 1 row a): factors reproduce the dense bias."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: @given tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

import repro.core.bias as bias_mod
from repro.core.lowrank import IOModel, rank_for_energy, retained_energy


class TestALiBi:
    @pytest.mark.parametrize("heads", [1, 2, 8, 25, 50])  # incl. non-pow2
    def test_factors_match_dense(self, heads):
        n, m = 33, 47
        pq, pk = bias_mod.alibi_factors(n, m, heads)
        dense = bias_mod.alibi_dense(n, m, heads)
        recon = jnp.einsum("hnr,mr->hnm", pq, pk)
        np.testing.assert_allclose(recon, dense, atol=1e-4)

    def test_rank_is_two(self):
        pq, pk = bias_mod.alibi_factors(16, 16, 4)
        assert pq.shape[-1] == 2 and pk.shape[-1] == 2   # Example 3.4: R=2

    def test_offsets_shift_positions(self):
        """Decode-time factors: q at absolute position q_offset."""
        pq, pk = bias_mod.alibi_factors(1, 8, 2, q_offset=5)
        dense_full = bias_mod.alibi_dense(8, 8, 2)
        recon = jnp.einsum("hnr,mr->hnm", pq, pk)
        np.testing.assert_allclose(recon[:, 0], dense_full[:, 5], atol=1e-5)

    def test_slopes_geometric_pow2(self):
        s = bias_mod.alibi_slopes(8)
        ratios = s[1:] / s[:-1]
        np.testing.assert_allclose(ratios, ratios[0], rtol=1e-5)


class TestSqDist:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 4), st.integers(2, 9), st.integers(2, 9))
    def test_factors_match_dense(self, d, n, m):
        key = jax.random.PRNGKey(d * 100 + n * 10 + m)
        xq = jax.random.normal(key, (n, d))
        xk = jax.random.normal(jax.random.PRNGKey(1), (m, d))
        pq, pk = bias_mod.sqdist_factors(xq, xk, negate=False)
        assert pq.shape[-1] == 3 * d                     # Example 3.5: R=3d
        recon = pq @ pk.T
        dense = bias_mod.sqdist_dense(xq, xk, negate=False)
        np.testing.assert_allclose(recon, dense, atol=1e-4)

    def test_learnable_alpha_folds_into_phi_q(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (12, 3))
        alpha = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (12,)))
        pq, pk = bias_mod.scaled_sqdist_factors(x, x, alpha)
        dense = bias_mod.scaled_sqdist_dense(x, x, alpha)
        np.testing.assert_allclose(pq @ pk.T, dense, atol=1e-4)

    def test_alpha_gradient_flows_without_dense_matrix(self):
        """Table 5's point: grad wrt alpha exists through the factored form."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 3))

        def loss(alpha):
            pq, pk = bias_mod.scaled_sqdist_factors(x, x, alpha)
            return jnp.sum((pq @ pk.T) ** 2)

        g = jax.grad(loss)(jnp.ones((8,)))
        assert g.shape == (8,) and bool(jnp.all(jnp.isfinite(g)))


class TestBroadcastFactors:
    """(B, S, H, R) canonicalization (regression, ISSUE 7): a 3-D factor
    is ONLY per-head (H, S, R) — the old code transposed any 3-D tensor
    whose shape happened to broadcast, so a (B, S, R) batch factor with
    B == S was silently scrambled into nonsense."""

    def test_per_head_3d_requires_leading_heads(self):
        heads = 4
        phi = jax.random.normal(jax.random.PRNGKey(0), (heads, 6, 2))
        out = bias_mod.broadcast_factors(phi, batch=3, seq=6, heads=heads)
        assert out.shape == (3, 6, heads, 2)
        # head h of the input lands in the head axis, not the batch axis
        np.testing.assert_array_equal(out[0, :, 1], phi[1])
        np.testing.assert_array_equal(out[2], out[0])    # batch-broadcast

    def test_3d_with_wrong_leading_dim_raises(self):
        # the ambiguous case: B == S == 6 used to pass the broadcast and
        # transpose batch into heads silently
        phi = jax.random.normal(jax.random.PRNGKey(1), (6, 6, 2))
        with pytest.raises(ValueError, match="per-head"):
            bias_mod.broadcast_factors(phi, batch=6, seq=6, heads=4)

    def test_batch_factors_come_in_explicit_4d(self):
        phi = jax.random.normal(jax.random.PRNGKey(2), (6, 5, 1, 2))
        out = bias_mod.broadcast_factors(phi, batch=6, seq=5, heads=3)
        assert out.shape == (6, 5, 3, 2)
        np.testing.assert_array_equal(out[:, :, 2], phi[:, :, 0])

    def test_2d_shared_and_bad_rank(self):
        phi = jax.random.normal(jax.random.PRNGKey(3), (5, 2))
        out = bias_mod.broadcast_factors(phi, batch=2, seq=5, heads=3)
        assert out.shape == (2, 5, 3, 2)
        with pytest.raises(ValueError, match="rank"):
            bias_mod.broadcast_factors(phi[None, None, None], 1, 5, 3)


class TestMultiplicativeCos:
    def test_factors_match_dense(self):
        pq, pk = bias_mod.cos_relpos_factors(9, 13)
        dense = bias_mod.cos_relpos_dense(9, 13)
        np.testing.assert_allclose(pq @ pk.T, dense, atol=1e-5)


class TestLowRankTooling:
    def test_rank_for_energy_full_rank_matrix(self):
        m = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
        assert rank_for_energy(m, 1.0) == 16

    def test_rank_for_energy_exact_low_rank(self):
        u = jax.random.normal(jax.random.PRNGKey(0), (32, 3))
        v = jax.random.normal(jax.random.PRNGKey(1), (32, 3))
        assert rank_for_energy(u @ v.T, 0.999) <= 3

    def test_retained_energy_monotone(self):
        m = jax.random.normal(jax.random.PRNGKey(2), (24, 24))
        es = [retained_energy(m, r) for r in (1, 4, 8, 24)]
        assert es == sorted(es) and abs(es[-1] - 1.0) < 1e-5

    def test_rank_for_energy_zero_matrix_clamps_to_spectrum(self):
        """Regression (ISSUE 3): an all-zero matrix has a zero energy
        profile (every entry < energy), which used to yield min(N,M)+1 —
        a rank larger than any factorization of the matrix can have."""
        z = jnp.zeros((12, 7))
        assert rank_for_energy(z, 0.99) == 7
        assert rank_for_energy(jnp.zeros((3, 3)), 1.0) == 3
        # batched: a zero slice must not inflate past the spectrum either
        batched = jnp.stack([jnp.zeros((8, 8)),
                             jax.random.normal(jax.random.PRNGKey(3), (8, 8))])
        assert rank_for_energy(batched, 0.99) <= 8

    def test_retained_energy_rank_zero_and_overlong(self):
        """Regression (ISSUE 3): rank 0 used to index profile[-1] and
        report FULL energy for an empty factorization."""
        m = jax.random.normal(jax.random.PRNGKey(4), (6, 6))
        assert retained_energy(m, 0) == 0.0
        assert retained_energy(m, -1) == 0.0
        # ranks past the spectrum saturate at full energy, monotonically
        assert abs(retained_energy(m, 100) - 1.0) < 1e-5
        # zero matrix: profile is all zeros at every rank
        assert retained_energy(jnp.zeros((5, 9)), 3) == 0.0

    def test_io_model_example_3_9(self):
        """Example 3.9: C=R=64, S=100KB(half prec) -> ~6x fewer HBM accesses."""
        io = IOModel(n=65536, m=65536, c=64, rank=64, sram=100 * 1024 // 2)
        ratio = io.speedup_over_dense_bias()
        assert 5.0 < ratio < 7.0

    def test_multiplicative_worthwhile_threshold(self):
        """Cor. I.2: worthwhile iff R <= sqrt(S/C^2 + 1).

        NOTE: the paper's Example I.3 states R <= 27 for C=64, S=100KB, which
        does NOT follow from its own Cor. I.2 (sqrt(102400/4096 + 1) = 5.1);
        we implement and test the corollary's formula. Recorded in
        EXPERIMENTS.md §Paper-claims as a reproduction discrepancy.
        """
        sram_elems = 100 * 1024 // 2     # half precision
        thresh = int(np.sqrt(sram_elems / 64**2 + 1))
        ok = IOModel(1, 1, 64, thresh, sram_elems).multiplicative_worthwhile()
        bad = IOModel(1, 1, 64, thresh + 2,
                      sram_elems).multiplicative_worthwhile()
        assert ok and not bad
        # boundary respects the exact formula on both sides
        r_star = np.sqrt(sram_elems / 64**2 + 1)
        assert IOModel(1, 1, 64, int(np.floor(r_star)),
                       sram_elems).multiplicative_worthwhile()
        assert not IOModel(1, 1, 64, int(np.ceil(r_star)) + 1,
                           sram_elems).multiplicative_worthwhile()
