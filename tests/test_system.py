"""End-to-end behaviour: training reduces loss; serving is self-consistent;
the paper's three decomposition modes hold at the model level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import LMBatches, PDEBatches
from repro.models import get_model
from repro.models import pairformer as pf_mod
from repro.models import pde as pde_mod
from repro.models import swin as swin_mod
from repro.models.common import init_params, stack_layers
from repro.optim import AdamW, cosine
from repro.serve import ServeEngine
from repro.train import make_train_step


def test_lm_training_reduces_loss():
    cfg = smoke_config("codeqwen15_7b")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    opt = AdamW(lr_fn=cosine(3e-3, 5, 40))
    st = opt.init(params)
    data = LMBatches(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    step = make_train_step(model.loss, opt)
    losses = []
    for i in range(50):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:5])


def test_serve_greedy_matches_stepwise_prefill():
    """Engine's cached decode == re-prefilling from scratch every step."""
    cfg = smoke_config("stablelm_12b")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=48)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, 6)

    seq = jnp.asarray(prompts)
    for i in range(6):
        logits, _ = model.prefill(params, {"tokens": seq}, max_len=48)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(nxt[:, 0]), out[:, i])
        seq = jnp.concatenate([seq, nxt], axis=1)


def test_swin_svd_flashbias_inference_path():
    """Sec 4.3: full-rank SVD factors give the dense-table result exactly."""
    cfg = smoke_config("swinv2_b")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    patches = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.window, 48))
    dense = swin_mod.forward(params, patches, cfg.replace(bias_mode="dense"))
    f_full = swin_mod.svd_factorize(params, rank=cfg.window)
    fb = swin_mod.forward(params, patches, cfg, f_full)
    np.testing.assert_allclose(dense, fb, atol=1e-4)


def test_pde_flashbias_trains_and_matches_dense():
    cfg = smoke_config("pde_solver")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    data = PDEBatches(n_points=48, global_batch=2, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    # forward equality (exact decomposition)
    out_fb = pde_mod.forward(params, batch["coords"], cfg)
    out_d = pde_mod.forward(params, batch["coords"],
                            cfg.replace(bias_mode="dense"))
    np.testing.assert_allclose(out_fb, out_d, atol=1e-4)

    # gradient equality — Table 5's trainability claim
    g_fb = jax.grad(lambda p: pde_mod.regression_loss(p, batch, cfg))(params)
    g_d = jax.grad(lambda p: pde_mod.regression_loss(
        p, batch, cfg.replace(bias_mode="dense")))(params)
    for a, b in zip(jax.tree.leaves(g_fb), jax.tree.leaves(g_d)):
        np.testing.assert_allclose(a, b, atol=5e-4)

    # short training run reduces loss
    opt = AdamW(lr_fn=cosine(1e-2, 3, 30), weight_decay=0.0)
    st = opt.init(params)
    step = make_train_step(lambda p, b: pde_mod.regression_loss(p, b, cfg), opt)
    losses = []
    pdata = PDEBatches(n_points=48, global_batch=2, seed=1)
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in pdata.batch(i).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_pairformer_neural_decomposition_close_to_dense():
    """Sec 4.4: factor MLPs fitted with Eq. 5 approximate the pair bias well
    enough that model outputs barely move (paper: metric within noise)."""
    cfg = smoke_config("pairformer_lite")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    feats = jax.random.normal(jax.random.PRNGKey(1), (1, 20, 64))
    dense_out = pf_mod.forward(params, feats, cfg.replace(bias_mode="dense"))
    fp0 = init_params(stack_layers(pf_mod.factor_mlp_template(cfg, hidden=32),
                                   cfg.n_layers), jax.random.PRNGKey(2))
    fp, losses = pf_mod.fit_factor_mlps(jax.random.PRNGKey(3), params, fp0,
                                        feats, cfg, steps=80, lr=3e-3)
    assert losses[-1] < 0.5 * losses[0]            # Eq. 5 objective falls
    fb_out = pf_mod.forward(params, feats, cfg, fp)
    # output drift bounded (scale of outputs ~1e-1)
    assert float(jnp.abs(fb_out - dense_out).max()) < 0.05
