"""Sharding rules + a miniature multi-device dry-run (subprocess: the device
count must be fixed before jax initializes, so it cannot run in-process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import Rules, batch_axes_for, spec_for

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class TestRules:
    def _mesh2d(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_spec_basic(self):
        mesh = self._mesh2d()
        r = Rules()
        assert spec_for(("fsdp", "heads", None), mesh, r) == \
            P(("data",), ("model",), None)

    def test_missing_mesh_axes_dropped(self):
        """Same rules drive single- and multi-pod meshes: 'pod' vanishes."""
        mesh = self._mesh2d()           # no 'pod' axis
        r = Rules()
        assert spec_for(("batch",), mesh, r) == P(("data",))

    def test_overrides(self):
        mesh = self._mesh2d()
        r = Rules.make({"seq": ("model",)})   # sequence parallelism
        assert spec_for((None, "seq", None), mesh, r) == P(None, ("model",), None)

    def test_batch_axes_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        r = Rules()
        # batch=1 cannot shard over data -> replicated, never an error
        assert batch_axes_for(1, mesh, r) == P(None) or \
            batch_axes_for(1, mesh, r)[0] is not None


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.dist import Rules, use_mesh_rules
    from repro.models import get_model
    from repro.models.common import abstract_params, param_shardings
    from repro.optim import AdamW, constant

    arch = sys.argv[1]
    cfg = smoke_config(arch).replace(tp=2)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = Rules()
    model = get_model(cfg)
    tmpl = model.template()
    aparams = abstract_params(tmpl)
    pshard = param_shardings(tmpl, mesh, rules)
    opt = AdamW(lr_fn=constant(1e-3))
    aopt = jax.eval_shape(opt.init, aparams)
    import jax.tree_util as jtu
    oshard = jtu.tree_map(lambda _: NamedSharding(mesh, P()), aopt)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jax.numpy.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), jax.numpy.int32),
    }
    bshard = {
        "tokens": NamedSharding(mesh, P(("pod", "data"), None)),
        "labels": NamedSharding(mesh, P(("pod", "data"), None)),
    }
    with use_mesh_rules(mesh, rules):
        jf = jax.jit(train_step, in_shardings=(pshard, oshard, bshard))
        compiled = jf.lower(aparams, aopt, batch).compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    txt = compiled.as_text()
    has_coll = any(op in txt for op in
                   ("all-reduce", "all-gather", "reduce-scatter"))
    print(json.dumps({"ok": True, "flops": cost.get("flops", 0),
                      "has_collectives": has_coll}))
""")


@pytest.mark.parametrize("arch", ["codeqwen15_7b", "granite_moe_3b_a800m",
                                  "mamba2_130m", "hymba_15b"])
def test_mini_multipod_lowering(arch):
    """A (2,2,2) pod x data x model mesh lowers + compiles a train step for
    every family, and the partitioned module contains real collectives."""
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN, arch],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["has_collectives"]
