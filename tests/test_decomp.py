"""SVD + neural decompositions (Table 1 rows b, c)."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.decomp as dc
from repro.core.bias import sqdist_dense


class TestSVD:
    def test_full_rank_is_exact(self):
        t = jax.random.normal(jax.random.PRNGKey(0), (3, 24, 24))
        pq, pk = dc.svd_factors(t, rank=24)
        assert dc.reconstruction_error(t, pq, pk) < 1e-5

    def test_truncation_is_eckart_young_optimal(self):
        """Rank-r SVD error == sqrt(sum of discarded sigma^2)."""
        t = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
        s = jnp.linalg.svd(t, compute_uv=False)
        r = 4
        pq, pk = dc.svd_factors(t, rank=r)
        want = float(jnp.sqrt((s[r:] ** 2).sum()) / jnp.linalg.norm(t))
        got = dc.reconstruction_error(t, pq, pk)
        np.testing.assert_allclose(got, want, rtol=1e-4)

    def test_energy_rank_selection(self):
        u = jax.random.normal(jax.random.PRNGKey(2), (32, 5))
        t = u @ u.T          # exact rank 5
        pq, pk = dc.svd_factors(t, rank=None, energy=0.999)
        assert pq.shape[-1] <= 5
        assert dc.reconstruction_error(t, pq, pk) < 1e-3

    def test_per_head_batched(self):
        t = jax.random.normal(jax.random.PRNGKey(3), (4, 12, 12))
        pq, pk = dc.svd_factors(t, rank=12)
        assert pq.shape == (4, 12, 12) and pk.shape == (4, 12, 12)


class TestNeuralDecomposition:
    def test_fit_recovers_low_rank_bias(self):
        """Eq. 5 training drives reconstruction loss down on sqdist bias
        (App. G-style target)."""
        key = jax.random.PRNGKey(0)
        params = dc.neural_decomp_init(key, 2, 2, hidden=32, heads=1, rank=8)

        def sample(k):
            xq = jax.random.uniform(k, (24, 2))
            target = sqdist_dense(xq, xq)[None]      # (1, N, N)
            return xq, xq, target

        fitted, losses = dc.fit_neural_decomposition(
            key, params, sample, steps=150, lr=3e-3)
        assert float(losses[-1]) < 0.3 * float(losses[:10].mean())

    def test_factors_are_tokenwise(self):
        """Remark 3.6: phi depends only on its own token's features — a
        permutation of inputs permutes outputs identically."""
        key = jax.random.PRNGKey(1)
        params = dc.neural_decomp_init(key, 3, 3, hidden=16, heads=2, rank=4)
        x = jax.random.normal(key, (10, 3))
        pq, _ = dc.neural_decomp_apply(params, x, x)
        perm = jnp.array([3, 1, 4, 0, 2, 9, 8, 7, 5, 6])
        pq_p, _ = dc.neural_decomp_apply(params, x[perm], x[perm])
        np.testing.assert_allclose(pq[perm], pq_p, atol=1e-6)
