"""Chunked prefill + streaming serve (ISSUE 7).

The chunked-prefill contract under test: admission becomes a host-side
planner (``ChunkPlan``) and prompts land ``prefill_chunk`` tokens per
engine step, interleaved with decode — and NOTHING observable changes.
Greedy and sampled outputs are bit-identical to the whole-prompt engine
(the PRNG chain is armed at plan time and only advances for decoding
slots), for contiguous, paged (factors ride ``pages_phi``) and ring-KV
(chunk clamped to the window, prefill wrapping the ring) cache families.

Streaming rides the same PR: ``submit(on_token=...)`` delivers each
emitted id as it is committed (token backends) or drains the per-step
state (pair backend), and the callback lives on the request descriptor so
preemption/resume keeps the stream attached.

Priority classes x paged preemption x chunked prefill: a lowest-class
victim caught MID-CHUNK returns its original request whole (zero tokens
generated -> nothing folded into the resumed prompt, partial chunk writes
are dead because the slot's committed length is still 0), its pages drain
back to the pool, and the resumed run is bit-identical to the
never-preempted engine.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import SamplingParams, ServeEngine
from repro.serve.scheduler import ChunkPlan, Request


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _traffic(engine, prompts, budget=6, temp=0.0, streams=None):
    """Staggered arrivals: half queue up front, the rest join one per
    step while earlier requests are mid-decode/mid-chunk."""
    rids = []

    def submit(i):
        cb = None if streams is None else streams.setdefault(i, []).append
        rids.append(engine.submit(
            prompts[i], budget,
            sampling=SamplingParams(temp, 0, seed=i), on_token=cb))

    for i in range(len(prompts) // 2):
        submit(i)
    i = len(prompts) // 2
    while len(engine.scheduler) or engine.occupancy or i < len(prompts):
        if i < len(prompts):
            submit(i)
            i += 1
        engine.step()
    return [engine.result(r) for r in rids]


# ---------------------------------------------------------------------------
# ChunkPlan: the host-side prompt cursor
# ---------------------------------------------------------------------------

def test_chunk_plan_walks_the_prompt():
    req = Request(0, np.arange(11, dtype=np.int32), 4)
    plan = ChunkPlan(req)
    assert plan.remaining == 11
    off, toks, last = plan.next_chunk(4)
    assert (off, last) == (0, False) and (toks == np.arange(4)).all()
    off, toks, last = plan.next_chunk(4)
    assert (off, last) == (4, False) and (toks == np.arange(4, 8)).all()
    off, toks, last = plan.next_chunk(4)        # ragged final chunk
    assert (off, last) == (8, True) and (toks == np.arange(8, 11)).all()
    assert plan.remaining == 0


# ---------------------------------------------------------------------------
# Bit-identity: chunked engine == whole-prompt engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,paged", [
    ("stablelm_12b", False),          # full-KV contiguous
    ("stablelm_12b", True),           # paged pools + page tables
    ("gpt2_alibi_15b", True),         # ALiBi factors ride pages_phi
])
def test_chunked_matches_whole_prompt_engine(arch, paged):
    cfg, model, params = _model(arch)
    kw = {"max_len": 48, "n_slots": 3}
    if paged:
        kw.update(page_size=4, pages_per_slot=12)
    prompts = _prompts(cfg, (13, 6, 17, 9, 5), seed=2)
    whole = _traffic(ServeEngine(model, params, **kw), prompts)
    streams = {}
    chunked = _traffic(
        ServeEngine(model, params, prefill_chunk=5, **kw), prompts,
        streams=streams)
    for i, (a, b) in enumerate(zip(whole, chunked)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
        # streaming delivered every committed token, in order
        np.testing.assert_array_equal(np.asarray(streams[i], np.int32), b)


def test_chunked_ring_kv_prompt_longer_than_window():
    """Ring-KV family (hymba: sliding-window ring + SSM state): the chunk
    is clamped to the window and a prompt LONGER than the window must
    wrap the ring mid-prefill exactly like the whole-prompt path."""
    cfg, model, params = _model("hymba_15b")
    assert cfg.window and cfg.window < 48     # ring is actually engaged
    kw = {"max_len": 48, "n_slots": 2}
    prompts = _prompts(cfg, (36, 10, 21), seed=5)   # 36 > window
    whole = _traffic(ServeEngine(model, params, **kw), prompts)
    chunked = _traffic(
        ServeEngine(model, params, prefill_chunk=8, **kw), prompts)
    for i, (a, b) in enumerate(zip(whole, chunked)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


@pytest.mark.parametrize("paged", [False, True])
def test_chunked_sampled_prng_chain_parity(paged):
    """Sampled decode: keys are armed at PLAN time and committed only for
    decoding slots, so the per-request PRNG chain advances identically
    whether the prompt landed whole or in chunks."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 48, "n_slots": 3}
    if paged:
        kw.update(page_size=4, pages_per_slot=12)
    prompts = _prompts(cfg, (12, 7, 15, 6), seed=3)
    whole = _traffic(ServeEngine(model, params, **kw), prompts, temp=0.8)
    chunked = _traffic(
        ServeEngine(model, params, prefill_chunk=4, **kw), prompts,
        temp=0.8)
    for i, (a, b) in enumerate(zip(whole, chunked)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


def test_unchunked_backend_reports_no_pending():
    """prefill_chunk=None keeps the legacy whole-prompt admission: no
    chunk planner, no pending slots, no prefill_step in the engine loop."""
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=32, n_slots=2)
    eng.submit(_prompts(cfg, (9,))[0], 4)
    eng.step()
    assert not eng.backend.prefill_pending()
    assert not list(eng.backend.pending_slots())
    eng.run()


# ---------------------------------------------------------------------------
# Priority classes x paged preemption x chunked prefill (mid-chunk victim)
# ---------------------------------------------------------------------------

def _priority_run(model, params, cfg, preempt_mid_chunk):
    """High-class request decoding, low-class long prompt admitted and
    caught mid-chunk; optionally preempt (default victim) right there."""
    eng = ServeEngine(model, params, max_len=48, n_slots=2,
                      prefill_chunk=4, page_size=4, pages_per_slot=12)
    p_hi, p_lo = _prompts(cfg, (6, 18), seed=9)
    streams = {"lo": []}
    r_hi = eng.submit(p_hi, 10, priority=1)
    for _ in range(3):                 # 2 chunks to land + 1 decode step
        eng.step()
    r_lo = eng.submit(p_lo, 6, priority=0,
                      on_token=streams["lo"].append)
    eng.step()                         # admit + first chunk, hi decodes
    if preempt_mid_chunk:
        (slot, plan), = eng.backend._pending.items()
        assert plan.req.rid == r_lo
        assert 0 < plan.done < plan.req.tokens.size      # mid-chunk
        assert eng.preempt() == r_lo   # lowest class wins the eviction
        assert eng.n_preemptions == 1
        # the victim generated nothing: its snapshot is the ORIGINAL
        # request, whole — nothing folded, full budget intact
        resumed = eng.scheduler.peek()
        assert resumed.rid == r_lo and resumed.max_new_tokens == 6
        np.testing.assert_array_equal(resumed.tokens, p_lo)
    eng.run()
    assert eng._pool.n_free == eng.n_pages               # pages drained
    return eng.result(r_hi), eng.result(r_lo), streams["lo"]


def test_mid_chunk_preemption_resumes_bit_identical():
    cfg, model, params = _model("stablelm_12b")
    hi0, lo0, _ = _priority_run(model, params, cfg, preempt_mid_chunk=False)
    hi1, lo1, stream = _priority_run(model, params, cfg,
                                     preempt_mid_chunk=True)
    np.testing.assert_array_equal(hi0, hi1)
    np.testing.assert_array_equal(lo0, lo1)
    # the stream callback rode the descriptor through preemption: the
    # resumed request delivered every token exactly once
    np.testing.assert_array_equal(np.asarray(stream, np.int32), lo1)
