"""Units for the statcheck static-analysis subsystem.

Three speed tiers: pure synthetic-jaxpr walker units (ms), AST-lint units
on inline snippets (ms), and real-backend contract checks (the legacy
tripwire, seconds) plus one subprocess mesh check (the device count must
be fixed before jax initializes, mirroring tests/test_sharding.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.statcheck.hostlint import lint_file, lint_tree
from repro.statcheck.jaxpr_rules import (
    count_primitive,
    eq3_fold_present,
    no_host_callback,
    no_pool_relayout,
    pool_threshold_for,
    walk_eqns,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- jaxpr rules

class TestWalkEqns:
    def test_descends_into_scan_body(self):
        def f(xs):
            def body(c, x):
                return c, (x * 2.0).T
            return jax.lax.scan(body, 0.0, xs)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((3, 8, 16)))
        names = {e.primitive.name for e in walk_eqns(jaxpr)}
        # the transpose lives only inside the scan body
        assert "scan" in names and "transpose" in names

    def test_count_primitive_with_size_floor(self):
        def f(a, b):
            return a.T, b.T

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((64, 64)), jnp.zeros((2, 2)))
        assert count_primitive(jaxpr, "transpose") == 2
        assert count_primitive(jaxpr, "transpose",
                               min_operand_size=1000) == 1


class TestNoPoolRelayout:
    def test_flags_pool_sized_transpose(self):
        jaxpr = jax.make_jaxpr(lambda x: x.T)(jnp.zeros((64, 64)))
        found = no_pool_relayout(jaxpr, 4096, program="t")
        assert len(found) == 1
        f = found[0]
        assert f.rule == "no-pool-relayout" and "transpose" in f.eqn

    def test_flags_inside_scan(self):
        """The legacy to_pool transpose lives inside the layer scan — the
        rule must see through it."""
        def f(xs):
            def body(c, x):
                return c, jnp.transpose(x, (1, 0, 2))
            return jax.lax.scan(body, 0.0, xs)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((2, 32, 8, 16)))
        assert no_pool_relayout(jaxpr, 32 * 8 * 16, program="t")

    def test_passes_token_sized_transpose(self):
        jaxpr = jax.make_jaxpr(lambda x: x.T)(jnp.zeros((4, 8)))
        assert no_pool_relayout(jaxpr, 4096, program="t") == []

    def test_flags_pool_sized_broadcast_and_convert(self):
        def f(x):
            y = jnp.broadcast_to(x[:, None], (64, 2, 64))
            return y.astype(jnp.bfloat16)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((64, 64)))
        rules_hit = {f.eqn.split(" ")[0]
                     for f in no_pool_relayout(jaxpr, 4096, program="t")}
        assert "broadcast_in_dim" in rules_hit
        assert "convert_element_type" in rules_hit


class TestNoHostCallback:
    def test_flags_pure_callback(self):
        def f(x):
            return jax.pure_callback(
                lambda v: v, jax.ShapeDtypeStruct((4,), jnp.float32), x)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,)))
        found = no_host_callback(jaxpr, program="t")
        assert found and found[0].rule == "no-host-callback"

    def test_clean_program_passes(self):
        jaxpr = jax.make_jaxpr(lambda x: x * 2)(jnp.zeros((4,)))
        assert no_host_callback(jaxpr, program="t") == []


class TestEq3Fold:
    def test_fold_concat_detected(self):
        def f(q, phi):
            return jnp.concatenate([q, phi], axis=-1)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((2, 4, 32)),
                                  jnp.zeros((2, 4, 8)))
        assert eq3_fold_present(jaxpr, 32, 8, program="t") == []

    def test_missing_fold_flagged(self):
        jaxpr = jax.make_jaxpr(lambda q: q @ q.T)(jnp.zeros((4, 32)))
        found = eq3_fold_present(jaxpr, 32, 8, program="t")
        assert found and found[0].rule == "eq3-fold"

    def test_wrong_width_concat_not_mistaken_for_fold(self):
        def f(q, phi):
            return jnp.concatenate([q, phi], axis=-1)

        jaxpr = jax.make_jaxpr(f)(jnp.zeros((2, 4, 32)),
                                  jnp.zeros((2, 4, 4)))   # rank 4, not 8
        assert eq3_fold_present(jaxpr, 32, 8, program="t")


class TestPoolThreshold:
    def test_kv_leaves_per_layer(self):
        cache = {"pages_k": jnp.zeros((2, 32, 4, 2, 40)),
                 "pages_v": jnp.zeros((2, 32, 4, 2, 40)),
                 "length": jnp.zeros((4,), jnp.int32)}
        assert pool_threshold_for(cache, 2) == 32 * 4 * 2 * 40

    def test_ssm_fallback(self):
        cache = {"ssm_h": jnp.zeros((2, 4, 8, 16)),
                 "length": jnp.zeros((4,), jnp.int32)}
        assert pool_threshold_for(cache, 2) == 4 * 8 * 16

    def test_none_when_nothing_pool_shaped(self):
        assert pool_threshold_for(
            {"length": jnp.zeros((4,), jnp.int32)}, 2) is None


# ------------------------------------------------------------- contracts

class TestContracts:
    def test_kernel_layout_clean(self):
        from repro.statcheck.contracts import check_family
        assert check_family("dense") == []

    def test_legacy_tripwire_fires(self):
        """The built-in negative test: cache_layout='legacy' must trip the
        decode-step transpose rule (the per-layer to_pool adapter)."""
        from repro.statcheck.contracts import verify_tripwire
        assert verify_tripwire() == []   # empty = the tripwire DID fire


# -------------------------------------------------------------- hostlint

def _lint_src(tmp_path, source, **roles):
    p = tmp_path / "mod.py"
    p.write_text(textwrap.dedent(source))
    return lint_file(str(p), **roles)


class TestHostJnp:
    def test_flags_jax_import_and_use(self, tmp_path):
        found = _lint_src(tmp_path, """
            import jax.numpy as jnp
            def free(pages):
                return jnp.sum(pages)
            """, host=True)
        assert {f.rule for f in found} == {"host-jnp"}
        assert len(found) == 2      # the import and the use

    def test_suppression_comment(self, tmp_path):
        found = _lint_src(tmp_path, """
            import jax  # statcheck: allow(host-jnp)
            """, host=True)
        assert found == []

    def test_numpy_is_fine(self, tmp_path):
        found = _lint_src(tmp_path, """
            import numpy as np
            def free(pages):
                return np.sum(pages)
            """, host=True)
        assert found == []


class TestHostAssert:
    def test_flags_bare_assert(self, tmp_path):
        found = _lint_src(tmp_path, """
            def free(pages, refs):
                assert refs[pages[0]] > 0, "double free"
                refs[pages[0]] -= 1
            """, host=True)
        assert [f.rule for f in found] == ["host-assert"]
        assert "python -O" in found[0].message

    def test_suppression_comment(self, tmp_path):
        found = _lint_src(tmp_path, """
            def free(pages, refs):
                assert refs[pages[0]] > 0  # statcheck: allow(host-assert)
            """, host=True)
        assert found == []

    def test_typed_raise_is_fine(self, tmp_path):
        found = _lint_src(tmp_path, """
            def free(pages, refs):
                if refs[pages[0]] <= 0:
                    raise PoolError("double free")
            """, host=True)
        assert found == []

    def test_serve_only_module_exempt(self, tmp_path):
        # backend/sampling are serve (device code allowed) but not HOST:
        # jitted-side asserts there are trace-time shape checks, not
        # runtime accounting
        found = _lint_src(tmp_path, """
            def validate(x):
                assert x.ndim == 2
            """, serve=True)
        assert found == []


class TestHostSync:
    def test_flags_block_until_ready(self, tmp_path):
        found = _lint_src(tmp_path, """
            def step(self):
                self.logits.block_until_ready()
            """, serve=True)
        assert found and found[0].rule == "host-sync"

    def test_flags_asarray_on_device_state_in_loop(self, tmp_path):
        found = _lint_src(tmp_path, """
            import numpy as np
            def drain(self):
                out = []
                for _ in range(8):
                    out.append(np.asarray(self._cache["length"]))
                return out
            """, serve=True)
        assert found and found[0].rule == "host-sync"

    def test_asarray_outside_loop_passes(self, tmp_path):
        found = _lint_src(tmp_path, """
            import numpy as np
            def snapshot(self):
                return np.asarray(self._cache["length"])
            """, serve=True)
        assert found == []


class TestBlockspecBounds:
    def test_unclamped_index_map_flagged(self, tmp_path):
        found = _lint_src(tmp_path, """
            def make(n_pages):
                def m(b, j, pt_ref):
                    return (b, pt_ref[b, j], 0, 0)
                return m
            """, kernel=True)
        assert found and found[0].rule == "blockspec-bounds"

    def test_clamped_index_map_passes(self, tmp_path):
        found = _lint_src(tmp_path, """
            import jax.numpy as jnp
            def make(n_pages):
                def m(b, j, pt_ref):
                    return (b, jnp.clip(pt_ref[b, j], 0, n_pages - 1), 0, 0)
                return m
            """, kernel=True)
        assert found == []

    def test_kernel_body_exempt(self, tmp_path):
        # kernel bodies subscript refs but never return index tuples
        found = _lint_src(tmp_path, """
            def kernel(q_ref, o_ref):
                o_ref[...] = q_ref[...] * 2.0
            """, kernel=True)
        assert found == []


def test_repo_tree_is_lint_clean():
    """The satellite 'fix any host-path violations the lint finds' holds
    by construction: the live tree has zero findings."""
    assert lint_tree(REPO) == []


# ------------------------------------------------------------ mesh rules

class TestMeshRuleUnits:
    def test_check_collectives_text_rules(self):
        from repro.statcheck.mesh_rules import check_collectives
        good = "fusion all-reduce f32 all-gather"
        assert check_collectives(good, program="t") == []
        assert check_collectives("fusion add", program="t")  # none present
        assert check_collectives(good, program="t",
                                 expect_all=("reduce-scatter",))
        bad = check_collectives(good, program="t", forbid=("all-gather",))
        assert bad and bad[0].rule == "mesh-collectives"

    def test_state_axes_vocab_typo_flagged(self):
        from repro.dist.sharding import Rules
        from repro.statcheck.mesh_rules import check_state_axes
        rules = Rules()
        ok = {"pages_k": (None, None, None, "kv_heads", None)}
        assert check_state_axes(ok, rules, program="t") == []
        typo = {"pages_k": (None, None, None, "kv_head", None)}
        found = check_state_axes(typo, rules, program="t")
        assert found and found[0].rule == "state-axes-vocab"
        assert "kv_head" in found[0].message


MESH_CHECK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys, json
    sys.path.insert(0, "src")
    import jax
    from repro.configs import smoke_config
    from repro.dist.sharding import Rules
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve.backend import TokenDecodeBackend
    from repro.statcheck.mesh_rules import (check_backend_mesh,
                                            check_shard_divisibility)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = smoke_config("stablelm_12b").replace(attn_impl="pallas_interpret")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    be = TokenDecodeBackend(model, params, max_len=32, n_slots=4,
                            page_size=4, mesh=mesh, rules=Rules())
    clean = check_backend_mesh(be, program="dense/decode@(2,2)")

    # negative: a 3-wide dim mapped to a 2-wide mesh axis must be reported
    degrade = check_shard_divisibility(
        {"x": (3, 8)}, {"x": ("kv_heads", None)}, mesh, Rules(),
        program="t", allow=())
    print(json.dumps({"clean": [str(f) for f in clean],
                      "degrade_rules": [f.rule for f in degrade]}))
""")


def test_mesh_collectives_on_2x2_host_mesh():
    """check_backend_mesh passes on a real (2,2)-sharded dense backend and
    the divisibility audit fires on a non-divisible leaf (subprocess: the
    forced device count must precede jax init)."""
    out = subprocess.run(
        [sys.executable, "-c", MESH_CHECK],
        capture_output=True, text=True, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["clean"] == []
    assert rec["degrade_rules"] == ["shard-divisibility"]
