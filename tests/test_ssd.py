"""Mamba2 SSD: chunked scan == naive per-token recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade: @given tests skip, example tests still run
    from _hypothesis_fallback import given, settings, st

from repro.models import ssd


def naive_ssm(x, dt, a, b, c, h0=None):
    """Token-by-token oracle: h = h*exp(dt a) + dt B x; y = C . h."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    hstate = jnp.zeros((bsz, h, p, n)) if h0 is None else h0
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])                     # (B,H)
        hstate = (hstate * da[:, :, None, None]
                  + jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], b[:, t]))
        ys.append(jnp.einsum("bhpn,bn->bhp", hstate, c[:, t]))
    return jnp.stack(ys, 1), hstate


def _mk(key, bsz, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, s, n))
    c = jax.random.normal(ks[4], (bsz, s, n))
    return x, dt, a, b, c


@pytest.mark.parametrize("s,chunk", [(16, 4), (17, 4), (32, 8), (7, 16)])
def test_chunked_scan_matches_naive(s, chunk):
    x, dt, a, b, c = _mk(jax.random.PRNGKey(0), 2, s, 3, 4, 5)
    y, hf = ssd.ssd_scan(x, dt, a, b, c, chunk=chunk)
    y_ref, h_ref = naive_ssm(x, dt, a, b, c)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)
    np.testing.assert_allclose(hf, h_ref, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 20), chunk=st.sampled_from([2, 4, 8]))
def test_property_chunk_invariance(s, chunk):
    """Output must not depend on the chunk size (pure reformulation)."""
    x, dt, a, b, c = _mk(jax.random.PRNGKey(s), 1, s, 2, 3, 4)
    y1, h1 = ssd.ssd_scan(x, dt, a, b, c, chunk=chunk)
    y2, h2 = ssd.ssd_scan(x, dt, a, b, c, chunk=s)
    np.testing.assert_allclose(y1, y2, atol=1e-4)
    np.testing.assert_allclose(h1, h2, atol=1e-4)


def test_decode_step_continues_scan():
    """prefill-then-decode == full scan (the serving contract)."""
    x, dt, a, b, c = _mk(jax.random.PRNGKey(1), 2, 12, 2, 4, 3)
    y_full, h_full = ssd.ssd_scan(x, dt, a, b, c, chunk=4)
    y_pre, h_pre = ssd.ssd_scan(x[:, :11], dt[:, :11], a, b[:, :11],
                                c[:, :11], chunk=4)
    y_last, h_last = ssd.ssd_decode_step(h_pre, x[:, 11], dt[:, 11], a,
                                         b[:, 11], c[:, 11])
    np.testing.assert_allclose(y_last, y_full[:, 11], atol=1e-4)
    np.testing.assert_allclose(h_last, h_full, atol=1e-4)


def test_initial_state_threading():
    x, dt, a, b, c = _mk(jax.random.PRNGKey(2), 1, 8, 2, 3, 4)
    _, h_mid = ssd.ssd_scan(x[:, :4], dt[:, :4], a, b[:, :4], c[:, :4],
                            chunk=2)
    y2, h_end = ssd.ssd_scan(x[:, 4:], dt[:, 4:], a, b[:, 4:], c[:, 4:],
                             chunk=2, h0=h_mid)
    y_full, h_full = ssd.ssd_scan(x, dt, a, b, c, chunk=2)
    np.testing.assert_allclose(y2, y_full[:, 4:], atol=1e-4)
    np.testing.assert_allclose(h_end, h_full, atol=1e-4)
