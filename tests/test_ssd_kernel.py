"""SSD Pallas kernel (interpret mode) vs the pure-jnp ssd_scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_scan_fwd
from repro.models import ssd


def _mk(key, bsz, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bsz, s, n))
    c = jax.random.normal(ks[4], (bsz, s, n))
    return x, dt, a, b, c


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(s, chunk, dtype):
    x, dt, a, b, c = _mk(jax.random.PRNGKey(0), 2, s, 3, 8, 4)
    # compare against the oracle on the SAME quantized inputs, so bf16 cases
    # measure kernel error rather than input-quantization error
    xq = x.astype(dtype).astype(jnp.float32)
    dtq = dt.astype(dtype).astype(jnp.float32)
    bq = b.astype(dtype).astype(jnp.float32)
    cq = c.astype(dtype).astype(jnp.float32)
    y_ref, _ = ssd.ssd_scan(xq, dtq, a, bq, cq, chunk=chunk)

    y_k = ssd_scan_fwd(
        x.transpose(0, 2, 1, 3).astype(dtype),       # (B,H,S,P)
        dt.transpose(0, 2, 1)[..., None].astype(dtype),
        a[:, None],
        b[:, None].astype(dtype),                    # (B,1,S,N)
        c[:, None].astype(dtype),
        chunk=chunk, interpret=True)
    y_k = y_k.transpose(0, 2, 1, 3)                  # back to (B,S,H,P)
    if dtype == jnp.bfloat16:
        # the kernel also WRITES y in bf16: quantize the oracle identically
        # so the comparison measures kernel error, not output rounding
        y_ref = y_ref.astype(jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=3e-2, atol=3e-2)
    else:
        np.testing.assert_allclose(np.asarray(y_k, np.float32),
                                   np.asarray(y_ref, np.float32), atol=2e-4)


def test_kernel_state_carries_across_chunks():
    """With multiple chunks the kernel's scratch state must thread exactly
    like the oracle's lax.scan carry (position > chunk sees history)."""
    x, dt, a, b, c = _mk(jax.random.PRNGKey(1), 1, 24, 2, 4, 3)
    y_ref, _ = ssd.ssd_scan(x, dt, a, b, c, chunk=8)
    y_k = ssd_scan_fwd(x.transpose(0, 2, 1, 3),
                       dt.transpose(0, 2, 1)[..., None],
                       a[:, None], b[:, None], c[:, None],
                       chunk=8, interpret=True).transpose(0, 2, 1, 3)
    # the last chunk depends on the full 24-token history
    np.testing.assert_allclose(y_k[:, -8:], y_ref[:, -8:], atol=2e-4)
