"""Prefix caching: content-hashed page sharing + copy-on-write (ISSUE 9).

The contract under test: with ``prefix_cache=True`` a request whose prompt
starts with an already-served prefix maps its page table onto the existing
pages (``PagePool.incref``) and prefills only the novel tail — and NOTHING
observable changes. Greedy and sampled outputs are bit-identical to the
unshared engine, including under eviction pressure and preemption/resume
of a request that is actively sharing pages.

Mechanics pinned here:

- ``PagePool`` refcounts: free is a decref, a page drains only at zero,
  double free and incref-of-free stay loud errors.
- ``PrefixCache``: hash-chain match/insert over full token blocks, LRU
  leaf-first eviction, and hash-collision safety — a colliding digest is
  rejected by the full token-block compare, never served.
- Retirement RETAINS the prompt's full pages in the index (refcount 1,
  evictable) — the vLLM-style cache-past-retirement behavior.
- Copy-on-write: a sharer that must re-run the span ``[done, matched)``
  (chunk-boundary alignment) copies those pages before writing.
- ``submit`` footprint errors account for shared-prefix hits while still
  matching the ``paged mode.*page-table`` shape tests/test_lazy_pages.py
  pins.
"""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import PrefixCache, SamplingParams, ServeEngine
from repro.serve.lifecycle import AdmissionRejected, PoolError
from repro.serve.pages import PagePool


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_prompts(cfg, common_len, tails, seed=11):
    rng = np.random.RandomState(seed)
    common = rng.randint(0, cfg.vocab, (common_len,)).astype(np.int32)
    return [np.concatenate([common, rng.randint(
        0, cfg.vocab, (n,)).astype(np.int32)]) for n in tails]


def _run(model, params, prompts, *, prefix_cache, n_pages=None, budget=14,
         temp=0.0, n_slots=2):
    kw = {} if n_pages is None else {"n_pages": n_pages}
    eng = ServeEngine(model, params, max_len=64, n_slots=n_slots,
                      page_size=4, pages_per_slot=16, prefill_chunk=4,
                      prefix_cache=prefix_cache, **kw)
    rids = [eng.submit(p, budget, sampling=SamplingParams(temp, 0, seed=i))
            for i, p in enumerate(prompts)]
    eng.run()
    return [eng.result(r) for r in rids], eng


# ---------------------------------------------------------------------------
# PagePool refcounts
# ---------------------------------------------------------------------------

def test_pool_refcount_units():
    pool = PagePool(4, page_size=4)
    a, b = pool.alloc(2)
    assert pool.refcount(a) == 1 and pool.refcount(b) == 1
    pool.incref([a])
    assert pool.refcount(a) == 2
    assert pool.free([a]) == []            # decref: still one holder
    assert pool.n_free == 2
    assert pool.free([a, b]) == [a, b]     # last holders -> both drain
    assert pool.n_free == 4


def test_pool_double_free_rejected():
    pool = PagePool(2, page_size=4)
    (p,) = pool.alloc(1)
    pool.free([p])
    with pytest.raises(PoolError, match="double free"):
        pool.free([p])


def test_pool_incref_of_free_page_rejected():
    pool = PagePool(2, page_size=4)
    with pytest.raises(PoolError, match="incref of free"):
        pool.incref([0])


# ---------------------------------------------------------------------------
# PrefixCache index
# ---------------------------------------------------------------------------

def test_index_match_walks_full_blocks_only():
    pool = PagePool(8, page_size=4)
    idx = PrefixCache(4)
    toks = np.arange(10, dtype=np.int32)        # 2 full blocks + partial
    pages = pool.alloc(3)
    assert idx.insert(toks, pages, pool) == 2   # partial page never indexed
    hit, matched = idx.match(toks)
    assert (hit, matched) == (pages[:2], 8)
    # a diverging second block matches only the first
    other = np.concatenate([toks[:4], toks[4:8][::-1]])
    hit, matched = idx.match(other)
    assert (hit, matched) == (pages[:1], 4)
    assert pool.refcount(pages[0]) == 2         # request + index
    assert pool.refcount(pages[2]) == 1         # partial page: request only


def test_index_rejects_hash_collisions_by_block_compare():
    """A degenerate digest maps EVERY block to one key: without the full
    token-block compare the index would serve page content for the wrong
    tokens. The compare must reject the hit (and count it)."""
    pool = PagePool(8, page_size=4)
    idx = PrefixCache(4, digest=lambda parent, block: b"collide")
    toks_a = np.arange(4, dtype=np.int32)
    toks_b = np.arange(4, 8, dtype=np.int32)
    idx.insert(toks_a, pool.alloc(1), pool)
    hit, matched = idx.match(toks_b)            # same key, different tokens
    assert (hit, matched) == ([], 0)
    assert idx.n_rejected == 1
    assert idx.match(toks_a)[1] == 4            # the real tokens still hit


def test_index_eviction_is_lru_leaf_first():
    pool = PagePool(8, page_size=2)
    idx = PrefixCache(2)
    toks = np.arange(6, dtype=np.int32)         # chain of 3 entries
    pages = pool.alloc(3)
    idx.insert(toks, pages, pool)
    pool.free(pages)                            # request retires: index-only
    assert idx.n_evictable(pool) == 3
    assert idx.evict(pool, 1) == 1              # deepest leaf goes first
    assert idx.match(toks) == (pages[:2], 4)
    assert idx.evict(pool, 5) == 2              # drains the rest, stops dry
    assert (len(idx), pool.n_free) == (0, 8)
    assert idx.n_evicted == 3


def test_index_evictable_excludes_chains_pinned_by_live_sharers():
    """An entry whose DESCENDANT has a live sharer can never become a
    leaf, so leaf-first eviction cannot drain it. n_evictable must not
    count such chains — the engine's preemption gate trusts it, and an
    overcount turns backpressure into pool exhaustion (the n_pages=9
    regression this PR fixed)."""
    pool = PagePool(8, page_size=2)
    idx = PrefixCache(2)
    toks = np.arange(6, dtype=np.int32)
    pages = pool.alloc(3)
    idx.insert(toks, pages, pool)
    pool.free(pages[:2])                        # ancestors: index-only
    assert pool.refcount(pages[2]) == 2         # leaf still shared
    assert idx.n_cached(pool) == 2              # retained, but...
    assert idx.n_evictable(pool) == 0           # ...pinned behind the leaf
    assert idx.evict(pool, 2) == 0              # and evict agrees
    pool.free([pages[2]])                       # sharer retires
    assert idx.n_evictable(pool) == 3


# ---------------------------------------------------------------------------
# Engine integration: retention, sharing, CoW
# ---------------------------------------------------------------------------

def test_retire_retains_prompt_pages_in_index():
    cfg, model, params = _model("stablelm_12b")
    prompts = _shared_prompts(cfg, 8, (2,))     # 2 full pages + partial
    outs, eng = _run(model, params, prompts, prefix_cache=True, budget=6)
    be = eng.backend
    assert len(be._prefix) == 2                 # full prompt pages indexed
    assert be._prefix.n_cached(eng._pool) == 2  # retained past retirement
    assert eng._pool.n_free == eng.n_pages - 2  # decode/partial pages drain
    # the retained pages are evictable on demand — nothing leaks
    assert be._prefix.evict(eng._pool, 2) == 2
    assert eng._pool.n_free == eng.n_pages


def test_second_request_hits_and_emits_identically():
    cfg, model, params = _model("stablelm_12b")
    p = _shared_prompts(cfg, 12, (5,))[0]
    prompts = [p, p]      # n_slots=1: the second arrives after the first
    off, _ = _run(model, params, prompts, prefix_cache=False, budget=8,
                  n_slots=1)                    # registers (same-wave
    on, eng = _run(model, params, prompts, prefix_cache=True, budget=8,
                   n_slots=1)                   # duplicates don't match)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    pf = eng.page_stats()["prefix"]
    assert pf["tokens_matched"] > 0 and pf["hit_rate"] > 0


def test_cow_on_page_aligned_hit():
    """A fully-cached page-aligned prompt must re-run its final chunk to
    produce the first sampled token (done is capped below prompt_len), so
    the deepest matched page is written by the sharer — copy-on-write
    copies it first, and the original entry keeps serving other
    requests."""
    cfg, model, params = _model("stablelm_12b")
    rng = np.random.RandomState(3)
    p = rng.randint(0, cfg.vocab, (8,)).astype(np.int32)   # exactly 2 pages
    prompts = [p, p, p]
    off, _ = _run(model, params, prompts, prefix_cache=False, budget=6)
    on, eng = _run(model, params, prompts, prefix_cache=True, budget=6)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    pf = eng.page_stats()["prefix"]
    assert pf["cow_copies"] >= 1
    assert pf["collisions_rejected"] == 0


@pytest.mark.parametrize("arch,temp", [
    ("stablelm_12b", 0.0),            # dense greedy
    ("stablelm_12b", 0.8),            # dense sampled (PRNG chain parity)
    ("granite_moe_3b_a800m", 0.8),    # MoE sampled (expert routing parity)
])
def test_shared_vs_unshared_bit_parity(arch, temp):
    cfg, model, params = _model(arch)
    prompts = _shared_prompts(cfg, 12, (5, 3, 7, 6))
    off, _ = _run(model, params, prompts, prefix_cache=False, temp=temp)
    on, eng = _run(model, params, prompts, prefix_cache=True, temp=temp)
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    assert eng.page_stats()["prefix"]["hit_rate"] > 0


def test_parity_through_preemption_of_a_sharing_request():
    """Tight pool (n_pages=9): the prefix engine preempts requests that
    are actively sharing pages and evicts retained entries mid-run —
    preempt decrefs without invalidating other holders, resume re-matches
    the index, and outputs stay bit-identical to the unshared engine."""
    cfg, model, params = _model("stablelm_12b")
    prompts = _shared_prompts(cfg, 12, (5, 3, 7, 6))
    off, _ = _run(model, params, prompts, prefix_cache=False, n_pages=9)
    on, eng = _run(model, params, prompts, prefix_cache=True, n_pages=9)
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    st = eng.page_stats()
    assert st["preemptions"] > 0            # sharing requests were evicted
    assert st["prefix"]["evictions"] > 0    # and the index gave pages back
    assert eng._pool.n_free + st["prefix"]["cached_pages"] == eng.n_pages


def test_submit_error_accounts_for_shared_hits():
    """The paged footprint error must state what admission would actually
    reserve under sharing — and keep the `paged mode.*page-table` shape
    test_lazy_pages pins for the unshared engine."""
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=48, n_slots=2, page_size=16,
                      n_pages=8, prefill_chunk=16, prefix_cache=True)
    rng = np.random.RandomState(7)
    head = rng.randint(0, cfg.vocab, (32,)).astype(np.int32)
    eng.submit(head, 4)                     # lands 2 pages in the index
    eng.run()
    over = np.concatenate([head, rng.randint(
        0, cfg.vocab, (68,)).astype(np.int32)])
    with pytest.raises(AdmissionRejected,
                       match=r"paged mode.*shared via the prefix cache"
                             r".*page-table"):
        eng.submit(over, 100)
