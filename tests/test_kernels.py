"""Pallas kernel sweeps (interpret=True on CPU) vs the pure-jnp oracle.

Per the assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.bias as bias_mod
from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


def _mk(key, b, n, m, h, kvh, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, n, h, d), dtype)
    k = jax.random.normal(ks[1], (b, m, kvh, d), dtype)
    v = jax.random.normal(ks[2], (b, m, kvh, d), dtype)
    return q, k, v


class TestFlashBiasAttnKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,n,h,kvh,d", [
        (1, 32, 4, 4, 16),     # MHA aligned
        (2, 48, 8, 2, 24),     # GQA, unaligned seq + channel
        (1, 17, 2, 1, 8),      # ragged seq (padding path)
        (1, 64, 6, 3, 160),    # head_dim > 128 (stablelm-style)
    ])
    def test_phi_causal(self, dtype, b, n, h, kvh, d):
        q, k, v = _mk(jax.random.PRNGKey(0), b, n, n, h, kvh, d, dtype)
        pq, pk = bias_mod.alibi_factors(n, n, h, dtype=jnp.float32)
        pq4 = bias_mod.broadcast_factors(pq, b, n, h)
        pk4 = bias_mod.broadcast_factors(pk, b, n, h)
        out = ops.flash_attention(q, k, v, pq4, pk4, mask_kind="causal",
                                  impl="pallas_interpret",
                                  block_q=16, block_k=16)
        want = ref.mha_reference(q, k, v, phi_q=pq4, phi_k=pk4,
                                 mask_kind="causal")
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=ATOL[dtype])

    @pytest.mark.parametrize("mask", ["none", "causal", "local"])
    def test_masks_match(self, mask):
        q, k, v = _mk(jax.random.PRNGKey(1), 1, 48, 48, 4, 4, 16, jnp.float32)
        out = ops.flash_attention(q, k, v, mask_kind=mask, window=16,
                                  impl="pallas_interpret",
                                  block_q=16, block_k=16)
        want = ref.mha_reference(q, k, v, mask_kind=mask, window=16)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_alibi_in_kernel_jit_generation(self):
        """App. C: slopes-only mode generates the rank-2 bias in-kernel."""
        h = 8
        q, k, v = _mk(jax.random.PRNGKey(2), 2, 32, 32, h, 4, 16, jnp.float32)
        slopes = bias_mod.alibi_slopes(h)
        out = ops.flash_attention(q, k, v, slopes=slopes, mask_kind="causal",
                                  impl="pallas_interpret",
                                  block_q=16, block_k=16)
        pq, pk = bias_mod.alibi_factors(32, 32, h)
        want = ref.mha_reference(
            q, k, v, phi_q=bias_mod.broadcast_factors(pq, 2, 32, h),
            phi_k=bias_mod.broadcast_factors(pk, 2, 32, h),
            mask_kind="causal")
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_gradients_match_reference(self):
        q, k, v = _mk(jax.random.PRNGKey(3), 1, 32, 32, 4, 2, 16, jnp.float32)
        pq, pk = bias_mod.alibi_factors(32, 32, 4)
        pq4 = bias_mod.broadcast_factors(pq, 1, 32, 4)
        pk4 = bias_mod.broadcast_factors(pk, 1, 32, 4)

        def f_kernel(q, pq4):
            return ops.flash_attention(q, k, v, pq4, pk4, mask_kind="causal",
                                       impl="pallas_interpret", block_q=16,
                                       block_k=16).sum()

        def f_ref(q, pq4):
            return ref.mha_reference(q, k, v, phi_q=pq4, phi_k=pk4,
                                     mask_kind="causal").sum()

        g1 = jax.grad(f_kernel, argnums=(0, 1))(q, pq4)
        g2 = jax.grad(f_ref, argnums=(0, 1))(q, pq4)
        np.testing.assert_allclose(g1[0], g2[0], atol=5e-5)
        np.testing.assert_allclose(g1[1], g2[1], atol=5e-4)

    def test_xla_and_kernel_paths_agree(self):
        q, k, v = _mk(jax.random.PRNGKey(4), 1, 40, 40, 4, 4, 16, jnp.float32)
        slopes = bias_mod.alibi_slopes(4)
        a = ops.flash_attention(q, k, v, slopes=slopes, mask_kind="causal",
                                impl="xla")
        b = ops.flash_attention(q, k, v, slopes=slopes, mask_kind="causal",
                                impl="pallas_interpret", block_q=8, block_k=8)
        np.testing.assert_allclose(a, b, atol=2e-5)


class TestFlashDecodeKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("b,s,h,kvh,d,blk", [
        (2, 64, 8, 4, 16, 16),
        (1, 96, 4, 1, 32, 32),
        (3, 40, 6, 2, 24, 8),    # ragged cache length
    ])
    def test_alibi_decode(self, dtype, b, s, h, kvh, d, blk):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, 1, h, d), dtype)
        kc = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, d), dtype)
        vc = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, d), dtype)
        lengths = jnp.asarray(
            np.random.RandomState(0).randint(1, s + 1, (b,)), jnp.int32)
        slopes = bias_mod.alibi_slopes(h)
        out = ops.flash_decode(q, kc, vc, lengths, slopes=slopes,
                               impl="pallas_interpret", block_k=blk)
        want = ref.decode_reference(q, kc, vc, lengths, slopes=slopes)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=ATOL[dtype])

    def test_phi_decode(self):
        b, s, h, kvh, d, r = 2, 48, 4, 2, 16, 5
        q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, d))
        kc = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, d))
        vc = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, d))
        pq = jax.random.normal(jax.random.PRNGKey(3), (b, 1, h, r))
        pk = jax.random.normal(jax.random.PRNGKey(4), (b, s, 1, r))
        lengths = jnp.array([31, 48], jnp.int32)
        out = ops.flash_decode(q, kc, vc, lengths, pq, pk,
                               impl="pallas_interpret", block_k=16)
        want = ref.decode_reference(q, kc, vc, lengths, phi_q=pq, phi_k=pk)
        np.testing.assert_allclose(out, want, atol=2e-5)

    def test_xla_decode_matches_oracle(self):
        b, s, h, kvh, d = 2, 64, 8, 4, 16
        q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, d))
        kc = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, d))
        vc = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, d))
        lengths = jnp.array([10, 64], jnp.int32)
        slopes = bias_mod.alibi_slopes(h)
        out = ops.flash_decode(q, kc, vc, lengths, slopes=slopes, impl="xla",
                               block_k=16)
        want = ref.decode_reference(q, kc, vc, lengths, slopes=slopes)
        np.testing.assert_allclose(out, want, atol=2e-5)
