"""scripts/check_bench.py — the CI benchmark-regression gate must pass on
healthy inputs, demonstrably FAIL on synthetic regressed inputs (a gate
that can't fail isn't one), and support --update-baseline."""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "check_bench.py"))
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


def _write(path, payload):
    with open(path, "w") as f:
        json.dump(payload, f)
    return str(path)


def _healthy_kernels(speedup=1.0):
    return {"dense_vs_factored": {"speedup": speedup, "seq_len": 512},
            "dense_vs_factored_sweep": [
                {"speedup": 0.8, "seq_len": 128},
                {"speedup": speedup, "seq_len": 512},
            ]}


def _healthy_serve(decode=2000.0, ratio=1.0, layout_ratio=1.0,
                   chunked_ratio=2.4, prefix_ratio=3.2, guard_ratio=1.0):
    return {
        "points": [
            {"occupancy": 1, "decode_tokens_per_s": decode / 2,
             "prefill_tokens_per_s": 1.0},
            {"occupancy": 4, "decode_tokens_per_s": decode,
             "prefill_tokens_per_s": 1.0},
        ],
        "lazy_vs_whole": {"occupancy": 4, "ratio": ratio},
        "layout_vs_legacy": {"occupancy": 4, "ratio": layout_ratio},
        "chunked_prefill": {"long_prompt": 128, "chunk": 16, "steps": 24,
                            "rounds": 3, "whole_p99_step_ms": 24.0,
                            "chunked_p99_step_ms": 24.0 / chunked_ratio,
                            "ratio": chunked_ratio},
        "prefix_sharing": {"n_requests": 64, "shared_prefix": 512,
                           "page_size": 64, "hit_rate": 0.98,
                           "outputs_identical": True,
                           "ratio": prefix_ratio},
        "guard_overhead": {"occupancy": 4, "page_size": 16,
                           "outputs_identical": True,
                           "ratio": guard_ratio},
    }


def _healthy_neural(dense_us=2000.0, flash_us=2000.0):
    return {"rows": [
        {"name": "table6_infer_dense_pairbias", "us_per_call": dense_us},
        {"name": "table6_infer_flashbias_neural", "us_per_call": flash_us},
        {"name": "unrelated_row", "us_per_call": 1.0},
    ]}


def _healthy_pairformer(ratio=1.2, cached_ratio=1.0):
    point = {"n_res": 384, "ratio": ratio, "cached_ratio": cached_ratio,
             "factored_step_ms": 1.0, "dense_step_ms": ratio,
             "cached_bias_step_ms": cached_ratio}
    return {"points": [
        {"n_res": 128, "ratio": 0.5, "cached_ratio": 0.5},  # small-N decoy
        point,
    ], "factored_vs_dense": point}


@pytest.fixture
def files(tmp_path):
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    _write(bdir / check_bench.KERNELS_BASELINE, {"speedup": 1.0})
    _write(bdir / check_bench.SERVE_BASELINE,
           {"occupancy": 4, "decode_tokens_per_s": 2000.0})
    _write(bdir / check_bench.NEURAL_BASELINE, {"speedup": 1.0})
    _write(bdir / check_bench.PAIRFORMER_BASELINE, {"cached_ratio": 1.0})
    kernels = _write(tmp_path / "k.json", _healthy_kernels())
    serve = _write(tmp_path / "s.json", _healthy_serve())
    return tmp_path, str(bdir), kernels, serve


def _run(bdir, kernels, serve, *extra):
    return check_bench.main(["--kernels", kernels, "--serve", serve,
                             "--baseline-dir", bdir, *extra])


def test_healthy_inputs_pass(files):
    tmp, bdir, kernels, serve = files
    assert _run(bdir, kernels, serve) == 0
    # a drop inside the band also passes (smoke-noise tolerant)
    k2 = _write(tmp / "k2.json", _healthy_kernels(speedup=0.8))
    s2 = _write(tmp / "s2.json", _healthy_serve(decode=1500.0, ratio=0.85))
    assert _run(bdir, k2, s2) == 0


def test_regressed_speedup_fails(files):
    tmp, bdir, _, serve = files
    bad = _write(tmp / "bad_k.json", _healthy_kernels(speedup=0.5))
    assert _run(bdir, bad, serve) == 1


def test_regressed_serve_decode_fails(files):
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_s.json", _healthy_serve(decode=900.0))
    assert _run(bdir, kernels, bad) == 1


def test_regressed_lazy_ratio_fails(files):
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_r.json", _healthy_serve(ratio=0.5))
    assert _run(bdir, kernels, bad) == 1


def test_regressed_layout_ratio_fails(files):
    """ISSUE 5 gate: a kernel-layout decode path slower than the legacy
    transpose-per-step path (ratio < 1 - tolerance) must fail CI."""
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_l.json", _healthy_serve(layout_ratio=0.5))
    assert _run(bdir, kernels, bad) == 1
    # inside the band passes (noise-tolerant, same as the lazy gate)
    near = _write(tmp / "near_l.json", _healthy_serve(layout_ratio=0.85))
    assert _run(bdir, kernels, near) == 0
    assert _run(bdir, kernels, near, "--tolerance", "0.05") == 1


def test_regressed_chunked_prefill_ratio_fails(files):
    """ISSUE 7 gate: chunked prefill degenerating into a monolithic
    prefill stall (whole/chunked p99 ratio ~1.0) must fail CI. The floor
    is structural (1.2, fixed), NOT tolerance-scaled — widening
    --tolerance must not save it."""
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_c.json", _healthy_serve(chunked_ratio=1.0))
    assert _run(bdir, kernels, bad) == 1
    assert _run(bdir, kernels, bad, "--tolerance", "0.90") == 1
    healthy = _write(tmp / "ok_c.json", _healthy_serve(chunked_ratio=1.3))
    assert _run(bdir, kernels, healthy) == 0


def test_regressed_prefix_sharing_ratio_fails(files):
    """ISSUE 9 gate: shared-prefix admission that stops matching (cached/
    uncached throughput ratio ~1.0) must fail CI. Structural floor (2.0,
    fixed), NOT tolerance-scaled — widening --tolerance must not save
    it."""
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_pf.json", _healthy_serve(prefix_ratio=1.0))
    assert _run(bdir, kernels, bad) == 1
    assert _run(bdir, kernels, bad, "--tolerance", "0.90") == 1
    healthy = _write(tmp / "ok_pf.json", _healthy_serve(prefix_ratio=2.2))
    assert _run(bdir, kernels, healthy) == 0


def test_regressed_guard_overhead_ratio_fails(files):
    """ISSUE 10 gate: NaN/Inf guards costing more than 5% of decode
    throughput (guarded/unguarded ratio < 0.95) must fail CI. Structural
    floor (0.95, fixed), NOT tolerance-scaled — widening --tolerance
    must not save it."""
    tmp, bdir, kernels, _ = files
    bad = _write(tmp / "bad_g.json", _healthy_serve(guard_ratio=0.90))
    assert _run(bdir, kernels, bad) == 1
    assert _run(bdir, kernels, bad, "--tolerance", "0.90") == 1
    healthy = _write(tmp / "ok_g.json", _healthy_serve(guard_ratio=0.96))
    assert _run(bdir, kernels, healthy) == 0


def test_serve_only_skips_kernels_gate(files, tmp_path):
    """--serve-only (the mesh-serve CI job) gates BENCH_serve.json without
    a kernels artifact on disk — and still fails on serve regressions."""
    tmp, bdir, _, serve = files
    missing = str(tmp_path / "no_such_kernels.json")
    assert check_bench.main(["--kernels", missing, "--serve", serve,
                             "--baseline-dir", bdir, "--serve-only"]) == 0
    bad = _write(tmp / "bad_so.json", _healthy_serve(chunked_ratio=1.0))
    assert check_bench.main(["--kernels", missing, "--serve", bad,
                             "--baseline-dir", bdir, "--serve-only"]) == 1


def test_headline_is_sweep_point_not_small_n():
    """The gated kernels headline must be the paper-scale sweep point —
    a small-N artifact (where factored legitimately loses) would weaken
    the gate to meaninglessness. Runs bench_kernels' actual
    headline-selection logic on a sweep whose small-N point both LEADS
    the list and has the bigger speedup, so any regression to
    first/last/best-point selection is caught."""
    from benchmarks.bench_kernels import headline_point
    sweep = [
        {"seq_len": 128, "speedup": 2.0},     # small-N decoy, listed first
        {"seq_len": 2048, "speedup": 1.1},    # paper scale: the headline
        {"seq_len": 512, "speedup": 1.5},
    ]
    assert headline_point(sweep) == sweep[1]


def test_tolerance_flag_widens_band(files):
    tmp, bdir, kernels, _ = files
    near = _write(tmp / "near.json", _healthy_serve(decode=1500.0))
    assert _run(bdir, kernels, near, "--tolerance", "0.10") == 1
    assert _run(bdir, kernels, near, "--tolerance", "0.30") == 0


def test_update_baseline_roundtrip(files, tmp_path):
    tmp, _, kernels, serve = files
    new_dir = str(tmp_path / "fresh")
    assert _run(new_dir, kernels, serve, "--update-baseline") == 0
    with open(os.path.join(new_dir, check_bench.SERVE_BASELINE)) as f:
        sb = json.load(f)
    assert sb == {"occupancy": 4, "decode_tokens_per_s": 2000.0}
    assert _run(new_dir, kernels, serve) == 0


def test_occupancy_mismatch_with_baseline_fails(files):
    """A bench whose highest measured occupancy no longer matches the
    committed baseline's occupancy is not comparable — fail loudly instead
    of comparing different workloads."""
    tmp, bdir, kernels, _ = files
    shrunk = _healthy_serve()
    shrunk["points"] = shrunk["points"][:1]      # occ 1 only
    s = _write(tmp / "occ1.json", shrunk)
    assert _run(bdir, kernels, s) == 1


def test_gates_highest_occupancy_point(files):
    """The serve gate reads the HIGHEST-occupancy point, not list order."""
    tmp, bdir, kernels, _ = files
    shuffled = _healthy_serve()
    shuffled["points"] = shuffled["points"][::-1]
    s = _write(tmp / "shuf.json", shuffled)
    occ, tps = check_bench.serve_decode_point(json.load(open(s)))
    assert (occ, tps) == (4, 2000.0)
    assert _run(bdir, kernels, s) == 0


def test_neural_gate_opt_in(files):
    """--neural enables the Table 6 speedup gate: healthy passes, a
    regressed flash path fails, and omitting the flag skips the gate
    entirely (even with a regressed file on disk)."""
    tmp, bdir, kernels, serve = files
    good = _write(tmp / "n.json", _healthy_neural())
    assert _run(bdir, kernels, serve, "--neural", good) == 0
    bad = _write(tmp / "bad_n.json", _healthy_neural(flash_us=5000.0))
    assert _run(bdir, kernels, serve, "--neural", bad) == 1
    assert _run(bdir, kernels, serve) == 0  # flag absent -> gate skipped


def test_pairformer_headline_gate(files):
    """--pairformer gates the factored-vs-official-recompute ratio of the
    LARGEST-n_res point at >= 1 - tolerance; a factored path slower than
    the recompute dataflow fails CI."""
    tmp, bdir, kernels, serve = files
    good = _write(tmp / "p.json", _healthy_pairformer())
    assert _run(bdir, kernels, serve, "--pairformer", good) == 0
    bad = _write(tmp / "bad_p.json", _healthy_pairformer(ratio=0.5))
    assert _run(bdir, kernels, serve, "--pairformer", bad) == 1
    assert _run(bdir, kernels, serve) == 0  # flag absent -> gate skipped


def test_pairformer_cached_ratio_tripwire(files):
    """The cached_ratio gate compares against its committed baseline — a
    drop beyond tolerance (e.g. the factored step silently materializing
    the dense bias) fails even when the headline ratio stays healthy."""
    tmp, bdir, kernels, serve = files
    bad = _write(tmp / "trip.json", _healthy_pairformer(cached_ratio=0.5))
    assert _run(bdir, kernels, serve, "--pairformer", bad) == 1
    near = _write(tmp / "near_p.json", _healthy_pairformer(cached_ratio=0.8))
    assert _run(bdir, kernels, serve, "--pairformer", near) == 0
    assert _run(bdir, kernels, serve, "--pairformer", near,
                "--tolerance", "0.05") == 1


def test_pairformer_headline_is_largest_n_res():
    """The gated point is factored_vs_dense — bench_pairformer pins it to
    the largest-n_res sweep point, not the small-N decoy where the
    factored path legitimately loses on CPU."""
    head = check_bench.pairformer_headline(_healthy_pairformer())
    assert head["n_res"] == 384
    assert head["ratio"] == pytest.approx(1.2)


def test_schema_missing_gated_key_fails(files):
    """A bench that silently drops a gated key (here: layout_vs_legacy
    loses 'ratio') must fail the schema gate loudly, not pass vacuously
    or die in a KeyError mid-check."""
    tmp, bdir, kernels, _ = files
    broken = _healthy_serve()
    del broken["layout_vs_legacy"]["ratio"]
    s = _write(tmp / "noratio.json", broken)
    assert _run(bdir, kernels, s) == 1
    errs = check_bench.schema_errors("serve", broken)
    assert errs == ["serve: missing required key path 'layout_vs_legacy.ratio'"]


def test_schema_empty_points_fails(files):
    """An empty sweep satisfies max()-free code paths nowhere — 'points[]'
    requires a non-empty list with the gated keys on every element."""
    tmp, bdir, kernels, _ = files
    broken = _healthy_serve()
    broken["points"] = []
    s = _write(tmp / "nopoints.json", broken)
    assert _run(bdir, kernels, s) == 1
    partial = _healthy_serve()
    del partial["points"][0]["decode_tokens_per_s"]
    s2 = _write(tmp / "partial.json", partial)
    assert _run(bdir, kernels, s2) == 1


def test_schema_named_row_missing_fails(files):
    """--neural schema pins the two Table 6 rows by NAME: a rename breaks
    the gate's row lookup, so it must fail at validation."""
    tmp, bdir, kernels, serve = files
    broken = _healthy_neural()
    broken["rows"][1]["name"] = "table6_infer_flashbias_renamed"
    n = _write(tmp / "renamed.json", broken)
    assert _run(bdir, kernels, serve, "--neural", n) == 1
    errs = check_bench.schema_errors("neural", broken)
    assert len(errs) == 1 and "table6_infer_flashbias_neural" in errs[0]


def test_schema_kernels_missing_sweep_fails(files):
    tmp, bdir, _, serve = files
    broken = _healthy_kernels()
    del broken["dense_vs_factored_sweep"]
    k = _write(tmp / "nosweep.json", broken)
    assert _run(bdir, k, serve) == 1


def test_schema_validates_before_update_baseline(files, tmp_path):
    """--update-baseline must not commit baselines read from a malformed
    bench file."""
    tmp, _, kernels, _ = files
    broken = _healthy_serve()
    del broken["chunked_prefill"]
    s = _write(tmp / "nochunk.json", broken)
    new_dir = str(tmp_path / "fresh_schema")
    assert _run(new_dir, kernels, s, "--update-baseline") == 1
    assert not os.path.exists(os.path.join(new_dir, check_bench.SERVE_BASELINE))


def test_schema_healthy_payloads_clean():
    for suite, payload in (
        ("kernels", _healthy_kernels()),
        ("serve", _healthy_serve()),
        ("neural", _healthy_neural()),
        ("pairformer", _healthy_pairformer()),
    ):
        assert check_bench.schema_errors(suite, payload) == []


def test_update_baseline_writes_opt_in_files(files, tmp_path):
    """--update-baseline with the opt-in flags also refreshes the neural
    and pairformer baselines; without the flags it leaves them unwritten."""
    tmp, _, kernels, serve = files
    new_dir = str(tmp_path / "fresh_opt")
    n = _write(tmp / "n_up.json", _healthy_neural(dense_us=3000.0))
    p = _write(tmp / "p_up.json", _healthy_pairformer(cached_ratio=0.9))
    assert _run(new_dir, kernels, serve, "--neural", n, "--pairformer", p,
                "--update-baseline") == 0
    with open(os.path.join(new_dir, check_bench.NEURAL_BASELINE)) as f:
        assert json.load(f) == {"speedup": 1.5}
    with open(os.path.join(new_dir, check_bench.PAIRFORMER_BASELINE)) as f:
        assert json.load(f) == {"cached_ratio": 0.9}
    assert _run(new_dir, kernels, serve, "--neural", n,
                "--pairformer", p) == 0
    bare_dir = str(tmp_path / "fresh_bare")
    assert _run(bare_dir, kernels, serve, "--update-baseline") == 0
    assert not os.path.exists(
        os.path.join(bare_dir, check_bench.NEURAL_BASELINE))
    assert not os.path.exists(
        os.path.join(bare_dir, check_bench.PAIRFORMER_BASELINE))
