"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward/train step on CPU, output shapes + no NaNs; plus decode-vs-
prefill consistency (the serving contract) for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import get_model
from repro.models.common import init_params

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=24):
    toks = jax.random.randint(key, (b, s - cfg.frontend_len), 0, cfg.vocab)
    out = {"tokens": toks, "labels": toks}
    if cfg.frontend_len:
        out["frontend"] = jax.random.normal(
            key, (b, cfg.frontend_len, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(t_T | prefill(t_<T)) logits == prefill(t_<=T) last logits.

    MoE archs use a high capacity factor here: consistency is exact only
    when capacity routing drops nothing (token-drop sets legitimately
    differ between a 13-token prefill and a 14-token prefill).
    """
    cfg = smoke_config(arch).replace(frontend_len=0, capacity_factor=8.0)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    b, t = 2, 13
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, t + 1), 0, cfg.vocab)
    _, cache = model.prefill(params, {"tokens": toks[:, :t]}, max_len=t + 4)
    lg, _ = model.decode(params, cache, toks[:, t:t + 1])
    lg_ref, _ = model.prefill(params, {"tokens": toks}, max_len=t + 5)
    np.testing.assert_allclose(lg, lg_ref, atol=3e-3)


def test_hymba_ring_cache_beyond_window():
    """SWA ring buffer: decoding past the window stays consistent."""
    cfg = smoke_config("hymba_15b")
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    t = cfg.window + 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, t + 1), 0, cfg.vocab)
    _, cache = model.prefill(params, {"tokens": toks[:, :t]}, max_len=t + 4)
    assert cache["k"].shape[3] == cfg.window            # ring, not full
    # kernel cache layout (ISSUE 5): (L, B, KVH, window, hd)
    assert cache["k"].shape[2] == cfg.kv_heads_padded
    lg, _ = model.decode(params, cache, toks[:, t:t + 1])
    lg_ref, _ = model.prefill(params, {"tokens": toks}, max_len=t + 5)
    np.testing.assert_allclose(lg, lg_ref, atol=3e-3)


def test_mamba2_cache_is_constant_size():
    cfg = smoke_config("mamba2_130m")
    model = get_model(cfg)
    c1 = model.init_cache(2, 64)
    c2 = model.init_cache(2, 4096)
    assert c1["ssm_h"].shape == c2["ssm_h"].shape       # no KV growth
    assert "k" not in c1


def test_moe_padded_experts_never_selected():
    """Padded experts receive -inf router logits -> zero dispatch mass."""
    from repro.models.lm import _moe_ffn
    cfg = smoke_config("granite_moe_3b_a800m").replace(tp=4)  # pads 5 -> 8
    assert cfg.experts_padded == 8 and cfg.n_experts == 5
    mp = init_params(
        __import__("repro.models.lm", fromlist=["x"])._moe_template(cfg),
        jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = _moe_ffn(mp, x, cfg)
    assert jnp.all(jnp.isfinite(y)) and jnp.isfinite(aux)


def test_moe_matches_dense_expert_when_top1_single_expert():
    """With 1 real expert and top-1, MoE == that expert's SwiGLU applied to
    every token (capacity permitting)."""
    from repro.models.lm import _moe_ffn, _moe_template
    from repro.models.common import swiglu
    cfg = smoke_config("llama4_scout_17b_a16e").replace(
        n_experts=1, top_k=1, capacity_factor=4.0, tp=1)
    mp = init_params(_moe_template(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = _moe_ffn(mp, x, cfg)
    want = swiglu(x, mp["wi"][0], mp["wo"][0])
    np.testing.assert_allclose(y, want, atol=1e-4)


def test_bias_mode_dense_equals_flashbias_lm():
    """The paper's A/B at model level: dense-materialized ALiBi == factored."""
    cfg = smoke_config("codeqwen15_7b")
    model_fb = get_model(cfg.replace(bias_mode="flashbias"))
    model_d = get_model(cfg.replace(bias_mode="dense"))
    params = init_params(model_fb.template(), jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1 = model_fb.loss(params, batch)
    l2 = model_d.loss(params, batch)
    np.testing.assert_allclose(l1, l2, atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_production_config_template_builds(arch):
    """Full-size templates materialize abstractly (no allocation) with
    TP-consistent padded dims."""
    from repro.configs import get_config
    from repro.models.common import abstract_params, param_bytes
    cfg = get_config(arch)
    model = get_model(cfg)
    tmpl = model.template()
    ap = abstract_params(tmpl)
    n_bytes = param_bytes(tmpl)
    assert n_bytes > 1e6
    if cfg.n_heads:
        assert cfg.heads_padded % cfg.tp == 0
        assert cfg.heads_padded % cfg.kv_heads_padded == 0
    assert cfg.vocab_padded % cfg.tp == 0
    if cfg.n_experts:
        assert cfg.experts_padded % cfg.tp == 0
