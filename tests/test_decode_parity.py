"""Decode-vs-prefill parity + GQA factored-bias regressions (ISSUE 2).

For GQA (KVH < H), ragged per-request lengths and all three bias modes
(none / phi / alibi), ``flash_decode`` — on both the XLA and the
interpreted Pallas path — must match the LAST ROW of full causal
``flash_attention`` over each request's valid prefix, to fp32 tolerance.

Plus regression tests for two GQA phi_k bugs: the full-attention XLA path
used to collapse per-kv-head factors to kv head 0, and the Pallas decode
path used to raise on them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

B, S, H, KVH, D, R = 3, 48, 8, 2, 16, 4
G = H // KVH
LENGTHS = np.array([17, 48, 33], np.int32)     # ragged, incl. non-block-multiple


def _setup(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    # PER-KV-HEAD factors: kv heads must get distinct rows for the
    # regression to bite (the old code used head 0's factors everywhere)
    pq = jax.random.normal(ks[3], (B, S, H, R))
    pk = jax.random.normal(ks[4], (B, S, KVH, R))
    slopes = jnp.asarray(0.5 ** np.arange(1, H + 1), jnp.float32)
    return q, k, v, pq, pk, slopes


def _bias_kwargs(mode, pq, pk, slopes):
    if mode == "phi":
        return {"phi_q": pq, "phi_k": pk}
    if mode == "alibi":
        return {"slopes": slopes}
    return {}


class TestDecodePrefillParity:
    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("mode", ["none", "phi", "alibi"])
    def test_decode_matches_last_prefill_row(self, impl, mode):
        q, k, v, pq, pk, slopes = _setup()
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]               # (B,1,H,D)
        kw = _bias_kwargs(mode, pq, pk, slopes)
        if mode == "phi":
            kw["phi_q"] = pq[bidx, LENGTHS - 1][:, None]    # (B,1,H,R)
        out = ops.flash_decode(q_dec, k, v, lengths, impl=impl, block_k=16,
                               **kw)
        for b in range(B):
            n = int(LENGTHS[b])
            kw_b = _bias_kwargs(mode, pq[b:b + 1, :n], pk[b:b + 1, :n],
                                slopes)
            full = ops.flash_attention(q[b:b + 1, :n], k[b:b + 1, :n],
                                       v[b:b + 1, :n], mask_kind="causal",
                                       impl="xla", **kw_b)
            np.testing.assert_allclose(np.asarray(out[b, 0], np.float32),
                                       np.asarray(full[0, n - 1], np.float32),
                                       atol=3e-5,
                                       err_msg=f"{impl}/{mode}/req{b}")

    @pytest.mark.parametrize("mode", ["none", "phi", "alibi"])
    def test_xla_and_pallas_decode_agree(self, mode):
        q, k, v, pq, pk, slopes = _setup(key=1)
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        kw = _bias_kwargs(mode, pq, pk, slopes)
        if mode == "phi":
            kw["phi_q"] = pq[bidx, LENGTHS - 1][:, None]
        a = ops.flash_decode(q_dec, k, v, lengths, impl="xla", block_k=16,
                             **kw)
        b_ = ops.flash_decode(q_dec, k, v, lengths, impl="pallas_interpret",
                              block_k=16, **kw)
        np.testing.assert_allclose(a, b_, atol=3e-5)


class TestGQAPhiKRegressions:
    """The old code silently used kv head 0's key factors for every query
    head (_xla_path) and raised on (B, S, KVH, R) (decode Pallas path)."""

    def test_full_attention_per_kv_head_phi_k(self):
        q, k, v, pq, pk, _ = _setup(key=2)
        out = ops.flash_attention(q, k, v, pq, pk, mask_kind="causal",
                                  impl="xla")
        pk_full = jnp.repeat(pk, G, axis=2)                 # (B,S,H,R)
        want = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_full,
                                 mask_kind="causal")
        np.testing.assert_allclose(out, want, atol=3e-5)
        # the head-0 collapse must actually produce DIFFERENT values here,
        # otherwise this regression test would pass vacuously
        pk_head0 = jnp.broadcast_to(pk[:, :, :1], pk_full.shape)
        wrong = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_head0,
                                  mask_kind="causal")
        assert float(jnp.abs(want - wrong).max()) > 1e-2

    def test_full_attention_per_kv_head_phi_k_pallas(self):
        q, k, v, pq, pk, _ = _setup(key=3)
        out = ops.flash_attention(q, k, v, pq, pk, mask_kind="causal",
                                  impl="pallas_interpret",
                                  block_q=16, block_k=16)
        pk_full = jnp.repeat(pk, G, axis=2)
        want = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_full,
                                 mask_kind="causal")
        np.testing.assert_allclose(out, want, atol=3e-5)

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_decode_per_kv_head_phi_k(self, impl):
        """Old Pallas path: jnp.broadcast_to((B,S,KVH,R) -> (B,S,H,R))
        raises; old XLA path hit the same broadcast in core attention."""
        q, k, v, pq, pk, _ = _setup(key=4)
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        pq_dec = pq[bidx, LENGTHS - 1][:, None]
        out = ops.flash_decode(q_dec, k, v, lengths, phi_q=pq_dec, phi_k=pk,
                               impl=impl, block_k=16)
        want = ref.decode_reference(q_dec, k, v, lengths, phi_q=pq_dec,
                                    phi_k=jnp.repeat(pk, G, axis=2))
        np.testing.assert_allclose(out, want, atol=3e-5)

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_decode_per_q_head_phi_k(self, impl):
        """Regression (ISSUE 3): per-Q-HEAD key factors (B, S, H, R) with
        DISTINCT rows inside each GQA group. The Pallas path's grouped-key
        layout carries one key factor per kv head and used to silently take
        each group's first head — it must route this shape to the XLA path
        instead (and the XLA path must expand nothing: the factors are
        already per head)."""
        q, k, v, pq, _, _ = _setup(key=5)
        # per-q-head factors, guaranteed distinct within every group
        pk_h = jax.random.normal(jax.random.PRNGKey(55), (B, S, H, R))
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        pq_dec = pq[bidx, LENGTHS - 1][:, None]
        out = ops.flash_decode(q_dec, k, v, lengths, phi_q=pq_dec,
                               phi_k=pk_h, impl=impl, block_k=16)
        want = ref.decode_reference(q_dec, k, v, lengths, phi_q=pq_dec,
                                    phi_k=pk_h)
        np.testing.assert_allclose(out, want, atol=3e-5)
        # the group-first-head collapse must produce DIFFERENT values here,
        # otherwise this regression would pass vacuously
        pk_head0 = jnp.repeat(pk_h.reshape(B, S, KVH, G, R)[:, :, :, 0],
                              G, axis=2)
        wrong = ref.decode_reference(q_dec, k, v, lengths, phi_q=pq_dec,
                                     phi_k=pk_head0)
        assert float(jnp.abs(want - wrong).max()) > 1e-2


class TestPagedDecodeParity:
    """The paged path (page pool + page table + per-page factor slab) must
    agree with the contiguous path for every bias mode, on both impls, with
    physically scrambled pages."""

    PS = 16                                       # page_size == block_k

    def _paginate(self, k, v, n_extra=5, seed=0):
        p = S // self.PS
        n_pages = B * p + n_extra
        rng = np.random.RandomState(seed)
        perm = rng.permutation(n_pages)[:B * p].reshape(B, p)
        kp = np.array(jax.random.normal(jax.random.PRNGKey(90 + seed),
                                        (n_pages, self.PS, KVH, D)))
        vp = np.array(jax.random.normal(jax.random.PRNGKey(91 + seed),
                                        (n_pages, self.PS, KVH, D)))
        slab = np.zeros((n_pages, self.PS, 2), np.float32)
        pos = np.arange(S, dtype=np.float32)
        slab_log = np.stack([np.ones(S, np.float32), pos], -1)
        for b in range(B):
            for j in range(p):
                kp[perm[b, j]] = np.asarray(k[b, j * self.PS:(j + 1) * self.PS])
                vp[perm[b, j]] = np.asarray(v[b, j * self.PS:(j + 1) * self.PS])
                slab[perm[b, j]] = slab_log[j * self.PS:(j + 1) * self.PS]
        return (jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(slab),
                jnp.asarray(perm, jnp.int32))

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("mode", ["none", "phi", "alibi"])
    def test_paged_matches_contiguous(self, impl, mode):
        q, k, v, pq, pk, slopes = _setup(key=6)
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        kp, vp, slab, pt = self._paginate(k, v)
        kw_c = _bias_kwargs(mode, pq, pk, slopes)
        kw_p = dict(kw_c)
        if mode == "phi":
            # paged mode reads key factors from the per-page slab (here the
            # rank-2 ALiBi position factor [1, pos]); q factors are per head
            # and must match the slab's rank
            pq2 = jax.random.normal(jax.random.PRNGKey(66), (B, 1, H, 2))
            kw_c = {"phi_q": pq2,
                    "phi_k": jnp.broadcast_to(
                        jnp.stack([jnp.ones(S), jnp.arange(S, dtype=jnp.float32)],
                                  -1)[None, :, None, :], (B, S, 1, 2))}
            kw_p = {"phi_q": pq2, "phi_k": slab}
        want = ops.flash_decode(q_dec, k, v, lengths, impl="xla", block_k=16,
                                **kw_c)
        got = ops.flash_decode(q_dec, kp, vp, lengths, page_table=pt,
                               impl=impl, block_k=16, **kw_p)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=3e-5,
                                   err_msg=f"paged {impl}/{mode}")
