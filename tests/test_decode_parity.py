"""Decode-vs-prefill parity + GQA factored-bias regressions (ISSUE 2).

For GQA (KVH < H), ragged per-request lengths and all three bias modes
(none / phi / alibi), ``flash_decode`` — on both the XLA and the
interpreted Pallas path — must match the LAST ROW of full causal
``flash_attention`` over each request's valid prefix, to fp32 tolerance.

Plus regression tests for two GQA phi_k bugs: the full-attention XLA path
used to collapse per-kv-head factors to kv head 0, and the Pallas decode
path used to raise on them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

B, S, H, KVH, D, R = 3, 48, 8, 2, 16, 4
G = H // KVH
LENGTHS = np.array([17, 48, 33], np.int32)     # ragged, incl. non-block-multiple


def _setup(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KVH, D))
    v = jax.random.normal(ks[2], (B, S, KVH, D))
    # PER-KV-HEAD factors: kv heads must get distinct rows for the
    # regression to bite (the old code used head 0's factors everywhere)
    pq = jax.random.normal(ks[3], (B, S, H, R))
    pk = jax.random.normal(ks[4], (B, S, KVH, R))
    slopes = jnp.asarray(0.5 ** np.arange(1, H + 1), jnp.float32)
    return q, k, v, pq, pk, slopes


def _bias_kwargs(mode, pq, pk, slopes):
    if mode == "phi":
        return {"phi_q": pq, "phi_k": pk}
    if mode == "alibi":
        return {"slopes": slopes}
    return {}


class TestDecodePrefillParity:
    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("mode", ["none", "phi", "alibi"])
    def test_decode_matches_last_prefill_row(self, impl, mode):
        q, k, v, pq, pk, slopes = _setup()
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]               # (B,1,H,D)
        kw = _bias_kwargs(mode, pq, pk, slopes)
        if mode == "phi":
            kw["phi_q"] = pq[bidx, LENGTHS - 1][:, None]    # (B,1,H,R)
        out = ops.flash_decode(q_dec, k, v, lengths, impl=impl, block_k=16,
                               **kw)
        for b in range(B):
            n = int(LENGTHS[b])
            kw_b = _bias_kwargs(mode, pq[b:b + 1, :n], pk[b:b + 1, :n],
                                slopes)
            full = ops.flash_attention(q[b:b + 1, :n], k[b:b + 1, :n],
                                       v[b:b + 1, :n], mask_kind="causal",
                                       impl="xla", **kw_b)
            np.testing.assert_allclose(np.asarray(out[b, 0], np.float32),
                                       np.asarray(full[0, n - 1], np.float32),
                                       atol=3e-5,
                                       err_msg=f"{impl}/{mode}/req{b}")

    @pytest.mark.parametrize("mode", ["none", "phi", "alibi"])
    def test_xla_and_pallas_decode_agree(self, mode):
        q, k, v, pq, pk, slopes = _setup(key=1)
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        kw = _bias_kwargs(mode, pq, pk, slopes)
        if mode == "phi":
            kw["phi_q"] = pq[bidx, LENGTHS - 1][:, None]
        a = ops.flash_decode(q_dec, k, v, lengths, impl="xla", block_k=16,
                             **kw)
        b_ = ops.flash_decode(q_dec, k, v, lengths, impl="pallas_interpret",
                              block_k=16, **kw)
        np.testing.assert_allclose(a, b_, atol=3e-5)


class TestGQAPhiKRegressions:
    """The old code silently used kv head 0's key factors for every query
    head (_xla_path) and raised on (B, S, KVH, R) (decode Pallas path)."""

    def test_full_attention_per_kv_head_phi_k(self):
        q, k, v, pq, pk, _ = _setup(key=2)
        out = ops.flash_attention(q, k, v, pq, pk, mask_kind="causal",
                                  impl="xla")
        pk_full = jnp.repeat(pk, G, axis=2)                 # (B,S,H,R)
        want = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_full,
                                 mask_kind="causal")
        np.testing.assert_allclose(out, want, atol=3e-5)
        # the head-0 collapse must actually produce DIFFERENT values here,
        # otherwise this regression test would pass vacuously
        pk_head0 = jnp.broadcast_to(pk[:, :, :1], pk_full.shape)
        wrong = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_head0,
                                  mask_kind="causal")
        assert float(jnp.abs(want - wrong).max()) > 1e-2

    def test_full_attention_per_kv_head_phi_k_pallas(self):
        q, k, v, pq, pk, _ = _setup(key=3)
        out = ops.flash_attention(q, k, v, pq, pk, mask_kind="causal",
                                  impl="pallas_interpret",
                                  block_q=16, block_k=16)
        pk_full = jnp.repeat(pk, G, axis=2)
        want = ref.mha_reference(q, k, v, phi_q=pq, phi_k=pk_full,
                                 mask_kind="causal")
        np.testing.assert_allclose(out, want, atol=3e-5)

    @pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
    def test_decode_per_kv_head_phi_k(self, impl):
        """Old Pallas path: jnp.broadcast_to((B,S,KVH,R) -> (B,S,H,R))
        raises; old XLA path hit the same broadcast in core attention."""
        q, k, v, pq, pk, _ = _setup(key=4)
        lengths = jnp.asarray(LENGTHS)
        bidx = jnp.arange(B)
        q_dec = q[bidx, LENGTHS - 1][:, None]
        pq_dec = pq[bidx, LENGTHS - 1][:, None]
        out = ops.flash_decode(q_dec, k, v, lengths, phi_q=pq_dec, phi_k=pk,
                               impl=impl, block_k=16)
        want = ref.decode_reference(q_dec, k, v, lengths, phi_q=pq_dec,
                                    phi_k=jnp.repeat(pk, G, axis=2))
        np.testing.assert_allclose(out, want, atol=3e-5)
