"""Kernel-native cache layout parity (ISSUE 5).

The caches now live in the flash-decode kernels' kv-head-major layout from
allocation (``cfg.cache_layout="kernel"``, the default) and the jitted
decode step hands them over zero-copy. The old canonical layout is kept as
``cache_layout="legacy"`` — this suite pins the refactor to it:

- prefill caches are the same tensors, just transposed, and prefill logits
  are BIT-identical (the compute path is shared);
- greedy decode across full-KV / ring-KV / paged families under GQA emits
  BIT-identical token streams (layout must never change what is sampled);
- the interpret-mode Pallas kernels agree across layouts too (the
  zero-copy dispatch is exercised, not just the XLA fallback);
- the preempt -> re-prefill -> resume path is layout-invariant;
- the paged XLA fallback's gather cap (ISSUE 5 satellite) resolves to
  ceil(max(lengths)/page_size) and never lets garbage table entries leak.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels import ops
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import ServeEngine

LAYOUTS = ("kernel", "legacy")


def _params(cfg):
    model = get_model(cfg)
    return model, init_params(model.template(), jax.random.PRNGKey(0))


def _prompts(cfg, n, t, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (t,)).astype(np.int32)
            for _ in range(n)]


def _generate(cfg, *, page_size=None, steps=8, n_slots=2, prompt_len=6,
              **eng_kw):
    model, params = _params(cfg)
    kw = dict(eng_kw)
    if page_size:
        kw.setdefault("page_size", page_size)
    eng = ServeEngine(model, params, max_len=32, n_slots=n_slots,
                      prefill_len=prompt_len, **kw)
    prompts = _prompts(cfg, n_slots, prompt_len)
    return eng, np.stack([r for r in eng.generate(prompts, steps)])


class TestLayoutParity:
    """Greedy outputs must be bit-identical across cache layouts."""

    @pytest.mark.parametrize("arch", ["command_r_plus_104b", "hymba_15b",
                                      "llama4_scout_17b_a16e"])
    def test_contiguous_families(self, arch):
        # command-r: full-KV GQA (H=8, KVH=2); hymba: ring-KV + SSM;
        # llama4: MoE full-KV GQA
        outs = {}
        for layout in LAYOUTS:
            cfg = smoke_config(arch).replace(cache_layout=layout)
            _, outs[layout] = _generate(cfg)
        np.testing.assert_array_equal(outs["kernel"], outs["legacy"])

    @pytest.mark.parametrize("arch", ["command_r_plus_104b"])
    def test_paged(self, arch):
        outs = {}
        for layout in LAYOUTS:
            cfg = smoke_config(arch).replace(cache_layout=layout)
            _, outs[layout] = _generate(cfg, page_size=8)
        np.testing.assert_array_equal(outs["kernel"], outs["legacy"])

    def test_prefill_cache_is_the_same_tensor_transposed(self):
        cfg_k = smoke_config("command_r_plus_104b")
        cfg_l = cfg_k.replace(cache_layout="legacy")
        toks = {"tokens": jnp.asarray(
            np.random.default_rng(1).integers(0, cfg_k.vocab, (2, 10)),
            jnp.int32)}
        model_k, params = _params(cfg_k)
        model_l = get_model(cfg_l)
        lg_k, cache_k = model_k.prefill(params, toks, max_len=16)
        lg_l, cache_l = model_l.prefill(params, toks, max_len=16)
        np.testing.assert_array_equal(np.asarray(lg_k), np.asarray(lg_l))
        # kernel (L,B,KVH,S,hd) <-> legacy (L,B,S,KVH,hd)
        for key in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(cache_k[key].transpose(0, 1, 3, 2, 4)),
                np.asarray(cache_l[key]))

    @pytest.mark.parametrize("paged", [False, True])
    def test_interpret_mode_pallas(self, paged):
        """The zero-copy Pallas dispatch (not just the XLA fallback) agrees
        across layouts, contiguous and paged, under GQA + ALiBi."""
        outs = {}
        for layout in LAYOUTS:
            cfg = smoke_config("command_r_plus_104b").replace(
                cache_layout=layout, attn_impl="pallas_interpret",
                attn_chunk=8)
            model, params = _params(cfg)
            if paged:
                cache = model.init_paged_cache(2, 8, 8, 4)
            else:
                cache = model.init_cache(2, 16)
            toks = {"tokens": jnp.asarray(
                np.random.default_rng(2).integers(0, cfg.vocab, (2, 8)),
                jnp.int32)}
            _, wave = model.prefill(params, toks, max_len=8)
            if paged:
                tables = np.full((2, 4), 8, np.int32)
                tables[0, 0], tables[1, 0] = 0, 1
                cache = model.insert_paged(cache, wave, np.arange(2),
                                           jnp.asarray(tables))
            else:
                pad = [(0, 0)] * wave["k"].ndim
                pad[3 if layout == "kernel" else 2] = (0, 8)
                wave = dict(wave, k=jnp.pad(wave["k"], pad),
                            v=jnp.pad(wave["v"], pad))
                cache = model.insert_cache(cache, wave, np.arange(2))
            step_tokens = jnp.asarray([[3], [5]], jnp.int32)
            seq = []
            for _ in range(3):
                lg, cache = model.decode(params, cache, step_tokens)
                step_tokens = jnp.argmax(lg[:, 0], -1)[:, None].astype(
                    jnp.int32)
                seq.append(np.asarray(step_tokens))
            outs[layout] = np.concatenate(seq, 1)
        np.testing.assert_array_equal(outs["kernel"], outs["legacy"])

    def test_contiguous_cache_lane_padded_at_allocation_for_pallas(self):
        """stablelm-class head dims (not 128-multiples) must be lane-padded
        ONCE at allocation when Pallas runs — a raw-hd cache would be
        re-padded per decode step, the exact Θ(pool) cost this PR deletes.
        XLA backends keep raw hd (their einsums read unpadded directly);
        ring caches stay raw (dense XLA window path). Prefill must emit
        the same width so insert_cache lines up."""
        cfg = smoke_config("stablelm_12b").replace(  # hd=40: not aligned
            attn_impl="pallas_interpret", attn_chunk=8)
        model, params = _params(cfg)
        assert model.init_cache(2, 24)["k"].shape[-1] == 128
        toks = {"tokens": jnp.asarray(
            np.random.default_rng(3).integers(0, cfg.vocab, (2, 6)),
            jnp.int32)}
        _, cache = model.prefill(params, toks, max_len=24)
        assert cache["k"].shape[-1] == 128
        assert np.all(np.asarray(cache["k"][..., 40:]) == 0)   # inert pad
        cfg_xla = cfg.replace(attn_impl="xla")
        assert get_model(cfg_xla).init_cache(2, 24)["k"].shape[-1] == 40
        cfg_ring = smoke_config("hymba_15b").replace(
            attn_impl="pallas_interpret")
        assert get_model(cfg_ring).init_cache(2, 64)["k"].shape[-1] \
            == cfg_ring.resolved_head_dim

    def test_preempt_resume_parity(self):
        """Auto-preemption under lazy paging (tiny pool) resumes to the
        same tokens in both layouts."""
        outs, preempts = {}, {}
        for layout in LAYOUTS:
            cfg = smoke_config("command_r_plus_104b").replace(
                cache_layout=layout)
            eng, out = _generate(cfg, page_size=4, steps=10, prompt_len=4,
                                 n_pages=5, pages_per_slot=5)
            outs[layout] = out
            preempts[layout] = eng.n_preemptions
        assert preempts["kernel"] > 0, "pool never ran dry: test is vacuous"
        assert preempts["kernel"] == preempts["legacy"]
        np.testing.assert_array_equal(outs["kernel"], outs["legacy"])


class TestPagedGatherCap:
    """ISSUE 5 satellite: the paged XLA fallback gathers at most
    ceil(max(lengths)/page_size) pages, not the full table width."""

    def test_static_cap_resolution(self):
        lengths = jnp.asarray([5, 17, 9], jnp.int32)
        assert ops._static_page_cap(lengths, 8, 64, None) == 3
        assert ops._static_page_cap(lengths, 8, 2, None) == 2   # clamped
        assert ops._static_page_cap(lengths, 8, 64, 7) == 7     # explicit
        assert ops._static_page_cap(jnp.zeros((2,), jnp.int32), 8, 64,
                                    None) == 1

    def test_traced_lengths_fall_back_to_table_width(self):
        caps = []

        def f(lengths):
            caps.append(ops._static_page_cap(lengths, 8, 64, None))
            return lengths

        jax.jit(f)(jnp.asarray([5, 17], jnp.int32))
        assert caps == [64]

    @pytest.mark.parametrize("kv_layout", ["bshd", "bhsd"])
    def test_wide_garbage_table_cannot_leak(self, kv_layout):
        """A page table far wider than any request, holding garbage ids
        past the mapped prefix, yields the same output as the exact one —
        the capped gather plus clamping discards all of it."""
        B, S, H, KVH, D, PS = 2, 32, 4, 2, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        k = jax.random.normal(ks[0], (B, S, KVH, D))
        v = jax.random.normal(ks[1], (B, S, KVH, D))
        q = jax.random.normal(ks[2], (B, 1, H, D))
        lengths = jnp.asarray([S, 13], jnp.int32)
        slopes = jnp.asarray(0.5 ** np.arange(1, H + 1), jnp.float32)
        p = S // PS
        if kv_layout == "bhsd":   # pools (KVH, n_pages, PS, D), page b*p+j
            kp, vp = [x.transpose(0, 2, 1, 3).reshape(B, KVH, p, PS, D)
                      .transpose(1, 0, 2, 3, 4).reshape(KVH, B * p, PS, D)
                      for x in (k, v)]
        else:                     # pools (n_pages, PS, KVH, D), page b*p+j
            kp, vp = [x.reshape(B * p, PS, KVH, D) for x in (k, v)]
        pt_exact = jnp.arange(B)[:, None] * p + jnp.arange(p)[None]
        pt_wide = jnp.concatenate(
            [pt_exact, jnp.full((B, 13), 10_000, jnp.int32)], axis=1)
        kw = {"slopes": slopes, "impl": "xla", "kv_layout": kv_layout}
        want = ops.flash_decode(q, kp, vp, lengths, page_table=pt_exact, **kw)
        got = ops.flash_decode(q, kp, vp, lengths, page_table=pt_wide, **kw)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_engine_page_cap_is_pow2_of_longest(self):
        cfg = smoke_config("stablelm_12b")
        model, params = _params(cfg)
        eng = ServeEngine(model, params, max_len=32, n_slots=2,
                          prefill_len=6, page_size=4)
        assert eng._page_cap() == 1                  # nothing live yet
        for p in _prompts(cfg, 2, 6):
            eng.submit(p, 8)
        eng.admit()
        # longest live length 6 -> needs ceil(7/4)=2 pages -> pow2 cap 2
        assert eng._page_cap() == 2
        eng.run()
