"""Paged KV serve engine (ISSUE 3): page-pool allocator unit tests,
paged-vs-contiguous greedy parity for every cache family under staggered
arrivals, OOM admission backpressure, the removal of the PR-2
``prompt + budget <= max_len`` bound, and the retired-slot freeze (stale
page tables must never scribble on reallocated pages)."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import PagePool, ServeEngine
from repro.serve.lifecycle import AdmissionRejected, PoolError

PF = 12


def _model(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab, (n,)).astype(np.int32) for n in lens]


def _alone(model, params, prompt, budget, **kw):
    eng = ServeEngine(model, params, **kw)
    rid = eng.submit(prompt, budget)
    eng.run()
    return eng.result(rid)


# ---------------------------------------------------------------------------
# PagePool allocator
# ---------------------------------------------------------------------------

class TestPagePool:
    def test_alloc_free_accounting(self):
        pool = PagePool(8, 4)
        assert pool.n_free == 8
        a = pool.alloc(3)
        assert sorted(a) == [0, 1, 2] and pool.n_free == 5
        b = pool.alloc(2)
        assert sorted(b) == [3, 4] and pool.n_free == 3
        pool.free(a)
        assert pool.n_free == 6

    def test_fragmented_free_list_reuses_lowest_first(self):
        pool = PagePool(6, 4)
        a, b, c = pool.alloc(2), pool.alloc(2), pool.alloc(2)
        pool.free(a)            # holes at 0,1
        pool.free(c)            # holes at 4,5
        got = pool.alloc(3)     # spans both holes — pages need not be
        assert got == [0, 1, 4]  # contiguous, lowest indices first
        assert pool.n_free == 1
        pool.free(got + b)
        assert pool.n_free == 6

    def test_oom_raises_and_can_alloc_gates(self):
        pool = PagePool(4, 16)
        pool.alloc(3)
        assert pool.can_alloc(1) and not pool.can_alloc(2)
        with pytest.raises(MemoryError):
            pool.alloc(2)
        assert pool.n_free == 1       # failed alloc takes nothing

    def test_double_free_and_double_alloc_guards(self):
        pool = PagePool(4, 8)
        a = pool.alloc(2)
        pool.free(a)
        with pytest.raises(PoolError):
            pool.free(a)

    def test_pages_needed(self):
        pool = PagePool(8, 16)
        assert pool.pages_needed(1) == 1
        assert pool.pages_needed(16) == 1
        assert pool.pages_needed(17) == 2
        assert pool.pages_needed(0) == 1      # a slot always owns a page


# ---------------------------------------------------------------------------
# Paged engine vs contiguous engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["stablelm_12b", "hymba_15b", "mamba2_130m"])
def test_paged_matches_contiguous_staggered(arch):
    """Greedy outputs must be identical for every cache family. stablelm
    (dense full KV) actually pages; hymba (ring + SSM) and mamba2 (SSM)
    have constant-size caches, so ``page_size`` must be a no-op for them."""
    cfg, model, params = _model(arch)
    kw = {"max_len": 48, "n_slots": 2, "prefill_len": 11}
    prompts = _prompts(cfg, (4, 11, 7), seed=2)
    budgets = [7, 4, 6]

    def run(extra):
        eng = ServeEngine(model, params, **kw, **extra)
        rids = [eng.submit(prompts[0], budgets[0]),
                eng.submit(prompts[1], budgets[1])]
        eng.step()
        eng.step()
        rids.append(eng.submit(prompts[2], budgets[2]))   # mid-flight arrival
        eng.run()
        return eng, [eng.result(r) for r in rids]

    eng_c, out_c = run({})
    eng_p, out_p = run({"page_size": 16})
    assert eng_p._paged == (arch == "stablelm_12b")
    for i, (c, p) in enumerate(zip(out_c, out_p)):
        np.testing.assert_array_equal(c, p, err_msg=f"{arch} request {i}")
    if eng_p._paged:        # drained engine must have returned every page
        assert eng_p._pool.n_free == eng_p.n_pages


def test_hybrid_full_kv_pages_with_ssm_slot_leaves():
    """A window-less hybrid pages its KV while the SSM state / conv tails
    keep the slot discipline — both travel through one ``insert_paged``."""
    cfg, model, params = _model("hymba_15b")
    cfg = cfg.replace(window=0)
    model = get_model(cfg)
    prompts = _prompts(cfg, (5, 9), seed=9)
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 10}
    out_c = ServeEngine(model, params, **kw).generate(prompts, 5)
    eng_p = ServeEngine(model, params, page_size=8, **kw)
    assert eng_p._paged and "ssm_h" in eng_p.model.init_paged_cache(2, 8, 8)
    np.testing.assert_array_equal(out_c, eng_p.generate(prompts, 5))


def test_moe_paged_matches_contiguous():
    cfg, model, params = _model("granite_moe_3b_a800m")
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 8}
    prompts = _prompts(cfg, (5, 8), seed=3)
    eng_c = ServeEngine(model, params, **kw)
    eng_p = ServeEngine(model, params, page_size=8, **kw)
    assert eng_p._paged
    np.testing.assert_array_equal(eng_c.generate(prompts, 4),
                                  eng_p.generate(prompts, 4))


def test_paged_accepts_request_beyond_max_len():
    """The PR-2 engine asserts on prompt + budget > max_len; the paged
    engine admits it as long as its pages fit the pool."""
    cfg, model, params = _model("stablelm_12b")
    prompt = _prompts(cfg, (40,), seed=4)[0]
    eng_c = ServeEngine(model, params, max_len=48, n_slots=2)
    with pytest.raises(AdmissionRejected):
        eng_c.submit(prompt, 40)                  # 40 + 40 > 48
    eng_p = ServeEngine(model, params, max_len=48, n_slots=2, page_size=16,
                        n_pages=8)
    rid = eng_p.submit(prompt, 40)                # needs 5 of 8 pages
    eng_p.run()
    assert eng_p.result(rid).size == 40
    assert eng_p._pool.n_free == eng_p.n_pages

    # a request that can NEVER fit its page-table row is rejected up front
    with pytest.raises(AdmissionRejected):
        eng_p.submit(_prompts(cfg, (100,), seed=5)[0], 100)


def test_oom_admission_backpressure():
    """Pool sized for ~one request: free-page gating (and, under the
    default lazy reservation, mid-flight preemption when growth finds the
    pool dry) must keep the traffic within the pool, in FIFO order, and
    every request still completes with its alone-run output."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 32, "n_slots": 2, "prefill_len": 10, "page_size": 8, "n_pages": 3}
    prompts = _prompts(cfg, (7, 9, 5), seed=6)
    budget = 6                                    # ceil((9+6-1)/8) = 2 pages
    eng = ServeEngine(model, params, **kw)
    rids = [eng.submit(p, budget) for p in prompts]
    max_occ = 0
    while eng.occupancy or len(eng.scheduler):
        eng.step()
        max_occ = max(max_occ, eng.occupancy)
    # 3 pages can hold at most one 2-page footprint plus one 1-page
    # footprint at a time; lazy growth may overlap prompts but preemption
    # keeps concurrent footprints within the pool
    assert max_occ <= 2
    for rid, p in zip(rids, prompts):
        alone = _alone(model, params, p, budget, **kw)
        np.testing.assert_array_equal(eng.result(rid), alone)
    assert eng._pool.n_free == eng.n_pages


# ---------------------------------------------------------------------------
# Retired-slot freeze (the idle-lane corruption class)
# ---------------------------------------------------------------------------

def test_retired_slot_is_frozen_and_reusable():
    """Regression (ISSUE 3): retired/free slots used to keep advancing
    ``cache["length"]`` and writing garbage KV on every engine step. Under
    paging the stale page table points at pages that get reallocated to
    other requests, so an unfrozen idle lane corrupts ANOTHER request's
    cache. Retire -> many steps -> reuse must leave every output equal to
    its alone run, and the freed slot's length must stay pinned at 0."""
    cfg, model, params = _model("stablelm_12b")
    kw = {"max_len": 64, "n_slots": 2, "prefill_len": PF, "page_size": 16}
    prompts = _prompts(cfg, (5, 9, 7), seed=7)

    eng = ServeEngine(model, params, **kw)
    r0 = eng.submit(prompts[0], 3)      # retires early
    r1 = eng.submit(prompts[1], 40)     # keeps decoding (> page_size steps,
    eng.step()                          # so an unfrozen idle lane would
    while not eng.is_done(r0):          # cross page boundaries)
        eng.step()
    free_slot = eng._free[0]
    for _ in range(20):                 # idle slot rides 20 full-batch steps
        eng.step()
        assert int(np.asarray(eng._cache["length"])[free_slot]) == 0
    r2 = eng.submit(prompts[2], 6)      # reuses the slot (and r0's pages)
    eng.run()

    for rid, prompt, budget in ((r0, prompts[0], 3), (r1, prompts[1], 40),
                                (r2, prompts[2], 6)):
        alone = _alone(model, params, prompt, budget, **kw)
        np.testing.assert_array_equal(eng.result(rid), alone)


def test_retired_slot_frozen_contiguous_too():
    """The same freeze applies without paging: a freed slot's length stays
    0 (it used to grow without bound, walking scatter indices past the
    segment) and its KV rows stop changing between retire and reuse."""
    cfg, model, params = _model("stablelm_12b")
    eng = ServeEngine(model, params, max_len=48, n_slots=2, prefill_len=PF)
    r0 = eng.submit(_prompts(cfg, (5,), seed=8)[0], 2)
    r1 = eng.submit(_prompts(cfg, (9,), seed=8)[0], 30)
    while not eng.is_done(r0):
        eng.step()
    slot = eng._free[0]
    k_before = np.asarray(eng._cache["k"][:, slot])
    for _ in range(10):
        eng.step()
        assert int(np.asarray(eng._cache["length"])[slot]) == 0
    np.testing.assert_array_equal(np.asarray(eng._cache["k"][:, slot]),
                                  k_before)
