"""Unit tests for repro.dist: context handling, rule overrides, and the
axis-dropping that lets one rule set drive 1D/2D/3D meshes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import (
    Rules,
    batch_axes_for,
    constrain,
    get_active_mesh,
    shard_put,
    spec_for,
    use_mesh_rules,
)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mesh(*axes):
    return jax.make_mesh((1,) * len(axes), axes)


class TestConstrainNoMesh:
    def test_identity_outside_context(self):
        x = jnp.arange(6.0).reshape(2, 3)
        assert get_active_mesh() is None
        y = constrain(x, "batch", "seq")
        assert y is x                       # literally a no-op, not a copy

    def test_applies_under_active_mesh(self):
        x = jnp.arange(6.0).reshape(2, 3)
        with use_mesh_rules(_mesh("data", "model"), Rules()):
            y = constrain(x, "batch", None)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_rank_mismatch_raises(self):
        x = jnp.zeros((2, 3))
        with use_mesh_rules(_mesh("data", "model"), Rules()):
            with pytest.raises(ValueError, match="rank-2"):
                constrain(x, "batch")


class TestUseMeshRules:
    def test_nesting_and_restoration(self):
        m1, m2 = _mesh("data", "model"), _mesh("pod", "data", "model")
        r1, r2 = Rules(), Rules.make({"seq": ("model",)})
        assert get_active_mesh() is None
        with use_mesh_rules(m1, r1):
            assert get_active_mesh() == (m1, r1)
            with use_mesh_rules(m2, r2):
                assert get_active_mesh() == (m2, r2)
            assert get_active_mesh() == (m1, r1)   # inner exit restores
        assert get_active_mesh() is None

    def test_restored_on_exception(self):
        with pytest.raises(RuntimeError):
            with use_mesh_rules(_mesh("data"), Rules()):
                raise RuntimeError("boom")
        assert get_active_mesh() is None


class TestRulesMake:
    def test_defaults(self):
        r = Rules()
        assert r.mesh_axes("fsdp") == ("data",)
        assert r.mesh_axes("heads") == ("model",)
        assert r.mesh_axes("batch") == ("pod", "data")
        assert r.mesh_axes("seq") is None

    def test_make_none_is_default(self):
        assert Rules.make(None) == Rules()

    def test_override_string_normalizes_to_tuple(self):
        r = Rules.make({"heads": "model_a"})
        assert r.mesh_axes("heads") == ("model_a",)

    def test_override_to_replicated(self):
        r = Rules.make({"heads": None, "mlp": None})
        assert r.mesh_axes("heads") is None
        assert r.mesh_axes("mlp") is None
        assert r.mesh_axes("vocab") == ("model",)   # untouched default

    def test_new_vocabulary_and_unknown_axes(self):
        r = Rules.make({"kv_seq": ("model",)})
        assert r.mesh_axes("kv_seq") == ("model",)
        assert r.mesh_axes("never_heard_of_it") is None
        assert r.mesh_axes(None) is None

    def test_immutable(self):
        r = Rules()
        with pytest.raises(AttributeError):
            r.table_entry = {}


class TestSpecForAxisDropping:
    def test_1d_mesh_drops_model_and_pod(self):
        mesh = _mesh("data")
        r = Rules()
        # heads -> ("model",): model absent -> replicated
        assert spec_for(("fsdp", "heads"), mesh, r) == P(("data",), None)
        # batch -> ("pod", "data"): pod absent -> ("data",)
        assert spec_for(("batch",), mesh, r) == P(("data",))

    def test_2d_mesh_drops_pod(self):
        mesh = _mesh("data", "model")
        assert spec_for(("batch", "seq", "mlp"), mesh, Rules()) == \
            P(("data",), None, ("model",))

    def test_3d_mesh_keeps_everything(self):
        mesh = _mesh("pod", "data", "model")
        assert spec_for(("batch", None, "vocab"), mesh, Rules()) == \
            P(("pod", "data"), None, ("model",))

    def test_duplicate_mesh_axis_first_wins(self):
        # sequence parallelism: seq and mlp both want "model"; the second
        # use must drop or the spec would be invalid (axis used twice)
        mesh = _mesh("data", "model")
        r = Rules.make({"seq": ("model",)})
        assert spec_for(("batch", "seq", "mlp"), mesh, r) == \
            P(("data",), ("model",), None)

    def test_batch_axes_divisibility(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        r = Rules()
        # dp product is 1 -> replication regardless of batch
        assert batch_axes_for(8, mesh, r) == P(None)
        assert batch_axes_for(1, mesh, r) == P(None)


class TestShardPut:
    """Host-side placement of persistent serve state (ISSUE 7): one
    logical axis per array dim, with the same divisibility degradation as
    ``batch_axes_for`` so arbitrary n_slots / head counts always place."""

    def test_places_with_resolved_spec(self):
        # size-1 mesh axes shard trivially — the resolved spec keeps its
        # names (no degradation needed: every dim divides 1)
        mesh = _mesh("data", "model")
        x = shard_put(np.zeros((4, 8)), mesh, Rules(), ("batch", "kv_heads"))
        assert x.sharding == NamedSharding(mesh, P(("data",), ("model",)))

    def test_rank_mismatch_raises(self):
        mesh = _mesh("data", "model")
        with pytest.raises(ValueError, match="rank-2"):
            shard_put(np.zeros((4, 8)), mesh, Rules(), ("batch",))

    def test_non_divisible_dims_degrade_to_replicated(self):
        # a real 2-device model axis: kv_heads=3 does not divide 2 ->
        # that dim replicates instead of erroring, divisible dims shard
        if len(jax.devices()) < 2:
            pytest.skip("needs 2 devices")
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        ok = shard_put(np.zeros((4, 2, 5)), mesh, Rules(),
                       (None, "kv_heads", None))
        assert ok.sharding.spec == P(None, ("model",), None)
        odd = shard_put(np.zeros((4, 3, 5)), mesh, Rules(),
                        (None, "kv_heads", None))
        assert odd.sharding.spec == P(None, None, None)
