"""Benchmark helpers.

IMPORTANT (DESIGN.md §Changed assumptions): this container is CPU-only, so
wall-clock numbers are *relative A/B comparisons* between execution paths of
the same workload, NOT TPU performance. TPU performance is derived
analytically in EXPERIMENTS.md §Roofline from the compiled dry-run.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

__all__ = ["time_fn", "Row", "print_rows"]


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of ``fn(*args)`` (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        times.append(time.monotonic() - t0)
    times.sort()
    return times[len(times) // 2]


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name, self.us, self.derived = name, us_per_call, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def print_rows(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
