"""Benchmark helpers.

IMPORTANT (DESIGN.md §Changed assumptions): this container is CPU-only, so
wall-clock numbers are *relative A/B comparisons* between execution paths of
the same workload, NOT TPU performance. TPU performance is derived
analytically in EXPERIMENTS.md §Roofline from the compiled dry-run.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

__all__ = ["time_fn", "Row", "print_rows", "rows_main"]


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of ``fn(*args)`` (block_until_ready'd)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        times.append(time.monotonic() - t0)
    times.sort()
    return times[len(times) // 2]


class Row:
    def __init__(self, name: str, us_per_call: float, derived: str = ""):
        self.name, self.us, self.derived = name, us_per_call, derived

    def csv(self) -> str:
        return f"{self.name},{self.us:.1f},{self.derived}"


def print_rows(rows):
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())


def rows_main(run_fn, default_out: str, argv=None) -> None:
    """Shared ``--smoke`` / ``--out`` CLI for row-emitting benchmarks:
    ``run_fn(smoke=...)`` produces Rows, written as JSON (uploaded with
    the CI BENCH artifact) and printed as CSV."""
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=default_out)
    args = ap.parse_args(argv)
    rows = run_fn(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump({"rows": [{"name": r.name, "us_per_call": r.us,
                             "derived": r.derived} for r in rows]}, f,
                  indent=2)
    print_rows(rows)
    print(f"# wrote {args.out}")
