# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure:

=====================  ==========================================
module                 paper artifact
=====================  ==========================================
bench_overall          Fig. 3/4  (overall efficiency vs seq len)
bench_alibi            Table 3   (GPT-2 + ALiBi, delta cost of bias)
bench_svd_swin         Table 4 + Fig. 6/8/9 (SwinV2 SVD)
bench_pde              Table 5   (PDE solver, learnable bias)
bench_neural           Table 6 / Fig. 7 + App. G (neural decomp)
bench_io_model         Thm 3.1/3.2, Cor 3.7, Ex. 3.9 (IO model)
bench_kernels          Fig. 5    (implementation choices / parity)
bench_serve            [beyond-paper] continuous-batching engine
                       throughput; also emits BENCH_serve.json
=====================  ==========================================

CPU container: wall-clock values are relative A/B only; TPU numbers live in
EXPERIMENTS.md §Roofline (from the compiled dry-run).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_alibi, bench_io_model, bench_kernels,
                            bench_neural, bench_overall, bench_pde,
                            bench_serve, bench_svd_swin)
    from benchmarks.common import print_rows

    modules = [bench_io_model, bench_overall, bench_alibi, bench_svd_swin,
               bench_pde, bench_neural, bench_kernels, bench_serve]
    rows = []
    failed = []
    for m in modules:
        name = m.__name__.split(".")[-1]
        print(f"# running {name} ...", file=sys.stderr)
        try:
            rows.extend(m.run())
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print_rows(rows)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
