"""Paper Table 6 / Fig. 7 (AlphaFold-3 Pairformer) + App. G (gravity /
spherical biases): the NEURAL decomposition.

- Pairformer-lite: fit factor MLPs (Eq. 5) against the pair-projected bias;
  report fit loss, dense-vs-FlashBias inference time, output drift.
- App. G: token-wise factor MLPs approximate gravity ``1/(d^2+eps)`` and
  spherical (haversine) distance biases; report reconstruction error.

    PYTHONPATH=src python -m benchmarks.bench_neural [--smoke] [--out PATH]

``--smoke`` shrinks the fit iteration counts for CI (which runs this every
push so the bench can't rot); ``--out`` writes the rows as
``BENCH_neural.json``, uploaded with the BENCH artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, rows_main, time_fn
from repro.configs import smoke_config
from repro.core import decomp
from repro.models import get_model
from repro.models import pairformer as pf_mod
from repro.models.common import init_params, stack_layers

DEFAULT_OUT = "BENCH_neural.json"


def _pairformer_rows(smoke=False):
    steps = 30 if smoke else 120
    cfg = smoke_config("pairformer_lite").replace(n_layers=4)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    feats = jax.random.normal(jax.random.PRNGKey(1), (1, 48, 64))

    fp0 = init_params(stack_layers(pf_mod.factor_mlp_template(cfg, hidden=48),
                                   cfg.n_layers), jax.random.PRNGKey(2))
    fp, losses = pf_mod.fit_factor_mlps(jax.random.PRNGKey(3), params, fp0,
                                        feats, cfg, steps=steps, lr=3e-3)
    rows = [Row("table6_fit_eq5", 0.0,
                f"loss {losses[0]:.4f}->{losses[-1]:.4f} ({steps} iters)")]

    dense_fn = jax.jit(lambda p, x: pf_mod.forward(
        p, x, cfg.replace(bias_mode="dense")))
    fb_fn = jax.jit(lambda p, x: pf_mod.forward(p, x, cfg, fp))
    t_d = time_fn(dense_fn, params, feats, iters=3)
    t_f = time_fn(fb_fn, params, feats, iters=3)
    drift = float(jnp.abs(fb_fn(params, feats)
                          - dense_fn(params, feats)).max())
    rows += [
        Row("table6_infer_dense_pairbias", t_d * 1e6, "official path"),
        Row("table6_infer_flashbias_neural", t_f * 1e6,
            f"output_drift={drift:.2e}; ratio={t_f / t_d:.3f}"),
    ]
    return rows


def _appg_rows(smoke=False):
    steps = 60 if smoke else 250
    rows = []
    key = jax.random.PRNGKey(0)

    def gravity(xq, xk):
        d2 = jnp.sum((xq[:, None] - xk[None]) ** 2, -1)
        return 1.0 / (d2 + 0.01)

    def spherical(xq, xk):
        lat1, lon1 = xq[:, None, 0], xq[:, None, 1]
        lat2, lon2 = xk[None, :, 0], xk[None, :, 1]
        h = (jnp.sin((lat1 - lat2) / 2) ** 2
             + jnp.cos(lat1) * jnp.cos(lat2) * jnp.sin((lon1 - lon2) / 2) ** 2)
        return 2 * jnp.arcsin(jnp.sqrt(jnp.clip(h, 0, 1)))

    for name, fn, box in (("gravity", gravity, (0.0, 1.0)),
                          ("spherical", spherical, (-1.5, 1.5))):
        params = decomp.neural_decomp_init(key, 2, 2, hidden=64, heads=1,
                                           rank=32)

        def sample(k, fn=fn, box=box):
            xq = jax.random.uniform(k, (48, 2), minval=box[0], maxval=box[1])
            return xq, xq, fn(xq, xq)[None]

        fitted, losses = decomp.fit_neural_decomposition(
            key, params, sample, steps=steps, lr=3e-3)
        xq, xk, target = sample(jax.random.PRNGKey(9))
        pred = decomp.predicted_bias(fitted, xq, xk)[0]
        rel = float(jnp.linalg.norm(pred - target[0])
                    / jnp.linalg.norm(target[0]))
        rows.append(Row(f"appG_{name}_fit", 0.0,
                        f"loss {float(losses[0]):.4f}->"
                        f"{float(losses[-1]):.4f}; rel_err={rel:.3f} (R=32)"))
    return rows


def run(smoke=False):
    return _pairformer_rows(smoke) + _appg_rows(smoke)


def main(argv=None):
    rows_main(lambda smoke: run(smoke=smoke), DEFAULT_OUT, argv)


if __name__ == "__main__":
    main()
