"""Paper Table 4 + Fig. 6/8/9 (SwinV2): SVD decomposition of learnable
relative-position bias tables.

Measures: offline SVD cost, per-rank retained energy (Fig. 6's "R keeps
99.5% energy" claim on trained-table surrogates), inference time of the
dense-table path vs the FlashBias-SVD path, and output drift vs rank
(Table 4's accuracy-preservation claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.configs import smoke_config
from repro.core.lowrank import retained_energy
from repro.models import get_model
from repro.models import swin as swin_mod
from repro.models.common import init_params


def _structured_tables(params):
    """Make bias tables low-rank-ish (trained Swin tables are; random init
    is full-rank): project onto a smooth relative-offset structure."""
    t = params["layers"]["bias_table"]
    l, h, w, _ = t.shape
    i = jnp.arange(w)[:, None]
    j = jnp.arange(w)[None, :]
    smooth = jnp.exp(-jnp.abs(i - j) / 8.0)             # distance decay
    mixed = 0.85 * smooth[None, None] + 0.15 * t * 0.1
    params = dict(params)
    params["layers"] = dict(params["layers"], bias_table=mixed)
    return params


def run():
    cfg = smoke_config("swinv2_b").replace(n_layers=4, window=64)
    model = get_model(cfg)
    params = _structured_tables(
        init_params(model.template(), jax.random.PRNGKey(0)))
    patches = jax.random.normal(jax.random.PRNGKey(1), (4, 4, cfg.window, 48))

    rows = []
    t0 = time.monotonic()
    factors_by_rank = {r: swin_mod.svd_factorize(params, rank=r)
                       for r in (4, 8, 16, cfg.window)}
    rows.append(Row("table4_offline_svd", (time.monotonic() - t0) * 1e6,
                    "one-time cost (paper: 4.79s for SwinV2-B)"))

    tables = params["layers"]["bias_table"].reshape(-1, cfg.window, cfg.window)
    for r in (4, 8, 16):
        e = retained_energy(tables, r)
        rows.append(Row(f"fig6_energy_rank{r}", 0.0,
                        f"retained_energy={e:.4f}"))

    dense_fn = jax.jit(lambda p, x: swin_mod.forward(
        p, x, cfg.replace(bias_mode="dense")))
    t_dense = time_fn(dense_fn, params, patches)
    out_dense = dense_fn(params, patches)
    rows.append(Row("table4_infer_dense_table", t_dense * 1e6, "official path"))

    for r in (8, 16, cfg.window):
        f = factors_by_rank[r]
        fb_fn = jax.jit(lambda p, x, f=f: swin_mod.forward(p, x, cfg, f))
        t_fb = time_fn(fb_fn, params, patches)
        drift = float(jnp.abs(fb_fn(params, patches) - out_dense).max())
        rows.append(Row(f"table4_infer_flashbias_r{r}", t_fb * 1e6,
                        f"output_drift={drift:.2e}; "
                        f"speed_ratio={t_fb / t_dense:.3f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
