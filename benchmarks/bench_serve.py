"""Serve-engine benchmark: prefill tokens/s + decode tokens/s vs occupancy.

Measures the continuous-batching engine end to end (admission prefill,
jitted slot-batch decode, sampling, host loop) at several slot
occupancies, and writes ``BENCH_serve.json`` — the first entry of the
serving perf trajectory. One engine serves every occupancy (pinned
``prefill_len`` + n_slots-padded waves mean one compiled prefill program),
so timings are warm after the first throwaway wave.

CPU container caveat (benchmarks/common.py): numbers are relative A/B
trends between occupancies, NOT TPU performance.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import Row

DEFAULT_OUT = "BENCH_serve.json"


def collect(arch: str = "stablelm_12b", n_slots: int = 8,
            prompt_len: int = 32, steps: int = 12,
            occupancies=(1, 4, 8), page_size: int = 0,
            page_reservation: str = "lazy") -> dict:
    """Run the engine at each occupancy; returns the BENCH_serve payload.

    ``page_size`` > 0 measures the PAGED engine (pool sized to the same HBM
    as the contiguous layout, table width = one contiguous segment so the
    per-step logical view matches). ``page_reservation`` picks the
    admission policy of the paged engine: ``"whole"`` reserves a request's
    full footprint at admit (PR-3, emitted as ``paged_points``), ``"lazy"``
    reserves only prompt pages and grows per page boundary, preempting on
    pool exhaustion (ISSUE 4, emitted as ``lazy_points``).
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    budget = steps + 4                       # never finishes mid-measurement
    max_len = prompt_len + budget + 8
    kw = {}
    if page_size:
        max_len = -(-max_len // page_size) * page_size
        kw = {"page_size": page_size,
              "pages_per_slot": max_len // page_size,
              "page_reservation": page_reservation}
    engine = ServeEngine(model, params, max_len=max_len,
                         n_slots=n_slots, prefill_len=prompt_len, **kw)
    rng = np.random.default_rng(0)

    def submit(n):
        return [engine.submit(
            rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32),
            budget) for _ in range(n)]

    # throwaway wave: compiles prefill/insert/decode/sample once
    submit(1)
    engine.run()

    result = {"arch": cfg.name, "n_slots": n_slots,
              "prompt_len": prompt_len, "decode_steps": steps, "points": []}
    for occ in occupancies:
        assert occ <= n_slots, (occ, n_slots)
        submit(occ)
        t0 = time.monotonic()
        engine.admit()
        t_admit = time.monotonic() - t0
        engine.decode(); engine.decode()     # decode warmup (already jitted)
        ts = []
        for _ in range(steps):
            t0 = time.monotonic()
            engine.decode()                  # _sample_and_commit syncs
            ts.append(time.monotonic() - t0)
        t_step = min(ts)                     # best observed step: on a
        engine.run()                         # contended CPU runner this is
        result["points"].append({            # the only stable estimate the
            "occupancy": occ,                # CI regression gate can band
            "prefill_tokens_per_s": occ * prompt_len / t_admit,
            "decode_tokens_per_s": occ / t_step,
        })
    if page_size:
        result["page_stats"] = engine.page_stats()
    return result


def _interleaved_decode_ab(engines: dict, vocab: int, prompt_len: int,
                           steps: int, occupancy: int) -> tuple:
    """Shared harness for one-occupancy interleaved decode A/Bs.

    Both engines serve the identical workload and alternate timed decode
    steps, so both see the same machine-load profile — the ratio stays
    meaningful on a noisy CPU runner where two back-to-back ``collect``
    calls can land in different load bursts. Min-based timing per engine
    (the only load-robust estimator on a shared runner). ONE harness
    serves every A/B gate, so a methodology change (warmup count,
    estimator, drain) can never skew one gated ratio and not the other.

    Returns ``(tokens_per_s, outputs)``: dicts keyed like ``engines``,
    with each engine's best-step throughput and per-request output arrays.
    """
    budget = steps + 4
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, vocab, (prompt_len,)).astype(np.int32)
               for _ in range(occupancy)]
    best, rids = {}, {}
    for mode, eng in engines.items():
        rids[mode] = [eng.submit(p, budget) for p in prompts]
        eng.admit()
        eng.decode(); eng.decode()           # warm (compile + first growth)
        best[mode] = float("inf")
    for _ in range(steps):                   # interleave: same load profile
        for mode, eng in engines.items():
            t0 = time.monotonic()
            eng.decode()
            best[mode] = min(best[mode], time.monotonic() - t0)
    for eng in engines.values():
        eng.run()
    tps = {mode: occupancy / t for mode, t in best.items()}
    outs = {mode: [engines[mode].result(r) for r in rids[mode]]
            for mode in engines}
    return tps, outs


def compare_lazy_whole(arch: str = "stablelm_12b", n_slots: int = 4,
                       prompt_len: int = 16, steps: int = 16,
                       occupancy: int = 4, page_size: int = 16) -> dict:
    """Interleaved lazy-vs-whole A/B at one occupancy (ISSUE 4 headline).

    The CI gate (scripts/check_bench.py) holds ``ratio`` to a tolerance
    band: lazy growth must sustain whole-request-reservation throughput.
    Timing methodology: ``_interleaved_decode_ab``.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    max_len = -(-(prompt_len + steps + 12) // page_size) * page_size
    engines = {}
    for mode in ("whole", "lazy"):
        engines[mode] = ServeEngine(
            model, params, max_len=max_len, n_slots=n_slots,
            prefill_len=prompt_len, page_size=page_size,
            pages_per_slot=max_len // page_size, page_reservation=mode)
    tps, _ = _interleaved_decode_ab(engines, cfg.vocab, prompt_len, steps,
                                    occupancy)
    return {"occupancy": occupancy, "page_size": page_size,
            "whole_decode_tokens_per_s": tps["whole"],
            "lazy_decode_tokens_per_s": tps["lazy"],
            "ratio": tps["lazy"] / tps["whole"]}


def compare_layout_legacy(arch: str = "stablelm_12b", n_slots: int = 4,
                          prompt_len: int = 16, steps: int = 16,
                          occupancy: int = 4, page_size: int = 16) -> dict:
    """Interleaved kernel-layout vs legacy-layout decode A/B (ISSUE 5).

    Two PAGED engines serve the identical workload from identical params:
    one with the default kernel-native cache layout (kv-head-major pools,
    zero-copy into the kernels, capped XLA gather), one with
    ``cache_layout="legacy"`` (canonical pools, per-step re-layout in
    ops). The CI gate (scripts/check_bench.py) holds ``ratio`` to a
    tolerance band around 1.0 — the kernel layout must never be slower
    than the transpose-per-step path it deleted. Timing methodology:
    ``_interleaved_decode_ab``.

    Output parity across layouts is recorded as ``outputs_identical``
    (and warned about), not asserted: the layouts' decode paths sum
    logits in different orders (concat-fold/chunked vs head-major
    einsums), so a vocab tie at ULP distance could legitimately flip one
    greedy argmax — a timing job shouldn't die on that. The HARD parity
    contract lives in tests/test_cache_layout.py, where seeds are pinned.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    base = smoke_config(arch)
    max_len = -(-(prompt_len + steps + 12) // page_size) * page_size
    engines = {}
    params = None
    for mode in ("legacy", "kernel"):
        cfg = base.replace(cache_layout=mode)
        model = get_model(cfg)
        if params is None:
            params = init_params(model.template(), jax.random.PRNGKey(0))
        engines[mode] = ServeEngine(
            model, params, max_len=max_len, n_slots=n_slots,
            prefill_len=prompt_len, page_size=page_size,
            pages_per_slot=max_len // page_size)
    tps, outs = _interleaved_decode_ab(engines, base.vocab, prompt_len,
                                       steps, occupancy)
    identical = all(np.array_equal(a, b)
                    for a, b in zip(outs["kernel"], outs["legacy"]))
    if not identical:
        print("# WARNING: layout A/B greedy outputs diverged (likely a "
              "ULP logit tie; hard parity is tested in "
              "tests/test_cache_layout.py)")
    return {"occupancy": occupancy, "page_size": page_size,
            "legacy_decode_tokens_per_s": tps["legacy"],
            "kernel_decode_tokens_per_s": tps["kernel"],
            "outputs_identical": identical,
            "ratio": tps["kernel"] / tps["legacy"]}


def compare_chunked_prefill(arch: str = "stablelm_12b", n_slots: int = 4,
                            prompt_len: int = 16, long_prompt: int = 192,
                            steps: int = 40, chunk: int = 16,
                            rounds: int = 3) -> dict:
    """Decode-step tail latency under concurrent long-prompt admission
    (ISSUE 7 headline A/B).

    Two engines serve the identical workload: ``n_slots - 1`` short
    requests decoding, and ``rounds`` long prompts arriving mid-run. The
    whole-prompt engine stalls every in-flight decode for a full
    ``long_prompt`` prefill in each step that admits one; the chunked
    engine amortizes the same prompts ``chunk`` tokens per step,
    interleaved with decode. Both engines' ``step()`` latencies are timed
    interleaved (same load profile); the gated metric is

        ratio = whole_p99 / chunked_p99

    — structurally >> 1 when chunking amortizes (the whole engine's tail
    IS its prefill stall) and ~1.0 if chunked admission ever degenerates
    into a monolithic prefill, which is exactly the regression the CI
    gate (scripts/check_bench.py) exists to catch. The tail estimator is
    the ``rounds``-th largest step: the whole engine stalls once per
    arrival round so one stall always survives the trim, while up to
    ``rounds - 1`` transient host hiccups in either engine's samples are
    discarded (max has no noise immunity; min would erase the signal).
    Compile warmup runs the full arrival pattern once per engine first,
    so no measured step is a jit compile.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    max_len = long_prompt + steps + 16
    engines = {
        "whole": ServeEngine(model, params, max_len=max_len,
                             n_slots=n_slots),
        "chunked": ServeEngine(model, params, max_len=max_len,
                               n_slots=n_slots, prefill_chunk=chunk),
    }
    budget = steps + 8
    spacing = max(1, steps // rounds)
    lat = {mode: [] for mode in engines}

    def submit_short(eng, rng):
        eng.submit(rng.integers(0, cfg.vocab,
                                (prompt_len,)).astype(np.int32), budget)

    def submit_long(eng, rng):
        eng.submit(rng.integers(0, cfg.vocab,
                                (long_prompt,)).astype(np.int32), 4)

    for eng in engines.values():             # compile warmup: full pattern
        rng = np.random.default_rng(0)
        for _ in range(n_slots - 1):
            submit_short(eng, rng)
        for _ in range(4):
            eng.step()
        submit_long(eng, rng)
        eng.run()

    # interleaved measurement: alternate per-step so both engines see the
    # same machine-load profile (a tail estimator has no min()-style
    # noise immunity, so load parity is what keeps the ratio meaningful)
    rngs = {m: np.random.default_rng(0) for m in engines}
    for mode, eng in engines.items():
        for _ in range(n_slots - 1):
            submit_short(eng, rngs[mode])
        for _ in range(4):                   # in-flight before arrivals
            eng.step()
    for i in range(steps):
        for mode, eng in engines.items():
            if i % spacing == 0 and i // spacing < rounds:
                submit_long(eng, rngs[mode])
            t0 = time.monotonic()
            eng.step()
            lat[mode].append(time.monotonic() - t0)
    for eng in engines.values():
        eng.run()

    def tail(xs):
        return float(sorted(xs)[-rounds])

    whole, chunked = tail(lat["whole"]), tail(lat["chunked"])
    return {"long_prompt": long_prompt, "chunk": chunk, "steps": steps,
            "rounds": rounds,
            "whole_p99_step_ms": 1e3 * whole,
            "chunked_p99_step_ms": 1e3 * chunked,
            "ratio": whole / chunked}


def compare_prefix_sharing(arch: str = "stablelm_12b", n_slots: int = 4,
                           n_requests: int = 64, shared_prefix: int = 512,
                           tail_len: int = 16, budget: int = 4,
                           page_size: int = 64) -> dict:
    """Shared-prefix admission throughput, prefix cache on vs off
    (ISSUE 9 headline A/B).

    ``n_requests`` requests share a ``shared_prefix``-token common prefix
    (system-prompt traffic) with short unique tails. Both engines are
    paged + chunked (chunk = page size); the cached engine maps each hit's
    page table onto the already-landed prefix pages and prefills ONLY the
    novel tail, so it retires the queue in ~1 chunk step per request where
    the uncached engine pays ``shared_prefix / page_size`` chunk steps
    each. Engines are stepped alternately until each drains, accumulating
    per-engine wall time — same load profile, per the
    ``_interleaved_decode_ab`` methodology (drain lengths differ, so this
    A/B times whole steps rather than reusing that harness). The gated
    metric is

        ratio = cached admission tokens/s / uncached admission tokens/s

    — structurally >= 2 when sharing works (the cache deletes ~8/9 of all
    prefill compute at the 64 x 512 point) and ~1.0 if admission ever
    stops matching, which is the regression the CI gate
    (scripts/check_bench.py) exists to catch. Both engines decode the
    same ``budget`` tokens per request, so decode work cancels in the
    ratio; outputs are compared and reported (``outputs_identical``) —
    the hard bit-parity contract lives in tests/test_prefix_cache.py.

    One compile warmup pair runs first THROUGH the cached engine's index
    (steady-state serving: the measured window starts with the prefix
    already resident, as every request after the first would see it).
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    max_len = shared_prefix + tail_len + budget + 8
    max_len = -(-max_len // page_size) * page_size
    engines = {}
    for mode in ("uncached", "cached"):
        engines[mode] = ServeEngine(
            model, params, max_len=max_len, n_slots=n_slots,
            prefill_len=shared_prefix + tail_len, page_size=page_size,
            pages_per_slot=max_len // page_size,
            prefill_chunk=page_size, prefix_cache=(mode == "cached"))

    rng = np.random.default_rng(0)
    common = rng.integers(0, cfg.vocab, (shared_prefix,)).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        0, cfg.vocab, (int(rng.integers(1, tail_len + 1)),)
    ).astype(np.int32)]) for _ in range(n_requests)]

    for eng in engines.values():             # compile warmup; also lands
        eng.submit(prompts[0], budget)       # the prefix in the cached
        eng.run()                            # engine's index (steady state)

    times = {mode: 0.0 for mode in engines}
    rids = {mode: [eng.submit(p, budget) for p in prompts]
            for mode, eng in engines.items()}
    live = dict(engines)
    while live:                              # alternate whole steps: same
        for mode, eng in list(live.items()): # load profile for both drains
            t0 = time.monotonic()
            eng.step()
            times[mode] += time.monotonic() - t0
            if not (len(eng.scheduler) or eng.occupancy):
                del live[mode]
    n_tok = sum(p.size for p in prompts)
    tps = {mode: n_tok / t for mode, t in times.items()}
    outs = {mode: [engines[mode].result(r) for r in rids[mode]]
            for mode in engines}
    identical = all(np.array_equal(a, b)
                    for a, b in zip(outs["cached"], outs["uncached"]))
    if not identical:
        print("# WARNING: prefix-sharing A/B greedy outputs diverged — "
              "sharing must be bit-exact; see tests/test_prefix_cache.py")
    pf = engines["cached"].page_stats()["prefix"]
    return {"n_requests": n_requests, "shared_prefix": shared_prefix,
            "page_size": page_size, "n_slots": n_slots,
            "uncached_admission_tokens_per_s": tps["uncached"],
            "cached_admission_tokens_per_s": tps["cached"],
            "hit_rate": pf["hit_rate"],
            "cow_copies": pf["cow_copies"],
            "evictions": pf["evictions"],
            "outputs_identical": identical,
            "ratio": tps["cached"] / tps["uncached"]}


def compare_guard_overhead(arch: str = "stablelm_12b", n_slots: int = 4,
                           prompt_len: int = 16, steps: int = 16,
                           occupancy: int = 4, page_size: int = 16) -> dict:
    """Decode throughput with the ISSUE-10 non-finite emission guards on
    vs off (interleaved A/B at the headline decode config).

    The guard's row-max reduction is fused into the sampling dispatch
    (``sample_tokens_guarded``) and its result rides the same host
    transfer as the tokens, so the guarded path keeps one device
    round-trip per step. The CI gate (scripts/check_bench.py) holds

        ratio = guarded decode tokens/s / unguarded decode tokens/s

    to >= 0.95: fault containment must cost at most 5% of decode
    throughput, or it doesn't get to default on. Timing methodology:
    ``_interleaved_decode_ab``. Outputs are also compared — on a healthy
    run the guard never trips, so committed tokens must be identical.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    max_len = -(-(prompt_len + steps + 12) // page_size) * page_size
    engines = {}
    for mode, guards in (("unguarded", False), ("guarded", True)):
        engines[mode] = ServeEngine(
            model, params, max_len=max_len, n_slots=n_slots,
            prefill_len=prompt_len, page_size=page_size,
            pages_per_slot=max_len // page_size, guards=guards)
    tps, outs = _interleaved_decode_ab(engines, cfg.vocab, prompt_len,
                                       steps, occupancy)
    identical = all(np.array_equal(a, b)
                    for a, b in zip(outs["guarded"], outs["unguarded"]))
    if not identical:
        print("# WARNING: guard A/B outputs diverged — a guard tripped on "
              "healthy logits; see tests/test_faults.py")
    return {"occupancy": occupancy, "page_size": page_size,
            "unguarded_decode_tokens_per_s": tps["unguarded"],
            "guarded_decode_tokens_per_s": tps["guarded"],
            "outputs_identical": identical,
            "ratio": tps["guarded"] / tps["unguarded"]}


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    """benchmarks/run.py entry: emit BENCH_serve.json + CSV rows."""
    kw = ({"n_slots": 4, "prompt_len": 16, "steps": 16,
           "occupancies": (1, 2, 4)}
          if smoke else {})
    data = collect(**kw)
    ps = 16 if smoke else 64
    data["page_size"] = ps
    whole = collect(page_size=ps, page_reservation="whole", **kw)
    lazy = collect(page_size=ps, page_reservation="lazy", **kw)
    data["paged_points"] = whole["points"]          # PR-3 whole-reservation
    data["lazy_points"] = lazy["points"]            # ISSUE-4 lazy growth
    data["lazy_page_stats"] = lazy["page_stats"]
    # the A/B pins page_size=16 regardless of the trajectory ps: with the
    # default prompt/steps a 64-token page is never outgrown, and an A/B
    # whose lazy engine never grows or preempts measures nothing
    data["lazy_vs_whole"] = compare_lazy_whole(
        **{k: v for k, v in kw.items() if k != "occupancies"},
        occupancy=max(kw.get("occupancies", (4,))))
    # ISSUE 5: kernel-native vs legacy cache layout, measured not asserted.
    # Pinned to a page-dense shape (long decode, small pages) regardless of
    # smoke: the layouts differ in per-step pool/view handling, so the A/B
    # needs enough pages in flight for that term to rise above host noise
    # (at steps=16/ps=16 every slot holds ~2 pages and the ratio is noise).
    data["layout_vs_legacy"] = compare_layout_legacy(
        **{k: v for k, v in kw.items() if k not in ("occupancies", "steps")},
        steps=64, page_size=8,
        occupancy=max(kw.get("occupancies", (4,))))
    # ISSUE 7: decode-step tail latency under a concurrent long-prompt
    # arrival — whole-prompt admission stalls the batch for one full
    # prefill, chunked admission amortizes it one chunk per step. The
    # long prompt stays long even in smoke: the stall IS the measurement.
    data["chunked_prefill"] = compare_chunked_prefill(
        **{k: v for k, v in kw.items() if k not in ("occupancies", "steps")},
        steps=24 if smoke else 40,
        long_prompt=128 if smoke else 192)
    # ISSUE 10: decode throughput with non-finite emission guards on vs
    # off — containment must stay within 5% of the unguarded engine.
    # steps pinned to 64 regardless of smoke (like the layout A/B): the
    # true per-step delta is small, and min-over-16 samples on a shared
    # CPU runner leaves ~5% jitter in the ratio — the gate's whole budget.
    data["guard_overhead"] = compare_guard_overhead(
        **{k: v for k, v in kw.items() if k not in ("occupancies", "steps")},
        steps=64, occupancy=max(kw.get("occupancies", (4,))))
    # ISSUE 9: shared-prefix admission throughput, prefix cache on vs off.
    # Deliberately NOT smoke-reduced: the acceptance point is 64 requests
    # over a 512-token common prefix, and shrinking either would gate a
    # different regime (short prefixes hide the chunk-step savings).
    data["prefix_sharing"] = compare_prefix_sharing()
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    rows = []
    for tag, points in (("", data["points"]),
                        ("_paged", data["paged_points"]),
                        ("_lazy", data["lazy_points"])):
        for p in points:
            occ = p["occupancy"]
            rows.append(Row(f"serve_prefill{tag}_occ{occ}",
                            1e6 / max(p["prefill_tokens_per_s"], 1e-9),
                            f"{p['prefill_tokens_per_s']:.1f}tok/s"))
            rows.append(Row(f"serve_decode{tag}_occ{occ}",
                            1e6 / max(p["decode_tokens_per_s"], 1e-9),
                            f"{p['decode_tokens_per_s']:.1f}tok/s"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    for r in rows:
        print(r.csv())
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
