"""Serve-engine benchmark: prefill tokens/s + decode tokens/s vs occupancy.

Measures the continuous-batching engine end to end (admission prefill,
jitted slot-batch decode, sampling, host loop) at several slot
occupancies, and writes ``BENCH_serve.json`` — the first entry of the
serving perf trajectory. One engine serves every occupancy (pinned
``prefill_len`` + n_slots-padded waves mean one compiled prefill program),
so timings are warm after the first throwaway wave.

CPU container caveat (benchmarks/common.py): numbers are relative A/B
trends between occupancies, NOT TPU performance.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import Row

DEFAULT_OUT = "BENCH_serve.json"


def collect(arch: str = "stablelm_12b", n_slots: int = 8,
            prompt_len: int = 32, steps: int = 12,
            occupancies=(1, 4, 8), page_size: int = 0,
            page_reservation: str = "lazy") -> dict:
    """Run the engine at each occupancy; returns the BENCH_serve payload.

    ``page_size`` > 0 measures the PAGED engine (pool sized to the same HBM
    as the contiguous layout, table width = one contiguous segment so the
    per-step logical view matches). ``page_reservation`` picks the
    admission policy of the paged engine: ``"whole"`` reserves a request's
    full footprint at admit (PR-3, emitted as ``paged_points``), ``"lazy"``
    reserves only prompt pages and grows per page boundary, preempting on
    pool exhaustion (ISSUE 4, emitted as ``lazy_points``).
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    budget = steps + 4                       # never finishes mid-measurement
    max_len = prompt_len + budget + 8
    kw = {}
    if page_size:
        max_len = -(-max_len // page_size) * page_size
        kw = dict(page_size=page_size,
                  pages_per_slot=max_len // page_size,
                  page_reservation=page_reservation)
    engine = ServeEngine(model, params, max_len=max_len,
                         n_slots=n_slots, prefill_len=prompt_len, **kw)
    rng = np.random.default_rng(0)

    def submit(n):
        return [engine.submit(
            rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32),
            budget) for _ in range(n)]

    # throwaway wave: compiles prefill/insert/decode/sample once
    submit(1)
    engine.run()

    result = {"arch": cfg.name, "n_slots": n_slots,
              "prompt_len": prompt_len, "decode_steps": steps, "points": []}
    for occ in occupancies:
        assert occ <= n_slots, (occ, n_slots)
        submit(occ)
        t0 = time.monotonic()
        engine.admit()
        t_admit = time.monotonic() - t0
        engine.decode(); engine.decode()     # decode warmup (already jitted)
        ts = []
        for _ in range(steps):
            t0 = time.monotonic()
            engine.decode()                  # _sample_and_commit syncs
            ts.append(time.monotonic() - t0)
        t_step = min(ts)                     # best observed step: on a
        engine.run()                         # contended CPU runner this is
        result["points"].append({            # the only stable estimate the
            "occupancy": occ,                # CI regression gate can band
            "prefill_tokens_per_s": occ * prompt_len / t_admit,
            "decode_tokens_per_s": occ / t_step,
        })
    if page_size:
        result["page_stats"] = engine.page_stats()
    return result


def compare_lazy_whole(arch: str = "stablelm_12b", n_slots: int = 4,
                       prompt_len: int = 16, steps: int = 16,
                       occupancy: int = 4, page_size: int = 16) -> dict:
    """Interleaved lazy-vs-whole A/B at one occupancy (ISSUE 4 headline).

    Two paged engines serve the identical workload and alternate timed
    decode steps, so both see the same machine-load profile — the ratio
    stays meaningful on a noisy CPU runner where two back-to-back
    ``collect`` calls can land in different load bursts. The CI gate
    (scripts/check_bench.py) holds ``ratio`` to a tolerance band: lazy
    growth must sustain whole-request-reservation throughput.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    budget = steps + 4
    max_len = -(-(prompt_len + budget + 8) // page_size) * page_size
    engines = {}
    for mode in ("whole", "lazy"):
        engines[mode] = ServeEngine(
            model, params, max_len=max_len, n_slots=n_slots,
            prefill_len=prompt_len, page_size=page_size,
            pages_per_slot=max_len // page_size, page_reservation=mode)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
               for _ in range(occupancy)]
    best = {}
    for mode, eng in engines.items():
        for p in prompts:
            eng.submit(p, budget)
        eng.admit()
        eng.decode(); eng.decode()           # warm (compile + first growth)
        best[mode] = float("inf")
    for _ in range(steps):                   # interleave: same load profile
        for mode, eng in engines.items():
            t0 = time.monotonic()
            eng.decode()
            best[mode] = min(best[mode], time.monotonic() - t0)
    for eng in engines.values():
        eng.run()
    whole_tps = occupancy / best["whole"]
    lazy_tps = occupancy / best["lazy"]
    return {"occupancy": occupancy, "page_size": page_size,
            "whole_decode_tokens_per_s": whole_tps,
            "lazy_decode_tokens_per_s": lazy_tps,
            "ratio": lazy_tps / whole_tps}


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    """benchmarks/run.py entry: emit BENCH_serve.json + CSV rows."""
    kw = (dict(n_slots=4, prompt_len=16, steps=16, occupancies=(1, 2, 4))
          if smoke else {})
    data = collect(**kw)
    ps = 16 if smoke else 64
    data["page_size"] = ps
    whole = collect(page_size=ps, page_reservation="whole", **kw)
    lazy = collect(page_size=ps, page_reservation="lazy", **kw)
    data["paged_points"] = whole["points"]          # PR-3 whole-reservation
    data["lazy_points"] = lazy["points"]            # ISSUE-4 lazy growth
    data["lazy_page_stats"] = lazy["page_stats"]
    # the A/B pins page_size=16 regardless of the trajectory ps: with the
    # default prompt/steps a 64-token page is never outgrown, and an A/B
    # whose lazy engine never grows or preempts measures nothing
    data["lazy_vs_whole"] = compare_lazy_whole(
        **{k: v for k, v in kw.items() if k != "occupancies"},
        occupancy=max(kw.get("occupancies", (4,))))
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    rows = []
    for tag, points in (("", data["points"]),
                        ("_paged", data["paged_points"]),
                        ("_lazy", data["lazy_points"])):
        for p in points:
            occ = p["occupancy"]
            rows.append(Row(f"serve_prefill{tag}_occ{occ}",
                            1e6 / max(p["prefill_tokens_per_s"], 1e-9),
                            f"{p['prefill_tokens_per_s']:.1f}tok/s"))
            rows.append(Row(f"serve_decode{tag}_occ{occ}",
                            1e6 / max(p["decode_tokens_per_s"], 1e-9),
                            f"{p['decode_tokens_per_s']:.1f}tok/s"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    for r in rows:
        print(r.csv())
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
