"""Serve-engine benchmark: prefill tokens/s + decode tokens/s vs occupancy.

Measures the continuous-batching engine end to end (admission prefill,
jitted slot-batch decode, sampling, host loop) at several slot
occupancies, and writes ``BENCH_serve.json`` — the first entry of the
serving perf trajectory. One engine serves every occupancy (pinned
``prefill_len`` + n_slots-padded waves mean one compiled prefill program),
so timings are warm after the first throwaway wave.

CPU container caveat (benchmarks/common.py): numbers are relative A/B
trends between occupancies, NOT TPU performance.

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import Row

DEFAULT_OUT = "BENCH_serve.json"


def collect(arch: str = "stablelm_12b", n_slots: int = 8,
            prompt_len: int = 32, steps: int = 12,
            occupancies=(1, 4, 8), page_size: int = 0) -> dict:
    """Run the engine at each occupancy; returns the BENCH_serve payload.

    ``page_size`` > 0 measures the PAGED engine (pool sized to the same HBM
    as the contiguous layout, table width = one contiguous segment so the
    per-step logical view matches) — emitted as ``paged_points`` next to
    the contiguous ``points`` headline.
    """
    from repro.configs import smoke_config
    from repro.models import get_model
    from repro.models.common import init_params
    from repro.serve import ServeEngine

    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    budget = steps + 4                       # never finishes mid-measurement
    max_len = prompt_len + budget + 8
    kw = {}
    if page_size:
        max_len = -(-max_len // page_size) * page_size
        kw = dict(page_size=page_size,
                  pages_per_slot=max_len // page_size)
    engine = ServeEngine(model, params, max_len=max_len,
                         n_slots=n_slots, prefill_len=prompt_len, **kw)
    rng = np.random.default_rng(0)

    def submit(n):
        return [engine.submit(
            rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32),
            budget) for _ in range(n)]

    # throwaway wave: compiles prefill/insert/decode/sample once
    submit(1)
    engine.run()

    result = {"arch": cfg.name, "n_slots": n_slots,
              "prompt_len": prompt_len, "decode_steps": steps, "points": []}
    for occ in occupancies:
        assert occ <= n_slots, (occ, n_slots)
        submit(occ)
        t0 = time.monotonic()
        engine.admit()
        t_admit = time.monotonic() - t0
        engine.decode(); engine.decode()     # decode warmup (already jitted)
        t0 = time.monotonic()
        for _ in range(steps):
            engine.decode()
        t_dec = time.monotonic() - t0
        engine.run()                         # drain before the next point
        result["points"].append({
            "occupancy": occ,
            "prefill_tokens_per_s": occ * prompt_len / t_admit,
            "decode_tokens_per_s": occ * steps / t_dec,
        })
    return result


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    """benchmarks/run.py entry: emit BENCH_serve.json + CSV rows."""
    kw = (dict(n_slots=4, prompt_len=16, steps=8, occupancies=(1, 2, 4))
          if smoke else {})
    data = collect(**kw)
    ps = 16 if smoke else 64
    data["page_size"] = ps
    data["paged_points"] = collect(page_size=ps, **kw)["points"]
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    rows = []
    for tag, points in (("", data["points"]),
                        ("_paged", data["paged_points"])):
        for p in points:
            occ = p["occupancy"]
            rows.append(Row(f"serve_prefill{tag}_occ{occ}",
                            1e6 / max(p["prefill_tokens_per_s"], 1e-9),
                            f"{p['prefill_tokens_per_s']:.1f}tok/s"))
            rows.append(Row(f"serve_decode{tag}_occ{occ}",
                            1e6 / max(p["decode_tokens_per_s"], 1e-9),
                            f"{p['decode_tokens_per_s']:.1f}tok/s"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    for r in rows:
        print(r.csv())
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
