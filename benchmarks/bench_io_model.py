"""Paper Thm 3.1 / Thm 3.2 / Cor 3.7 / Example 3.9: the analytic HBM-access
model. Pure math — validates that the implementation reproduces the paper's
claimed asymptotics and the ~6x constant of Example 3.9."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.lowrank import IOModel, optimal_storage_bytes


def run():
    rows = []
    # Example 3.9: C=R=64, S=100KB(half precision) => ~6x
    io = IOModel(n=65536, m=65536, c=64, rank=64, sram=100 * 1024 // 2)
    rows.append(Row("ex3_9_hbm_ratio", 0.0,
                    f"flashbias_vs_densebias={io.speedup_over_dense_bias():.2f}x"
                    " (paper: ~6x)"))
    # Thm 3.2: storage Theta(NR)
    for n, r in ((4096, 16), (65536, 64)):
        rows.append(Row(f"thm3_2_storage_n{n}_r{r}", 0.0,
                        f"optimal_bytes={optimal_storage_bytes(n, r)} "
                        f"dense_bytes={n * n * 2}"))
    # Cor 3.7 scaling in R at fixed C: quadratic in R, not NM
    base = IOModel(n=16384, m=16384, c=64, rank=8, sram=51200)
    for r in (8, 32, 128):
        io_r = IOModel(n=16384, m=16384, c=64, rank=r, sram=51200)
        rows.append(Row(f"cor3_7_rank{r}", 0.0,
                        f"hbm_accesses={io_r.flashbias():.3e} "
                        f"ratio_vs_r8={io_r.flashbias() / base.flashbias():.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
