"""Batched Pairformer serve benchmark: factored vs dense bias caches.

The paper's Sec. 4.4 serving claim, measured through the serve engine's
``PairBatchBackend``: admission runs the trunk once per complex and caches
its per-layer pair-bias state per slot; every step is one refinement
iteration of single-rep attention over the full slot batch. Three cache
representations serve the identical workload with interleaved timed steps
(same load profile, min-estimator: bench_serve's A/B methodology):

- ``factored`` — rank-R SVD factors phi_q/phi_k (FlashBias Sec. 4.3),
  Theta((N+M)R) bias bytes per step;
- ``dense`` (``bias_mode="dense_recompute"``) — the OFFICIAL dataflow and
  the paper's Table 6 baseline: the per-layer pair rep z is cached and the
  bias is re-projected from it at every use, exactly as AF3's pair-bias
  attention computes it;
- ``cached_bias`` (``bias_mode="dense"``) — the strongest dense variant:
  the projected (H, N, N) bias itself cached at admission, steps only
  stream it. Stronger than anything the official implementation does, kept
  as an ungated diagnostic.

The gated headline (``factored_vs_dense`` — scripts/check_bench.py holds
its LARGEST-n_res ratio >= 1.0 within tolerance) is factored vs the
official dataflow, the paper's actual A/B. ``cached_ratio`` is gated
separately against a committed conservative baseline as a factored-path
regression tripwire (e.g. a silent dense materialization).

CPU container caveat (benchmarks/common.py): on an accelerator the
factored path also beats the CACHED dense bias at paper scale (the rank-R
logit term is MXU compute, the N^2 bias stream is HBM bandwidth — see
EXPERIMENTS.md §Roofline); on CPU, matmul throughput is the scarce
resource, so ``cached_ratio`` sits below 1.0 in the compute-bound tail.
The sweep ends at n_res=384, the AF3 training-crop scale.

    PYTHONPATH=src python -m benchmarks.bench_pairformer [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import Row

DEFAULT_OUT = "BENCH_pairformer.json"


def _timed_step(engine) -> float:
    """One engine step, blocked on the updated single-rep cache (the pair
    step emits nothing host-side, so without the block the loop would time
    async dispatch instead of compute)."""
    t0 = time.monotonic()
    engine.decode()
    jax.block_until_ready(engine.backend._cache["s"])
    return time.monotonic() - t0


def compare_point(models: dict, params, n_res: int, n_slots: int,
                  steps: int) -> dict:
    """Interleaved refinement-step A/B at one n_res across the three cache
    representations. All engines admit the identical wave of full-length
    complexes (the A/B measures the bias-cache representation, not
    masking). Ratios > 1 mean the factored cache steps faster.
    """
    from repro.serve import ServeEngine

    rng = np.random.default_rng(0)
    complexes = [rng.standard_normal((n_res, 64)).astype(np.float32)
                 for _ in range(n_slots)]
    engines = {mode: ServeEngine(m, params, max_len=n_res, n_slots=n_slots)
               for mode, m in models.items()}
    best = {}
    for mode, eng in engines.items():
        for c in complexes:
            eng.submit(c, steps + 4)
        eng.admit()
        _timed_step(eng)                      # compile + first step
        _timed_step(eng)
        best[mode] = float("inf")
    for _ in range(steps):                    # interleave: same load profile
        for mode, eng in engines.items():
            best[mode] = min(best[mode], _timed_step(eng))
    for eng in engines.values():
        eng.run()
    return {"n_res": n_res,
            "factored_step_ms": best["factored"] * 1e3,
            "dense_step_ms": best["dense"] * 1e3,
            "cached_bias_step_ms": best["cached_bias"] * 1e3,
            "ratio": best["dense"] / best["factored"],
            "cached_ratio": best["cached_bias"] / best["factored"]}


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    """benchmarks/run.py entry: emit BENCH_pairformer.json + CSV rows."""
    from repro.configs.pairformer_lite import CONFIG
    from repro.models import get_model
    from repro.models.common import init_params

    sizes = (48, 96) if smoke else (128, 256, 384)
    n_slots, n_layers = 2, 2
    steps = 4 if smoke else 6
    # paper config at reduced depth (the A/B scales linearly in layers),
    # f32 so all paths run the same CPU dtype path; rank = App. H's R=96
    cfg_f = CONFIG.replace(n_layers=n_layers, dtype="float32", remat="none")
    models = {"factored": get_model(cfg_f),
              "dense": get_model(cfg_f.replace(
                  bias_mode="dense_recompute")),
              "cached_bias": get_model(cfg_f.replace(bias_mode="dense"))}
    params = init_params(models["factored"].template(), jax.random.PRNGKey(0))

    data = {"arch": cfg_f.name, "mode": "svd", "rank": cfg_f.bias_rank,
            "n_slots": n_slots, "n_layers": n_layers,
            "refine_steps": steps, "points": []}
    for n in sizes:
        data["points"].append(compare_point(models, params, n, n_slots,
                                            steps))
    # headline: the LARGEST n_res of the sweep (AF3 crop scale in the full
    # run) — gated >= 1.0 within tolerance by scripts/check_bench.py
    data["factored_vs_dense"] = dict(data["points"][-1])
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    rows = []
    for p in data["points"]:
        rows.append(Row(f"pairformer_step_factored_n{p['n_res']}",
                        p["factored_step_ms"] * 1e3,
                        f"R={data['rank']} svd"))
        rows.append(Row(f"pairformer_step_dense_n{p['n_res']}",
                        p["dense_step_ms"] * 1e3,
                        f"official recompute; ratio={p['ratio']:.3f}"))
        rows.append(Row(f"pairformer_step_cachedbias_n{p['n_res']}",
                        p["cached_bias_step_ms"] * 1e3,
                        f"cached_ratio={p['cached_ratio']:.3f}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    for r in rows:
        print(r.csv())
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
