"""Paper Fig. 3/4: overall efficiency — FlashBias vs FlashAttention-with-Bias
vs pure FlashAttention, across sequence lengths, training and inference.

Paths (CPU-relative A/B; see common.py):
- ``pure``       — chunked flash attention, no bias (the paper's upper bound),
- ``dense_bias`` — chunked flash attention streaming a dense (H,N,N) bias
                   (the "FlashAttention w/ Bias" baseline; Theta(NM) bias IO),
- ``flashbias``  — rank-R factors ride with q/k (Theta((N+M)R) bias IO).

Memory column: bias-path bytes actually materialized (analytic, exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.core import bias as bias_mod
from repro.core.attention import MaskSpec, attention

HEADS, DIM, RANK = 8, 64, 8


def _setup(n, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    q = jax.random.normal(ks[0], (1, n, HEADS, DIM))
    k = jax.random.normal(ks[1], (1, n, HEADS, DIM))
    v = jax.random.normal(ks[2], (1, n, HEADS, DIM))
    pq = jax.random.normal(ks[3], (1, n, HEADS, RANK)) * 0.1
    pk = jax.random.normal(ks[4], (1, n, HEADS, RANK)) * 0.1
    dense = jnp.einsum("bnhr,bmhr->bhnm", pq, pk)      # same bias, dense form
    return q, k, v, pq, pk, dense


def run(seqs=(256, 512, 1024), train=True):
    rows = []
    for n in seqs:
        q, k, v, pq, pk, dense = _setup(n)
        chunk = min(256, n)

        pure = jax.jit(lambda q, k, v: attention(
            q, k, v, impl="chunked", chunk_size=chunk))
        with_dense = jax.jit(lambda q, k, v, b: attention(
            q, k, v, bias=b, impl="chunked", chunk_size=chunk))
        with_phi = jax.jit(lambda q, k, v, pq, pk: attention(
            q, k, v, phi_q=pq, phi_k=pk, impl="chunked", chunk_size=chunk))

        t_pure = time_fn(pure, q, k, v)
        t_dense = time_fn(with_dense, q, k, v, dense)
        t_phi = time_fn(with_phi, q, k, v, pq, pk)
        bias_bytes_dense = dense.size * 4
        bias_bytes_phi = (pq.size + pk.size) * 4
        rows += [
            Row(f"fig3_infer_pure_n{n}", t_pure * 1e6, "bias_bytes=0"),
            Row(f"fig3_infer_densebias_n{n}", t_dense * 1e6,
                f"bias_bytes={bias_bytes_dense}"),
            Row(f"fig3_infer_flashbias_n{n}", t_phi * 1e6,
                f"bias_bytes={bias_bytes_phi}; "
                f"ratio_vs_pure={t_phi / t_pure:.3f}"),
        ]
        if train:
            def loss_dense(q, b):
                return with_dense(q, k, v, b).sum()

            def loss_phi(q, pq):
                return with_phi(q, k, v, pq, pk).sum()

            g_dense = jax.jit(jax.grad(loss_dense))
            g_phi = jax.jit(jax.grad(loss_phi))
            t_gd = time_fn(g_dense, q, dense)
            t_gp = time_fn(g_phi, q, pq)
            rows += [
                Row(f"fig3_train_densebias_n{n}", t_gd * 1e6,
                    f"bias_grad_bytes={bias_bytes_dense}"),
                Row(f"fig3_train_flashbias_n{n}", t_gp * 1e6,
                    f"bias_grad_bytes={bias_bytes_phi}"),
            ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
