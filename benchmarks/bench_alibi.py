"""Paper Table 3 (GPT-2 + ALiBi): cost of PROCESSING THE BIAS on top of pure
causal attention, for FlashAttention-with-Bias vs FlashBias (exact R=2).

Reported as the paper does: Delta = path_time - pure_causal_time, train and
inference, on a reduced GPT-2-family model (CPU-relative; see common.py).
FlashBias's exact decomposition makes its outputs bit-comparable to the
dense-ALiBi baseline — asserted here, not just timed.

    PYTHONPATH=src python -m benchmarks.bench_alibi [--smoke] [--out PATH]

``--smoke`` shrinks the workload for CI (which runs this every push so the
bench can't rot); ``--out`` writes the rows as ``BENCH_alibi.json``,
uploaded with the BENCH artifact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, rows_main, time_fn
from repro.configs import smoke_config
from repro.models import get_model
from repro.models.common import init_params

DEFAULT_OUT = "BENCH_alibi.json"


def run(seq=256, batch=2, smoke=False):
    if smoke:
        seq, batch = 96, 1
    cfg_fb = smoke_config("gpt2_alibi_15b").replace(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=8, d_ff=256,
        head_dim=16)
    cfg_dense = cfg_fb.replace(bias_mode="dense")
    cfg_pure = cfg_fb.replace(bias_kind="none")

    model_fb = get_model(cfg_fb)
    model_dense = get_model(cfg_dense)
    model_pure = get_model(cfg_pure)
    params = init_params(model_fb.template(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                              cfg_fb.vocab)
    batch_d = {"tokens": toks, "labels": toks}

    rows = []
    # inference (forward)
    fns = {}
    for name, model in (("pure_causal", model_pure),
                        ("flashattn_with_bias", model_dense),
                        ("flashbias", model_fb)):
        fns[name] = jax.jit(model.loss)
    t = {name: time_fn(f, params, batch_d) for name, f in fns.items()}
    base = t["pure_causal"]
    for name in ("flashattn_with_bias", "flashbias"):
        rows.append(Row(f"table3_infer_{name}", t[name] * 1e6,
                        f"delta_vs_pure_us={(t[name] - base) * 1e6:.1f}"))

    # training (grad)
    gs = {name: jax.jit(jax.grad(model.loss))
          for name, model in (("pure_causal", model_pure),
                              ("flashattn_with_bias", model_dense),
                              ("flashbias", model_fb))}
    tg = {name: time_fn(g, params, batch_d) for name, g in gs.items()}
    baseg = tg["pure_causal"]
    for name in ("flashattn_with_bias", "flashbias"):
        rows.append(Row(f"table3_train_{name}", tg[name] * 1e6,
                        f"delta_vs_pure_us={(tg[name] - baseg) * 1e6:.1f}"))

    # exactness: FlashBias == dense ALiBi bit-for-bit (up to fp assoc.)
    l1 = float(fns["flashbias"](params, batch_d))
    l2 = float(fns["flashattn_with_bias"](params, batch_d))
    rows.append(Row("table3_exactness", 0.0,
                    f"loss_delta={abs(l1 - l2):.2e} (exact decomposition)"))
    return rows


def main(argv=None):
    rows_main(lambda smoke: run(smoke=smoke), DEFAULT_OUT, argv)


if __name__ == "__main__":
    main()
