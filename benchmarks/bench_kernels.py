"""Kernel-level A/B (paper Fig. 5, "implementation choices"): the XLA chunked
path vs the Pallas kernel in interpret mode (numerical parity + call cost),
plus the paper's HEADLINE A/B — dense-bias attention vs FlashBias factored
bias — emitted as ``BENCH_kernels.json`` at the repo root (the kernel half
of the perf trajectory, next to ``BENCH_serve.json``).

The dense-vs-factored A/B is a SWEEP over sequence lengths, and the
headline (the ``dense_vs_factored`` entry the CI gate reads) is its
LARGEST, paper-scale point: at tiny N the factored path's extra rank-R
matmul per tile dominates the saved Θ(N·M) bias IO and the factored path
legitimately *loses* (the committed artifact once reported speedup 0.80 at
N=128 as if it were the result) — FlashBias's claim is about the regime
where the bias matrix is the traffic, which is exactly where serving runs.
The small-N points stay in ``dense_vs_factored_sweep`` so the crossover is
visible, not hidden.

interpret=True runs the kernel body in Python — its wall time is NOT TPU
performance; the number that matters there is allclose parity and the block
configuration that the TPU deployment will use (block_q=block_k=128). The
dense-vs-factored A/B times two fully-jitted XLA paths of the SAME workload,
so its ratio is a meaningful relative trend even on CPU
(benchmarks/common.py caveat).

    PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import bias as bias_mod
from repro.kernels import ops, ref

DEFAULT_OUT = "BENCH_kernels.json"


def headline_point(sweep: list) -> dict:
    """The gated ``dense_vs_factored`` headline: the LARGEST-seq sweep
    point (the paper-scale, bias-IO-dominated regime). Factored-bias
    attention legitimately loses at tiny N, so headlining a small-N point
    would gate the wrong regime — keep this the single source of truth
    for headline selection (unit-tested in tests/test_check_bench.py)."""
    return max(sweep, key=lambda pt: pt["seq_len"])


def _dense_vs_factored(n: int, rank: int, chunk: int = 128) -> dict:
    """Same attention workload, dense (H, N, N) bias vs rank-R factors.

    Both sides run the SAME chunked flash path at the SAME chunk size —
    only the bias representation differs (a streamed (H, N, N) slab vs
    rank-R factors folded into the QK matmul per Eq. 3). The old bench
    compared dense-at-chunk-128 against the factored path's default
    chunk-512 dispatch, so its ratio mixed chunking effects into the bias
    A/B and under-reported the factored win. chunk=128 mirrors the TPU
    kernel's block_k. The dense bias is materialized OUTSIDE the timed
    region (charitable to the baseline: ALiBi-style biases could be
    cached), so the measured gap is pure per-call bias traffic/compute.
    """
    b, h, d = 1, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, n, h, d))
    v = jax.random.normal(ks[2], (b, n, h, d))
    pq = jax.random.normal(ks[3], (b, n, h, rank))
    pk = jax.random.normal(ks[4], (b, n, h, rank))
    dense = jnp.einsum("bnhr,bmhr->bhnm", pq, pk)     # materialized bias

    from repro.core.attention import MaskSpec, attention
    dense_fn = jax.jit(lambda q, k, v, bias: attention(
        q, k, v, mask=MaskSpec("causal"), bias=bias, impl="chunked",
        chunk_size=chunk))
    fact_fn = jax.jit(lambda q, k, v, pq, pk: attention(
        q, k, v, mask=MaskSpec("causal"), phi_q=pq, phi_k=pk,
        impl="chunked", chunk_size=chunk))

    t_dense = time_fn(dense_fn, q, k, v, dense)
    t_fact = time_fn(fact_fn, q, k, v, pq, pk)
    err = float(jnp.abs(dense_fn(q, k, v, dense)
                        - fact_fn(q, k, v, pq, pk)).max())
    return {"seq_len": n, "heads": h, "head_dim": d, "rank": rank,
            "chunk": chunk,
            "dense_bias_us": t_dense * 1e6,
            "factored_bias_us": t_fact * 1e6,
            "speedup": t_dense / max(t_fact, 1e-12),
            "max_abs_err": err}


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    rows = []
    n = 128 if smoke else 256
    b, h, kvh, d = 1, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, n, kvh, d))
    v = jax.random.normal(ks[2], (b, n, kvh, d))
    slopes = bias_mod.alibi_slopes(h)

    xla_fn = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, slopes=slopes, mask_kind="causal", impl="xla"))
    t_xla = time_fn(xla_fn, q, k, v)
    rows.append(Row("fig5_xla_chunked_alibi", t_xla * 1e6,
                    "training-path impl (paper: SDPA)"))

    out_pallas = ops.flash_attention(q, k, v, slopes=slopes,
                                     mask_kind="causal",
                                     impl="pallas_interpret",
                                     block_q=128, block_k=128)
    err = float(jnp.abs(out_pallas - xla_fn(q, k, v)).max())
    rows.append(Row("fig5_pallas_parity", 0.0,
                    f"max_err={err:.2e} (blocks 128x128, TPU target)"))

    # decode kernel parity at production block size
    s = 256 if smoke else 512
    kc = jax.random.normal(ks[1], (2, s, kvh, d))
    vc = jax.random.normal(ks[2], (2, s, kvh, d))
    q1 = jax.random.normal(ks[0], (2, 1, h, d))
    lengths = jnp.array([s - 195, s], jnp.int32)
    o_k = ops.flash_decode(q1, kc, vc, lengths, slopes=slopes,
                           impl="pallas_interpret", block_k=128)
    o_r = ref.decode_reference(q1, kc, vc, lengths, slopes=slopes)
    rows.append(Row("decode_kernel_parity", 0.0,
                    f"max_err={float(jnp.abs(o_k - o_r).max()):.2e}"))

    # HEADLINE: dense-bias vs factored-bias cost of the same workload,
    # swept over seq lengths; the headline is the largest (paper-scale)
    # point — smoke keeps the historical small size in the sweep so the
    # small-N crossover stays visible, but never as the headline
    seqs = (128, 512) if smoke else (512, 1024, 2048)
    rank = 8 if smoke else 16
    sweep = [_dense_vs_factored(n=ni, rank=rank) for ni in seqs]
    ab = headline_point(sweep)
    for pt in sweep:
        rows.append(Row(f"attn_dense_bias_n{pt['seq_len']}",
                        pt["dense_bias_us"],
                        f"materialized (H,{pt['seq_len']},{pt['seq_len']}) "
                        "bias"))
        rows.append(Row(f"attn_factored_bias_n{pt['seq_len']}",
                        pt["factored_bias_us"],
                        f"rank-{pt['rank']} factors, "
                        f"{pt['speedup']:.2f}x vs dense"))

    payload = {"dense_vs_factored": ab,
               "dense_vs_factored_sweep": sweep,
               "parity": {"fig5_pallas_max_err": err,
                          "decode_kernel_max_err":
                          float(jnp.abs(o_k - o_r).max())}}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    from benchmarks.common import print_rows
    print_rows(rows)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
