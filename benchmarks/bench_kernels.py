"""Kernel-level A/B (paper Fig. 5, "implementation choices"): the XLA chunked
path vs the Pallas kernel in interpret mode (numerical parity + call cost).

interpret=True runs the kernel body in Python — its wall time is NOT TPU
performance; the number that matters here is allclose parity and the block
configuration that the TPU deployment will use (block_q=block_k=128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import bias as bias_mod
from repro.kernels import ops, ref


def run():
    rows = []
    b, n, h, kvh, d = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, n, kvh, d))
    v = jax.random.normal(ks[2], (b, n, kvh, d))
    slopes = bias_mod.alibi_slopes(h)

    xla_fn = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, slopes=slopes, mask_kind="causal", impl="xla"))
    t_xla = time_fn(xla_fn, q, k, v)
    rows.append(Row("fig5_xla_chunked_alibi", t_xla * 1e6,
                    "training-path impl (paper: SDPA)"))

    out_pallas = ops.flash_attention(q, k, v, slopes=slopes,
                                     mask_kind="causal",
                                     impl="pallas_interpret",
                                     block_q=128, block_k=128)
    err = float(jnp.abs(out_pallas - xla_fn(q, k, v)).max())
    rows.append(Row("fig5_pallas_parity", 0.0,
                    f"max_err={err:.2e} (blocks 128x128, TPU target)"))

    # decode kernel parity at production block size
    s = 512
    kc = jax.random.normal(ks[1], (2, s, kvh, d))
    vc = jax.random.normal(ks[2], (2, s, kvh, d))
    q1 = jax.random.normal(ks[0], (2, 1, h, d))
    lengths = jnp.array([317, 512], jnp.int32)
    o_k = ops.flash_decode(q1, kc, vc, lengths, slopes=slopes,
                           impl="pallas_interpret", block_k=128)
    o_r = ref.decode_reference(q1, kc, vc, lengths, slopes=slopes)
    rows.append(Row("decode_kernel_parity", 0.0,
                    f"max_err={float(jnp.abs(o_k - o_r).max()):.2e}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
