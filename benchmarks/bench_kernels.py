"""Kernel-level A/B (paper Fig. 5, "implementation choices"): the XLA chunked
path vs the Pallas kernel in interpret mode (numerical parity + call cost),
plus the paper's HEADLINE A/B — dense-bias attention vs FlashBias factored
bias — emitted as ``BENCH_kernels.json`` at the repo root (the kernel half
of the perf trajectory, next to ``BENCH_serve.json``).

interpret=True runs the kernel body in Python — its wall time is NOT TPU
performance; the number that matters there is allclose parity and the block
configuration that the TPU deployment will use (block_q=block_k=128). The
dense-vs-factored A/B times two fully-jitted XLA paths of the SAME workload,
so its ratio is a meaningful relative trend even on CPU
(benchmarks/common.py caveat).

    PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_fn
from repro.core import bias as bias_mod
from repro.kernels import ops, ref

DEFAULT_OUT = "BENCH_kernels.json"


def _dense_vs_factored(n: int, rank: int) -> dict:
    """Same attention workload, dense (H, N, N) bias vs rank-R factors."""
    b, h, d = 1, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, n, h, d))
    v = jax.random.normal(ks[2], (b, n, h, d))
    pq = jax.random.normal(ks[3], (b, n, h, rank))
    pk = jax.random.normal(ks[4], (b, n, h, rank))
    dense = jnp.einsum("bnhr,bmhr->bhnm", pq, pk)     # materialized bias

    from repro.core.attention import MaskSpec, attention
    dense_fn = jax.jit(lambda q, k, v, bias: attention(
        q, k, v, mask=MaskSpec("causal"), bias=bias, impl="chunked",
        chunk_size=128))
    fact_fn = jax.jit(lambda q, k, v, pq, pk: ops.flash_attention(
        q, k, v, pq, pk, mask_kind="causal", impl="xla"))

    t_dense = time_fn(dense_fn, q, k, v, dense)
    t_fact = time_fn(fact_fn, q, k, v, pq, pk)
    err = float(jnp.abs(dense_fn(q, k, v, dense)
                        - fact_fn(q, k, v, pq, pk)).max())
    return {"seq_len": n, "heads": h, "head_dim": d, "rank": rank,
            "dense_bias_us": t_dense * 1e6,
            "factored_bias_us": t_fact * 1e6,
            "speedup": t_dense / max(t_fact, 1e-12),
            "max_abs_err": err}


def run(out_path: str = DEFAULT_OUT, smoke: bool = False):
    rows = []
    n = 128 if smoke else 256
    b, h, kvh, d = 1, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, n, h, d))
    k = jax.random.normal(ks[1], (b, n, kvh, d))
    v = jax.random.normal(ks[2], (b, n, kvh, d))
    slopes = bias_mod.alibi_slopes(h)

    xla_fn = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, slopes=slopes, mask_kind="causal", impl="xla"))
    t_xla = time_fn(xla_fn, q, k, v)
    rows.append(Row("fig5_xla_chunked_alibi", t_xla * 1e6,
                    "training-path impl (paper: SDPA)"))

    out_pallas = ops.flash_attention(q, k, v, slopes=slopes,
                                     mask_kind="causal",
                                     impl="pallas_interpret",
                                     block_q=128, block_k=128)
    err = float(jnp.abs(out_pallas - xla_fn(q, k, v)).max())
    rows.append(Row("fig5_pallas_parity", 0.0,
                    f"max_err={err:.2e} (blocks 128x128, TPU target)"))

    # decode kernel parity at production block size
    s = 256 if smoke else 512
    kc = jax.random.normal(ks[1], (2, s, kvh, d))
    vc = jax.random.normal(ks[2], (2, s, kvh, d))
    q1 = jax.random.normal(ks[0], (2, 1, h, d))
    lengths = jnp.array([s - 195, s], jnp.int32)
    o_k = ops.flash_decode(q1, kc, vc, lengths, slopes=slopes,
                           impl="pallas_interpret", block_k=128)
    o_r = ref.decode_reference(q1, kc, vc, lengths, slopes=slopes)
    rows.append(Row("decode_kernel_parity", 0.0,
                    f"max_err={float(jnp.abs(o_k - o_r).max()):.2e}"))

    # HEADLINE: dense-bias vs factored-bias cost of the same workload
    ab = _dense_vs_factored(n=n, rank=8 if smoke else 16)
    rows.append(Row("attn_dense_bias", ab["dense_bias_us"],
                    f"materialized (H,{n},{n}) bias"))
    rows.append(Row("attn_factored_bias", ab["factored_bias_us"],
                    f"rank-{ab['rank']} factors, "
                    f"{ab['speedup']:.2f}x vs dense"))

    payload = {"dense_vs_factored": ab,
               "parity": {"fig5_pallas_max_err": err,
                          "decode_kernel_max_err":
                          float(jnp.abs(o_k - o_r).max())}}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    rows = run(out_path=args.out, smoke=args.smoke)
    from benchmarks.common import print_rows
    print_rows(rows)
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
