"""Paper Table 5 (Transformer PDE solver, learnable spatial-distance bias):
training + inference across point counts; the dense path's bias memory grows
O(N^2) (the paper's OOM column) while FlashBias stays O(N*R).

The learnable alpha makes the dense path store an (H, N, N) gradient — we
report the analytic bias/bias-grad bytes next to measured step times.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_fn
from repro.configs import smoke_config
from repro.data import PDEBatches
from repro.models import get_model
from repro.models import pde as pde_mod
from repro.models.common import init_params


def run(sizes=(256, 1024, 2048)):
    cfg = smoke_config("pde_solver").replace(n_layers=4)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(0))
    rows = []
    for n in sizes:
        data = PDEBatches(n_points=n, global_batch=1, seed=0)
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        h = cfg.n_heads
        dense_bias_bytes = h * n * n * 4
        fb_bytes = 2 * n * h * 9 * 4

        for mode, tag in (("flashbias", "flashbias"), ("dense", "dense")):
            c = cfg.replace(bias_mode=mode)
            if mode == "dense" and n > 1024:
                rows.append(Row(f"table5_train_{tag}_n{n}", float("nan"),
                                f"bias_grad_bytes={dense_bias_bytes} "
                                "(paper: OOM at scale)"))
                continue
            lf = jax.jit(lambda p, b, c=c: pde_mod.regression_loss(p, b, c))
            gf = jax.jit(jax.grad(
                lambda p, b, c=c: pde_mod.regression_loss(p, b, c)))
            t_i = time_fn(lf, params, batch, iters=3)
            t_t = time_fn(gf, params, batch, iters=3)
            bb = dense_bias_bytes if mode == "dense" else fb_bytes
            rows.append(Row(f"table5_infer_{tag}_n{n}", t_i * 1e6,
                            f"bias_bytes={bb}"))
            rows.append(Row(f"table5_train_{tag}_n{n}", t_t * 1e6,
                            f"bias_grad_bytes={bb}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows(run())
