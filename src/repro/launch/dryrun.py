import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder CPU devices back the production
meshes. Nothing here allocates model-scale memory: parameters, optimizer
states and caches are ShapeDtypeStructs; only ``.lower().compile()`` runs.

Per cell this prints/dumps:
- ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
- ``compiled.cost_analysis()``    — FLOPs / bytes for §Roofline,
- parsed collective bytes         — the third roofline term,
- the roofline report             — terms, dominant bottleneck, MODEL_FLOPS.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh single multi --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import flags
from repro.analysis import collective_bytes, roofline_report
from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import cells
from repro.dist import Rules, batch_axes_for, use_mesh_rules
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.models.common import abstract_params, param_shardings
from repro.optim import AdamW, constant

__all__ = ["run_cell", "main"]


def _batch_shardings(specs: dict, mesh, rules: Rules):
    """Input shardings: batch-shard every leaf on its batch dim.

    tokens/labels/frontend: dim 0; cache leaves: dim 1 (layer-stacked),
    except 'length' (dim 0). Degrades to replication when batch doesn't
    divide the DP axes (long_500k batch=1).
    """
    def leaf_sharding(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        bdim = 0
        if name.startswith("cache") and not name.endswith("length"):
            bdim = 1
        bspec = batch_axes_for(leaf.shape[bdim], mesh, rules)[0]
        parts = [None] * len(leaf.shape)
        parts[bdim] = bspec
        # decode KV caches: optionally shard the cache sequence dim (rules)
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf_sharding, specs)


def _step_fn(model, cfg, shape, pshard=None):
    """The function each cell lowers: train_step / prefill_step / serve_step."""
    if shape.kind == "train":
        opt = AdamW(lr_fn=constant(1e-4))
        from repro.train.step import make_train_step
        raw = make_train_step(model.loss, opt, grad_accum=cfg.grad_accum,
                              jit=False,
                              grad_shardings=pshard if cfg.grad_rs else None)

        def train_step(params, opt_state, batch):
            params, opt_state, metrics = raw(params, opt_state, batch)
            return params, opt_state, metrics["loss"]
        return train_step, opt

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, cache = model.prefill(params, batch,
                                          max_len=shape.seq_len)
            return logits, cache
        return prefill_step, None

    def serve_step(params, cache, tokens):
        return model.decode(params, cache, tokens)
    return serve_step, None


def _lower_and_compile(cfg, shape, mesh, rules):
    """Lower + compile one module; returns (compiled, wall seconds)."""
    model = get_model(cfg)
    tmpl = model.template()
    aparams = abstract_params(tmpl)
    pshard = param_shardings(tmpl, mesh, rules)
    specs = model.input_specs(shape)
    bshard = _batch_shardings(specs, mesh, rules)
    step, opt = _step_fn(model, cfg, shape, pshard)

    t0 = time.monotonic()
    with use_mesh_rules(mesh, rules):
        if shape.kind == "train":
            aopt = jax.eval_shape(opt.init, aparams)
            oshard = _opt_shardings(aopt, pshard, mesh)   # ZeRO-1 mirror
            jf = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard,
                                        NamedSharding(mesh, P())),
                         donate_argnums=(0, 1))
            lowered = jf.lower(aparams, aopt, specs)
        elif shape.kind == "prefill":
            jf = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jf.lower(aparams, specs)
        else:
            jf = jax.jit(step,
                         in_shardings=(pshard, bshard["cache"],
                                       bshard["tokens"]),
                         donate_argnums=(1,))
            lowered = jf.lower(aparams, specs["cache"], specs["tokens"])
        compiled = lowered.compile()
    return compiled, time.monotonic() - t0


def _cost_variant(cfg, shape):
    """Config/shape for the unrolled cost lowering.

    XLA's cost_analysis counts while bodies once, so the cost module unrolls
    every scan. To keep the unrolled HLO tractable the attention kv-chunk is
    raised to seq/8 and (for train) a single microbatch is lowered — the
    reported numbers are scaled back by grad_accum (weight gathers and grad
    reductions recur per microbatch, so scaling is faithful).
    """
    ccfg = cfg.replace(attn_chunk=max(512, shape.seq_len // 8))
    scale = 1.0
    cshape = shape
    if shape.kind == "train" and cfg.grad_accum > 1:
        ccfg = ccfg.replace(grad_accum=1)
        cshape = dataclasses.replace(
            shape, global_batch=shape.global_batch // cfg.grad_accum)
        scale = float(cfg.grad_accum)
    return ccfg, cshape, scale


def _cost_numbers(cfg, shape, mesh, rules):
    """FLOPs / bytes / collective bytes per device, trip-count-correct.

    Layers are homogeneous, so instead of unrolling all L layers (compile
    blows up at L=64) we lower the unrolled cost module at n_layers=1 and
    n_layers=2 and extrapolate: total = c1 + (L-1) * (c2 - c1). The
    intercept c1 carries embed/unembed/optimizer cost; the slope is the
    exact per-layer cost including remat recompute and per-layer FSDP
    collectives. grad-accum microbatching is restored by linear scaling.
    """
    ccfg, cshape, scale = _cost_variant(cfg, shape)

    def measure(n_layers):
        mcfg = ccfg.replace(n_layers=n_layers)
        with flags.unroll_scans():
            compiled, secs = _lower_and_compile(mcfg, cshape, mesh, rules)
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        coll = collective_bytes(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll, secs)

    f1, b1, coll1, s1 = measure(1)
    f2, b2, coll2, s2 = measure(2)
    L = cfg.n_layers

    def extrap(v1, v2):
        return max(v1, v1 + (L - 1) * (v2 - v1))

    flops = extrap(f1, f2) * scale
    bytes_acc = extrap(b1, b2) * scale
    coll = {k: (extrap(coll1[k], coll2[k]) * scale
                if isinstance(coll1[k], (int, float)) else coll1[k])
            for k in coll1}
    coll["total"] = sum(coll[k] for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
    return flops, bytes_acc, coll, scale, s1 + s2


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             rules: Rules = Rules(), verbose: bool = True,
             cfg_override=None, with_cost: bool = True,
             mesh_override=None) -> dict:
    """One dry-run cell. ``cfg_override`` / ``rules`` / ``mesh_override``
    are the §Perf hillclimb hooks (alternate remat, sharding rules, or a
    re-factored 256-chip mesh such as (16, 8, 2))."""
    cfg = cfg_override or get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh = mesh_override or make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    # ---- 1) production module: compile proof + memory analysis ----------
    compiled, compile_s = _lower_and_compile(cfg, shape, mesh, rules)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:                                   # CPU backend quirk
        mem_info = {"error": str(e)}

    if not with_cost:      # multi-pod pass: compile proof only (roofline
        report = {         # table is single-pod per the assignment)
            "arch": cfg.name, "arch_id": arch_id, "shape": shape.name,
            "mesh": "multi" if multi_pod else "single",
            "devices": n_dev, "compile_s": compile_s, "memory": mem_info,
            "compile_ok": True,
        }
        if verbose:
            print(f"[dryrun] {arch_id} x {shape_name} x "
                  f"{'2x16x16' if multi_pod else '16x16'}: "
                  f"compile {compile_s:.1f}s OK (proof only)")
            print(f"         memory_analysis: {mem_info}")
        return report

    # ---- 2) unrolled cost modules (L=1, L=2 -> extrapolate) --------------
    flops, bytes_acc, coll, scale, cost_compile_s = _cost_numbers(
        cfg, shape, mesh, rules)
    coll_total = coll["total"]

    # ---- 3) deployment-adjusted memory: same lowering with the Pallas
    # kernels' HBM footprint stubbed in for attention/decode (the XLA
    # fallback materializes its softmax pipeline + functional cache scatter,
    # which the TPU kernel keeps in VMEM / writes in place) ---------------
    adj_bytes = adj_flops = None
    if cfg.family in ("dense", "moe", "hybrid"):
        try:
            adj_flops, adj_bytes, _, _, adj_secs = _cost_numbers(
                cfg.replace(attn_impl="io_stub"), shape, mesh, rules)
            cost_compile_s += adj_secs
            # + the flash kernel's analytic attention terms: KV tile rereads
            # (the Cor 3.7 IO term) and block-pruned matmul FLOPs — the XLA
            # fallback materializes/computes the FULL quadratic and masks it.
            from repro.analysis.roofline import (attention_kernel_flops,
                                                 attention_kv_reread_bytes)
            n_model = mesh.shape.get("model", 1)
            n_data = n_dev // n_model
            adj_bytes += attention_kv_reread_bytes(cfg, shape, n_data)
            adj_flops += attention_kernel_flops(cfg, shape, n_data, n_model)
        except Exception:
            traceback.print_exc()
            adj_bytes = adj_flops = None

    report = roofline_report(
        flops_per_device=flops, bytes_per_device=bytes_acc,
        coll_bytes_per_device=coll_total, cfg=cfg, shape=shape,
        n_devices=n_dev, coll_detail=coll,
        adjusted_bytes_per_device=adj_bytes,
        adjusted_flops_per_device=adj_flops)
    report.update(mesh="multi" if multi_pod else "single",
                  compile_s=compile_s, cost_compile_s=cost_compile_s,
                  cost_scale=scale, memory=mem_info, arch_id=arch_id,
                  compile_ok=True)
    if verbose:
        print(f"[dryrun] {arch_id} x {shape_name} x "
              f"{'2x16x16' if multi_pod else '16x16'}: "
              f"compile {compile_s:.1f}s+{cost_compile_s:.1f}s  "
              f"flops/dev {flops:.3e}  bytes/dev {bytes_acc:.3e}  "
              f"coll/dev {coll_total:.3e}  dominant={report['dominant']}")
        print(f"         memory_analysis: {mem_info}")
    return report


def _opt_shardings(aopt, pshard, mesh):
    """mu/nu/err mirror params; scalar step replicated."""
    from repro.optim.adamw import OptState
    rep = NamedSharding(mesh, P())

    def mirror(tree):
        return jax.tree.map(lambda _, s: s, tree, pshard)

    return OptState(step=rep, mu=mirror(aopt.mu), nu=mirror(aopt.nu),
                    err=(mirror(aopt.err) if aopt.err is not None else None))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel activation rules")
    args = ap.parse_args()

    grid = cells()
    if args.arch != ["all"]:
        grid = [(a, s) for a, s in grid if a in args.arch]
    if args.shape != ["all"]:
        grid = [(a, s) for a, s in grid if s in args.shape]

    rules = Rules.make({"seq": ("model",)} if args.sp else None)
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in [m == "multi" for m in args.mesh]:
        for arch_id, shape_name in grid:
            tag = f"{arch_id}.{shape_name}.{'multi' if multi else 'single'}"
            try:
                rep = run_cell(arch_id, shape_name, multi_pod=multi,
                               rules=rules, with_cost=not multi)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rep, f, indent=1, default=str)
            except Exception:
                failures.append(tag)
                traceback.print_exc()
                print(f"[dryrun] FAILED {tag}")
    print(f"[dryrun] done; {len(failures)} failures: {failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
