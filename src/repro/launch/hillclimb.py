import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""§Perf hillclimb driver: apply one named change to one cell, re-lower,
re-analyse, and append the (hypothesis, before, after) record to
results/perf/<cell>.jsonl.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch command_r_plus_104b --shape train_4k --change grad_rs \
        --hypothesis "..."

Changes are registered in CHANGES below; each returns (cfg_override, rules,
mesh_override) for repro.launch.dryrun.run_cell. The baseline (change
"baseline") is the paper-faithful configuration.
"""
import argparse
import json

import jax

from repro.configs import get_config
from repro.dist import Rules
from repro.launch.dryrun import run_cell

__all__ = ["CHANGES", "apply_change"]


def _mesh_16_8_2():
    # 256 chips re-factored so attention TP can be 8-way while the FFN/vocab
    # stay 16-way (model_a x model_b): removes head padding for 24/40-head
    # archs (musicgen, minicpm, granite, llama4).
    return jax.make_mesh((16, 8, 2), ("data", "model_a", "model_b"))


CHANGES = {
    # paper-faithful baseline (bf16 compute, FSDP x TP, remat per config)
    "baseline": lambda cfg: (cfg, Rules(), None),

    # [beyond-paper] constrain grads to param shardings -> the DP gradient
    # reduction becomes reduce-scatter (ZeRO-2); without it GSPMD holds FULL
    # per-device gradients (416 GB/dev on command-r) and all-reduces them.
    "grad_rs": lambda cfg: (cfg.replace(grad_rs=True), Rules(), None),

    # [beyond-paper] sequence parallelism: shard activations' seq dim over
    # the model axis between blocks (halves per-device activation traffic
    # at the cost of boundary collectives).
    "sp": lambda cfg: (cfg, Rules.make({"seq": ("model",)}), None),

    # [beyond-paper] context parallelism for small-d archs: shard the
    # SEQUENCE over the model axis and drop head/mlp TP entirely — matmuls
    # become local (no per-token partial-sum all-reduces); attention
    # all-gathers the (small) KV per layer. FlashBias factors shard with q,
    # so the bias costs nothing extra (the paper's composability claim).
    "cp": lambda cfg: (cfg.replace(tp=1, pad_heads=0, pad_kv_heads=0),
                       Rules.make({"seq": ("model",), "heads": None,
                                   "mlp": None, "kv_heads": None,
                                   "vocab": None, "expert": None}),
                       None),

    # [beyond-paper] shard the decode KV cache's sequence dim over the model
    # axis (flash-decoding at the mesh level): cache reads split 16 ways.
    "kv_seq_shard": lambda cfg: (cfg, Rules.make({"kv_seq": ("model",)}),
                                 None),

    # remat policy sweep (memory <-> recompute tradeoff)
    "remat_dots": lambda cfg: (cfg.replace(remat="dots"), Rules(), None),
    "remat_full": lambda cfg: (cfg.replace(remat="full"), Rules(), None),
    "remat_none": lambda cfg: (cfg.replace(remat="none"), Rules(), None),

    # attention chunk size (XLA path logits-tile traffic)
    "chunk_1024": lambda cfg: (cfg.replace(attn_chunk=1024), Rules(), None),
    "chunk_2048": lambda cfg: (cfg.replace(attn_chunk=2048), Rules(), None),

    # SSD intra-chunk block (mamba2/hymba quadratic-term size)
    "ssd_128": lambda cfg: (cfg.replace(ssd_chunk=128), Rules(), None),
    "ssd_512": lambda cfg: (cfg.replace(ssd_chunk=512), Rules(), None),

    # grad accumulation sweep (activation footprint vs per-micro gathers)
    "accum_half": lambda cfg: (cfg.replace(
        grad_accum=max(1, cfg.grad_accum // 2)), Rules(), None),
    "accum_double": lambda cfg: (cfg.replace(
        grad_accum=cfg.grad_accum * 2), Rules(), None),

    # [beyond-paper] re-factored mesh (16, 8, 2): attention TP 8-way (no
    # head padding for 24/36/40-head archs), FFN/vocab 16-way.
    "mesh_16_8_2": lambda cfg: (
        cfg.replace(tp=8),
        Rules.make({"heads": "model_a", "mlp": ("model_a", "model_b"),
                    "vocab": ("model_a", "model_b"),
                    "expert": ("model_a", "model_b"),
                    "fsdp": ("pod", "data"), "batch": ("pod", "data")}),
        _mesh_16_8_2()),

    # fp32 master + bf16 params in HBM (halves param/optimizer HBM reads;
    # [beyond-paper] — the paper doesn't discuss precision placement)
    # (modeled via dtype of gathers; already default — kept for A/B)
}


def apply_change(arch_id, shape_name, change):
    cfg = get_config(arch_id)
    cfg2, rules, mesh = CHANGES[change](cfg)
    return run_cell(arch_id, shape_name, multi_pod=False, rules=rules,
                    cfg_override=cfg2, mesh_override=mesh, verbose=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--change", required=True, choices=sorted(CHANGES))
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    rep = apply_change(args.arch, args.shape, args.change)
    rep["change"] = args.change
    rep["hypothesis"] = args.hypothesis
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}.{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rep, default=str) + "\n")
    print(f"[hillclimb] appended {args.change} -> {path}")


if __name__ == "__main__":
    main()
