"""Launchers: production mesh, multi-pod dry-run, train/serve entry points.

NOTE: do not import ``dryrun`` from here — it must own the very first jax
initialization (it sets XLA_FLAGS for 512 placeholder devices before any
other import).
"""
from repro.launch.mesh import make_production_mesh

__all__ = ["make_production_mesh"]
