"""Production mesh construction (a FUNCTION, so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips of TPU v5e.
    Multi-pod:  (2, 16, 16) = (pod, data, model) — 512 chips across 2 pods;
    the ``pod`` axis composes with ``data`` into the DP/FSDP product (intra-
    pod reduce-scatter + inter-pod DCN all-reduce fall out of GSPMD)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
