"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Prefill a batch of synthetic prompts and decode greedily — the runnable
wrapper around ``serve_step`` (which the decode-shaped dry-run cells lower).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.new_tokens + 8)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.monotonic()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.monotonic() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("first row:", out[0][:16])
    return out


if __name__ == "__main__":
    main()
