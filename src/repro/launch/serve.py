"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the continuous-batching engine against synthetic traffic: ragged
prompt lengths, staggered arrivals (requests keep joining the queue while
earlier ones decode), and per-request sampling. The decode step stays one
jitted program over the full slot batch regardless of the arrival pattern.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import get_model
from repro.models.common import init_params
from repro.serve import FaultPlan, SamplingParams, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length (ragged draws in [4, prompt-len])")
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=0,
                    help="> 0: paged KV — shared page pool + page tables "
                         "instead of per-slot max_len segments")
    ap.add_argument("--page-reservation", choices=("lazy", "whole"),
                    default="lazy",
                    help="lazy: reserve prompt pages, grow on demand, "
                         "preempt on pool exhaustion; whole: reserve the "
                         "full footprint at admit (PR-3)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="> 0: override the page-pool size (undersize it "
                         "to watch lazy growth preempt under pressure)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="> 0: chunked prefill — prompts land this many "
                         "tokens per engine step, interleaved with decode "
                         "(long arrivals never stall the batch)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-hashed prefix caching: requests whose "
                         "prompts share completed pages map their page "
                         "tables onto them and prefill only the novel "
                         "tail (needs --page-size; defaults "
                         "--prefill-chunk to the page size)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="> 0: prepend a common prefix of this many "
                         "tokens to every request (system-prompt traffic "
                         "— watch --prefix-cache hit rates)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="> 0: per-request deadline — a request still live "
                         "or queued after this many engine steps finishes "
                         "TIMED_OUT (ISSUE 10 lifecycle)")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="quarantine retries per request before a guard "
                         "fault becomes terminal FAILED (0: fail on the "
                         "first non-finite emission)")
    ap.add_argument("--inject-fault", default="",
                    help="fault plan, e.g. 'nan@6/0x2,alloc@3x4' — "
                         "kind@step[/slot][xcount], comma-separated; kinds: "
                         "alloc, nan, step, delay. Drives the same "
                         "containment paths the chaos suite gates "
                         "(tests/test_faults.py)")
    ap.add_argument("--mesh", default="",
                    help="DxM (e.g. 2x2): serve on a (data, model) device "
                         "mesh — TP-sharded heads/pools, DP-sharded slot "
                         "rows; needs D*M devices (CPU: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    args = ap.parse_args(argv)
    if args.pool_pages and not args.page_size:
        ap.error("--pool-pages requires --page-size (paged KV)")
    if args.prefix_cache and not args.page_size:
        ap.error("--prefix-cache requires --page-size (paged KV)")
    if args.prefix_cache and not args.prefill_chunk:
        args.prefill_chunk = args.page_size
    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        if d * m > len(jax.devices()):
            ap.error(f"--mesh {args.mesh} needs {d * m} devices, "
                     f"found {len(jax.devices())}")
        mesh = jax.make_mesh((d, m), ("data", "model"))

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(args.seed))
    max_len = args.shared_prefix + args.prompt_len + args.new_tokens + 8
    kw = {}
    if args.page_size:
        # every request fits max_len here by construction, so cap the page
        # table at the per-slot segment footprint — the paged logical view
        # (and the XLA gather) stays the size of one contiguous segment
        kw = {"page_size": args.page_size,
              "pages_per_slot": -(-max_len // args.page_size),
              "page_reservation": args.page_reservation}
        if args.pool_pages:
            kw["n_pages"] = args.pool_pages
    if args.prefill_chunk:
        kw["prefill_chunk"] = args.prefill_chunk
    if args.prefix_cache:
        kw["prefix_cache"] = True
    if mesh is not None:
        kw["mesh"] = mesh
    if args.inject_fault:
        kw["faults"] = FaultPlan.parse(args.inject_fault)
    engine = ServeEngine(model, params, max_len=max_len,
                         n_slots=args.slots,
                         prefill_len=args.shared_prefix + args.prompt_len,
                         **kw)

    rng = np.random.default_rng(args.seed)
    lens = rng.integers(4, args.prompt_len + 1, (args.requests,))
    common = rng.integers(0, cfg.vocab,
                          (args.shared_prefix,)).astype(np.int32)

    def make_prompt(n):
        tail = rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
        return np.concatenate([common, tail]) if common.size else tail

    sub = {"deadline_steps": args.deadline_steps or None,
           "max_retries": args.max_retries}
    rids = []
    t0 = time.monotonic()
    # staggered arrivals: half the traffic queues up front, the rest joins
    # one request per engine step while earlier requests are mid-decode
    for i in range(args.requests // 2):
        rids.append(engine.submit(
            make_prompt(lens[i]), args.new_tokens,
            sampling=SamplingParams(args.temperature, args.top_k, seed=i),
            **sub))
    i = args.requests // 2
    while len(engine.scheduler) or engine.occupancy or i < args.requests:
        if i < args.requests:
            rids.append(engine.submit(
                make_prompt(lens[i]), args.new_tokens,
                sampling=SamplingParams(args.temperature, args.top_k, seed=i),
                **sub))
            i += 1
        engine.step()
    dt = time.monotonic() - t0

    n_tok = sum(engine.result(r).size for r in rids)
    print(f"[serve] {cfg.name}: {args.requests} ragged requests "
          f"(prompts {lens.min()}-{lens.max()}) over {args.slots} slots: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
    counts = engine.status_counts()
    line = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"[serve] lifecycle: {line}; {engine.n_quarantines} quarantines, "
          f"{engine.n_faults_contained} faults contained")
    if args.inject_fault and engine.faults is not None:
        for step, kind, slot in engine.faults.fired:
            at = f" slot {slot}" if slot is not None and slot >= 0 else ""
            print(f"[serve] fault fired: {kind}@{step}{at}")
    stats = engine.page_stats()
    if stats:
        print(f"[serve] pages: {stats['watermark']}/{stats['n_pages']} peak "
              f"({args.page_reservation}), {stats['grown']} grown "
              f"mid-flight, {stats['preemptions']} preemptions")
        if "prefix" in stats:
            pf = stats["prefix"]
            print(f"[serve] prefix cache: {pf['hit_rate']:.0%} hit rate "
                  f"({pf['tokens_matched']}/{pf['tokens_matchable']} "
                  f"tokens), {pf['entries']} entries, "
                  f"{pf['cow_copies']} CoW copies, "
                  f"{pf['evictions']} evictions")
    print("first request:", engine.result(rids[0])[:16])
    return [engine.result(r) for r in rids]


if __name__ == "__main__":
    main()
