"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant loop on whatever devices exist. On the container's
CPU this trains smoke-scale configs end-to-end (see examples/); on a real
pod the same entry point runs the production config under
``make_production_mesh()`` with the sharding rules from repro.dist.

Flags mirror the production story: ``--smoke`` (reduced config), ``--mesh``
(build the production mesh; requires the device count), ``--steps``,
``--ckpt-dir`` (restart-safe), ``--grad-accum``, ``--schedule wsd|cosine``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data import LMBatches
from repro.dist import Rules, use_mesh_rules
from repro.models import get_model
from repro.models.common import init_params, param_shardings
from repro.optim import AdamW, cosine, wsd
from repro.train import TrainLoop, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None)
    ap.add_argument("--mesh", action="store_true",
                    help="build the production mesh (needs 256 devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = init_params(model.template(), jax.random.PRNGKey(args.seed))

    if args.schedule == "wsd":   # minicpm's schedule
        lr_fn = wsd(args.lr, args.steps // 10, int(args.steps * 0.7),
                    args.steps - args.steps // 10 - int(args.steps * 0.7))
    else:
        lr_fn = cosine(args.lr, args.steps // 10, args.steps)
    opt = AdamW(lr_fn=lr_fn)
    opt_state = opt.init(params)

    grad_accum = args.grad_accum or cfg.grad_accum
    data = LMBatches(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=args.seed,
                     frontend_len=cfg.frontend_len, d_model=cfg.d_model)

    def data_fn(step):
        return {k: jnp.asarray(v) for k, v in data.batch(step).items()}

    ctx = None
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        ctx = use_mesh_rules(mesh, Rules())
        ctx.__enter__()

    step_fn = make_train_step(model.loss, opt, grad_accum=grad_accum)
    loop = TrainLoop(step_fn, data_fn, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, log_path=args.log)
    params, opt_state, info = loop.run(params, opt_state, args.steps)
    print(f"[train] {cfg.name}: {info}")
    if ctx is not None:
        ctx.__exit__(None, None, None)
    return info


if __name__ == "__main__":
    main()
