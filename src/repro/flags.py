"""Process-wide tracing flags.

``unroll_scans``: XLA's ``cost_analysis`` counts a while-loop body ONCE,
regardless of trip count, so a scan-over-layers module under-reports
FLOPs/bytes/collectives by ~n_layers x. The dry-run therefore lowers each
cell twice: the production module (scans — compile proof + memory analysis)
and a cost module with every scan fully unrolled (accurate per-device
FLOPs / bytes / collective counts). Model code asks ``scan_unroll(n)`` for
its ``lax.scan(..., unroll=...)`` argument.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


@contextlib.contextmanager
def unroll_scans(on: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = on
    try:
        yield
    finally:
        _UNROLL = prev


def unrolling() -> bool:
    return _UNROLL


def scan_unroll(length: int) -> int:
    """unroll= argument for lax.scan: full trip count in cost mode, else 1."""
    return max(int(length), 1) if _UNROLL else 1
