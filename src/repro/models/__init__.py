"""Model zoo: one decoder-LM family covering dense/MoE/SSM/hybrid archs,
plus the paper's own models (SwinV2 window attention, PDE solver,
Pairformer-lite). ``api`` exposes the uniform Model interface the launcher,
trainer and server consume.
"""
from repro.models import api, common, lm, pairformer, pde, ssd, swin  # noqa: F401
from repro.models.api import get_model

__all__ = ["api", "common", "lm", "pairformer", "pde", "ssd", "swin",
           "get_model"]
