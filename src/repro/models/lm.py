"""Decoder LM covering the dense / MoE / SSM / hybrid assigned families.

One parameter template + three entry points per family:

- ``loss_fn(params, batch, cfg)``        — training loss (scan over layers,
  remat policy from cfg, FlashBias-ALiBi attention).
- ``prefill(params, batch, cfg)``        — run the prompt, build the cache.
- ``decode_step(params, cache, tokens, cfg)`` — one token against the cache
  (flash-decoding kernel / XLA path; ring cache for sliding-window layers;
  constant-size SSM state for ssm/hybrid).

TP padding (heads/vocab/experts -> multiples of cfg.tp) is *mathematically
exact*: padded q-heads have zero o-proj rows, padded experts get -inf router
logits, padded vocab rows are masked out of the loss. The waste is visible
as MODEL_FLOPS/HLO_FLOPS < 1 in the roofline table and is a hillclimb lever.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.configs.base import ArchConfig
from repro.dist import sharding as dshard
from repro.dist.sharding import constrain
from repro.kernels import ops as kops
from repro.models import ssd
from repro.models.common import (
    PDef,
    cross_entropy_loss,
    embed_lookup,
    rmsnorm,
    stack_layers,
    swiglu,
    unembed_logits,
)

__all__ = ["lm_template", "loss_fn", "prefill", "prefill_chunk",
           "decode_step", "init_cache", "init_paged_cache",
           "insert_cache_at_slots", "insert_paged_cache_at_slots",
           "grow_page_tables_at_slots", "forward_hidden"]


# ---------------------------------------------------------------------------
# Template
# ---------------------------------------------------------------------------

def _attn_template(cfg: ArchConfig) -> dict:
    d, hp, kvp = cfg.d_model, cfg.heads_padded, cfg.kv_heads_padded
    hd = cfg.resolved_head_dim
    sd = 0.02
    return {
        "wq": PDef((d, hp, hd), ("fsdp", "heads", None), ("normal", sd)),
        "wk": PDef((d, kvp, hd), ("fsdp", "kv_heads", None), ("normal", sd)),
        "wv": PDef((d, kvp, hd), ("fsdp", "kv_heads", None), ("normal", sd)),
        "wo": PDef((hp, hd, d), ("heads", None, "fsdp"),
                   ("normal", sd / np.sqrt(2 * cfg.n_layers))),
        "slopes": PDef((hp,), (None,), ("slopes", cfg.n_heads)),
    }


def _mlp_template(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    sd = 0.02
    return {
        # gate+up FUSED (trailing dim 2) -> one matmul, one backward AR
        "wi": PDef((d, f, 2), ("fsdp", "mlp", None), ("normal", sd)),
        "wo": PDef((f, d), ("mlp", "fsdp"),
                   ("normal", sd / np.sqrt(2 * cfg.n_layers))),
    }


def _moe_template(cfg: ArchConfig) -> dict:
    d, f, ep = cfg.d_model, cfg.d_ff, cfg.experts_padded
    sd = 0.02
    return {
        "router": PDef((d, ep), ("fsdp", None), ("normal", sd)),
        "wi": PDef((ep, d, f, 2), ("expert", "fsdp", None, None),
                   ("normal", sd)),
        "wo": PDef((ep, f, d), ("expert", None, "fsdp"),
                   ("normal", sd / np.sqrt(2 * cfg.n_layers))),
    }


def _ssm_template(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    hs, p, n = cfg.ssm_heads_padded, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.conv_width
    sd = 0.02
    return {
        "in_x": PDef((d, hs, p), ("fsdp", "heads", None), ("normal", sd)),
        "in_z": PDef((d, hs, p), ("fsdp", "heads", None), ("normal", sd)),
        "in_b": PDef((d, n), ("fsdp", None), ("normal", sd)),
        "in_c": PDef((d, n), ("fsdp", None), ("normal", sd)),
        "in_dt": PDef((d, hs), ("fsdp", "heads"), ("normal", sd)),
        "conv_w": PDef((w, hs, p), (None, "heads", None), ("normal", 0.2)),
        "conv_bc_w": PDef((w, 2 * n), (None, None), ("normal", 0.2)),
        "a_log": PDef((hs,), ("heads",), ("zeros",)),
        "dt_bias": PDef((hs,), ("heads",), ("zeros",)),
        "d_skip": PDef((hs,), ("heads",), ("ones",)),
        "gate_norm": PDef((hs, p), ("heads", None), ("zeros",)),
        "out": PDef((hs, p, d), ("heads", None, "fsdp"),
                    ("normal", sd / np.sqrt(2 * cfg.n_layers))),
    }


def _layer_template(cfg: ArchConfig) -> dict:
    layer: dict = {"ln1": PDef((cfg.d_model,), (None,), ("zeros",))}
    if cfg.family in ("dense", "moe", "hybrid"):
        layer["attn"] = _attn_template(cfg)
    if cfg.family in ("ssm", "hybrid"):
        layer["ssm"] = _ssm_template(cfg)
    if cfg.family == "hybrid":
        layer["branch_norm_attn"] = PDef((cfg.d_model,), (None,), ("zeros",))
        layer["branch_norm_ssm"] = PDef((cfg.d_model,), (None,), ("zeros",))
    if cfg.family == "moe":
        layer["moe"] = _moe_template(cfg)
        layer["ln2"] = PDef((cfg.d_model,), (None,), ("zeros",))
    elif cfg.family in ("dense", "hybrid"):
        layer["mlp"] = _mlp_template(cfg)
        layer["ln2"] = PDef((cfg.d_model,), (None,), ("zeros",))
    return layer


def lm_template(cfg: ArchConfig) -> dict:
    return {
        "embed": PDef((cfg.vocab_padded, cfg.d_model), ("vocab", "fsdp"),
                      ("normal", 0.02)),
        "layers": stack_layers(_layer_template(cfg), cfg.n_layers),
        "final_norm": PDef((cfg.d_model,), (None,), ("zeros",)),
    }


# ---------------------------------------------------------------------------
# Attention (FlashBias-ALiBi; the paper's technique lives HERE)
# ---------------------------------------------------------------------------

def _attention(lp: dict, x: jax.Array, cfg: ArchConfig, *,
               mask_kind: str, q_offset=0) -> jax.Array:
    """Full-sequence attention (train / prefill). Returns (y, k, v).

    k, v come back in the cfg's CACHE layout: kv-head-major
    ``(B, KVH, S, hd)`` under ``cache_layout="kernel"`` (when the Pallas
    kernel runs, the projection einsums write head-major directly and the
    kernel consumes it zero-copy — prefill emits kernel-layout caches with
    no post-hoc fixup), canonical ``(B, S, KVH, hd)`` under ``"legacy"``.
    """
    dt = x.dtype
    head_major = cfg.cache_layout == "kernel"

    slopes = None
    phi_q = phi_k = None
    dense_bias = None
    if cfg.bias_kind == "alibi":
        if cfg.bias_mode == "flashbias":
            slopes = lp["slopes"].astype(jnp.float32)
        else:  # dense baseline: materialize the (H, N, M) bias (paper A/B)
            from repro.core.bias import alibi_dense
            n = x.shape[1]
            bd = alibi_dense(n, n, cfg.n_heads)
            pad = cfg.heads_padded - cfg.n_heads
            dense_bias = jnp.pad(bd, ((0, pad), (0, 0), (0, 0)))[None]

    # Compute layout follows the impl that will run: head-major projections
    # feed the Pallas kernel zero-copy; the XLA chunked fallback (and the
    # dense-bias baseline) speak canonical, so there the projections stay
    # canonical and only the cache emission transposes (once per prefill —
    # the "cheap view" the layout contract allows off the hot path).
    hm_compute = (head_major and dense_bias is None
                  and kops.resolve_impl(cfg.attn_impl) != "xla")
    if hm_compute:
        q = jnp.einsum("bsd,dhe->bhse", x, lp["wq"].astype(dt))
        k = jnp.einsum("bsd,dhe->bhse", x, lp["wk"].astype(dt))
        v = jnp.einsum("bsd,dhe->bhse", x, lp["wv"].astype(dt))
        q = constrain(q, "batch", "heads", "seq", None)
        k = constrain(k, "batch", "kv_heads", "seq", None)
        v = constrain(v, "batch", "kv_heads", "seq", None)
        o = kops.flash_attention(
            q, k, v, phi_q, phi_k, slopes, mask_kind=mask_kind,
            window=cfg.window, impl=cfg.attn_impl, block_q=128, block_k=128,
            layout="bhsd")
        y = jnp.einsum("bhse,hed->bsd", o, lp["wo"].astype(dt))
        return constrain(y, "batch", "seq", None), k, v

    q = jnp.einsum("bsd,dhe->bshe", x, lp["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, lp["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, lp["wv"].astype(dt))
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)

    if dense_bias is not None:
        from repro.core.attention import MaskSpec, attention as core_attn
        o = core_attn(q, k, v, mask=MaskSpec(mask_kind, cfg.window),
                      bias=dense_bias, impl="chunked",
                      chunk_size=cfg.attn_chunk)
    else:
        o = kops.flash_attention(
            q, k, v, phi_q, phi_k, slopes, mask_kind=mask_kind,
            window=cfg.window, impl=cfg.attn_impl, block_q=128, block_k=128)
    y = jnp.einsum("bshe,hed->bsd", o, lp["wo"].astype(dt))
    if head_major:                       # cache emission only, off hot path
        k, v = k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    return constrain(y, "batch", "seq", None), k, v


def _attention_decode(lp: dict, x: jax.Array, k_cache, v_cache, lengths,
                      cfg: ArchConfig, *, active=None, page_table=None,
                      phi_pages=None, max_pages=None):
    """One-token attention against a (ring / full / paged) cache.

    ``active`` (B,) bool freezes retired slot rows: their KV writes are
    dropped (scatter index pushed out of range, ``mode="drop"``) so an idle
    lane can never scribble on cache it no longer owns — under the paged
    layout a stale page table would otherwise corrupt pages that have been
    reallocated to ANOTHER request.

    Cache layout (ISSUE 5): under ``cfg.cache_layout == "kernel"`` every
    cache arrives in the kernels' native kv-head-major layout — contiguous
    / ring ``(B, KVH, S, hd)``, page pools ``(KVH, n_pages, ps, hd_pad)``
    — and is passed to ``ops.flash_decode`` ZERO-COPY (``kv_layout=
    "bhsd"``); only the one new token's row is touched per step. The
    ``"legacy"`` canonical layout (``(B, S, KVH, hd)`` / ``(n_pages, ps,
    KVH, hd)``) is kept as the layout_vs_legacy A/B + parity reference and
    pays ops' per-call adaptation.

    Paged mode (``page_table`` given): the new token is written through the
    slot's page table. ``phi_pages`` is the per-page ALiBi key factor slab
    (layer- and kv-head-shared; lane-padded ``(n_pages, ps, r_pad)`` under
    the kernel layout); when present the bias is computed from the CACHED
    factors (phi mode — factors ride with k, FlashBias Sec. 4.3) instead
    of re-materializing positions. ``max_pages`` statically caps the pages
    any request can reference (the serve engine derives it from host-side
    lengths).
    """
    dt = x.dtype
    kernel_layout = cfg.cache_layout == "kernel"
    kv_layout = "bhsd" if kernel_layout else "bshd"
    q = jnp.einsum("bsd,dhe->bshe", x, lp["wq"].astype(dt))
    k_new = jnp.einsum("bsd,dhe->bshe", x, lp["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dhe->bshe", x, lp["wv"].astype(dt))
    slopes = (lp["slopes"].astype(jnp.float32)
              if cfg.bias_kind == "alibi" else None)
    bidx = jnp.arange(x.shape[0])

    def drop_if_frozen(idx, oob):
        return idx if active is None else jnp.where(active, idx, oob)

    def new_row(x_new, pool_like):
        # (B, 1, KVH, hd) -> (B, KVH, hd[_pad]): the one token-sized write
        row = x_new[:, 0]
        pad = pool_like.shape[-1] - row.shape[-1]
        if pad:
            row = jnp.pad(row, ((0, 0), (0, 0), (0, pad)))
        return row

    # io_stub (dry-run accounting only): the donated cache is updated
    # IN PLACE on hardware (one row written); the functional `.at[].set`
    # would count a full cache read+write per layer in cost_analysis.
    skip_scatter = cfg.attn_impl == "io_stub"
    if page_table is not None:                     # paged full cache
        if kernel_layout:                          # (KVH, n_pages, ps, hd_p)
            n_pages, ps = k_cache.shape[1], k_cache.shape[2]
        else:                                      # (n_pages, ps, KVH, hd)
            n_pages, ps = k_cache.shape[0], k_cache.shape[1]
        pos = lengths - 1
        page = drop_if_frozen(page_table[bidx, pos // ps], n_pages)
        if not skip_scatter:
            if kernel_layout:
                kr = new_row(k_new, k_cache).transpose(1, 0, 2)
                vr = new_row(v_new, v_cache).transpose(1, 0, 2)
                k_cache = k_cache.at[:, page, pos % ps].set(kr, mode="drop")
                v_cache = v_cache.at[:, page, pos % ps].set(vr, mode="drop")
            else:
                k_cache = k_cache.at[page, pos % ps].set(k_new[:, 0],
                                                         mode="drop")
                v_cache = v_cache.at[page, pos % ps].set(v_new[:, 0],
                                                         mode="drop")
        phi_q = phi_k = None
        if slopes is not None and phi_pages is not None:
            # same rank-2 q factor the ops ALiBi path materializes; the key
            # factors come from the paged slab instead
            b, hp = x.shape[0], q.shape[2]
            qpos = (lengths.astype(jnp.float32) - 1.0)[:, None, None, None]
            pq = jnp.concatenate([-jnp.broadcast_to(qpos, (b, 1, hp, 1)),
                                  jnp.ones((b, 1, hp, 1), jnp.float32)], -1)
            phi_q = pq * slopes.reshape(1, 1, hp, 1)
            phi_k, slopes = phi_pages, None
        o = kops.flash_decode(q, k_cache, v_cache, lengths, phi_q=phi_q,
                              phi_k=phi_k, slopes=slopes, impl=cfg.attn_impl,
                              block_k=cfg.attn_chunk, page_table=page_table,
                              kv_layout=kv_layout, max_pages=max_pages)
        # lane-padded pools return lane-padded values; the pad rows are
        # zero so slicing them off is exact (token-sized, not pool-sized)
        o = o[..., :v_new.shape[-1]]
    elif cfg.window and cfg.window == k_cache.shape[2 if kernel_layout
                                                    else 1]:  # ring (SWA)
        sc = cfg.window
        slot = drop_if_frozen((lengths - 1) % sc, sc)
        if not skip_scatter:
            if kernel_layout:
                k_cache = k_cache.at[bidx, :, slot].set(k_new[:, 0],
                                                        mode="drop")
                v_cache = v_cache.at[bidx, :, slot].set(v_new[:, 0],
                                                        mode="drop")
            else:
                k_cache = k_cache.at[bidx, slot].set(k_new[:, 0], mode="drop")
                v_cache = v_cache.at[bidx, slot].set(v_new[:, 0], mode="drop")
        o = _ring_window_attention(q, k_cache, v_cache, lengths, slopes, cfg,
                                   head_major=kernel_layout)
    else:                                          # contiguous full cache
        sc = k_cache.shape[2 if kernel_layout else 1]
        pos = drop_if_frozen(lengths - 1, sc)
        if not skip_scatter:
            if kernel_layout:
                k_cache = k_cache.at[bidx, :, pos].set(
                    new_row(k_new, k_cache), mode="drop")
                v_cache = v_cache.at[bidx, :, pos].set(
                    new_row(v_new, v_cache), mode="drop")
            else:
                k_cache = k_cache.at[bidx, pos].set(k_new[:, 0], mode="drop")
                v_cache = v_cache.at[bidx, pos].set(v_new[:, 0], mode="drop")
        o = kops.flash_decode(q, k_cache, v_cache, lengths, slopes=slopes,
                              impl=cfg.attn_impl, block_k=cfg.attn_chunk,
                              kv_layout=kv_layout)
        o = o[..., :v_new.shape[-1]]     # lane-padded caches return padded
    y = jnp.einsum("bshe,hed->bsd", o, lp["wo"].astype(dt))
    return y, k_cache, v_cache


def _ring_window_attention(q, k_cache, v_cache, lengths, slopes, cfg, *,
                           head_major=False):
    """Dense decode over a ring cache of size window (small: <= few K).

    Slot s holds absolute position p = len-1 - ((len-1 - s) mod W), valid
    iff p >= 0. ALiBi bias from absolute positions; softmax over the window.

    ``head_major``: the ring cache is kernel-layout ``(B, KVH, W, hd)`` —
    grouped einsums consume it directly (no G-fold ``jnp.repeat`` of the
    window, no transpose).
    """
    b, _, h, e = q.shape
    scale = 1.0 / np.sqrt(e)
    if head_major:
        kvh, w = k_cache.shape[1], k_cache.shape[2]
        g = h // kvh
        slot = jnp.arange(w)
        last = (lengths - 1)[:, None]
        pos = last - ((last - slot) % w)                 # (B, W)
        valid = pos >= 0
        qg = q[:, 0].reshape(b, kvh, g, e).astype(jnp.float32)
        s = jnp.einsum("bkge,bkwe->bkgw", qg,
                       k_cache.astype(jnp.float32)) * scale
        if slopes is not None:
            rel = (pos - last).astype(jnp.float32)       # <= 0
            s = s + slopes.reshape(kvh, g)[None, :, :, None] \
                * rel[:, None, None, :]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgw,bkwe->bkge", p, v_cache.astype(jnp.float32))
        return o.reshape(b, 1, h, e).astype(q.dtype)
    w = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = h // kvh
    slot = jnp.arange(w)
    last = (lengths - 1)[:, None]
    pos = last - ((last - slot) % w)                     # (B, W)
    valid = pos >= 0
    kf = jnp.repeat(k_cache, g, axis=2)
    vf = jnp.repeat(v_cache, g, axis=2)
    s = jnp.einsum("bhe,bwhe->bhw", q[:, 0].astype(jnp.float32),
                   kf.astype(jnp.float32)) * scale
    if slopes is not None:
        rel = (pos - last).astype(jnp.float32)           # <= 0
        s = s + slopes[None, :, None] * rel[:, None, :]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhw,bwhe->bhe", p, vf.astype(jnp.float32))
    return o[:, None].astype(q.dtype)


def _attention_chunk(lp: dict, x: jax.Array, k_cache, v_cache,
                     cfg: ArchConfig, *, offsets, chunk_lens,
                     page_table=None, max_pages=None):
    """C-token chunk attention against a (ring / full / paged) slot cache.

    Chunked prefill's attention step: row ``b``'s chunk occupies absolute
    positions ``offsets[b] .. offsets[b]+chunk_lens[b]-1``. Rows with
    ``chunk_lens[b] == 0`` are frozen lanes riding the fixed slot batch
    (live decoding slots, empty slots): their KV writes drop (out-of-range
    scatter indices, ``mode="drop"``) and their outputs are garbage nobody
    reads — exactly the ``active`` discipline of ``_attention_decode``.

    Full/paged caches scatter the chunk's keys FIRST and attend against the
    written cache under the offset causal mask (``k_pos <= q_pos``, see
    ``ops.flash_chunk_attention``). Ring caches must attend FIRST: a later
    chunk position may alias the ring slot an earlier query still needs, so
    queries read old keys from the PRE-write ring (slot validity per query:
    in-window and written) plus the chunk's own keys (causal + local), and
    only then does the chunk rotate into the ring — which also bounds the
    chunk size at ``window`` (positions must land on distinct slots).
    """
    dt = x.dtype
    kernel_layout = cfg.cache_layout == "kernel"
    kv_layout = "bhsd" if kernel_layout else "bshd"
    b, c, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, lp["wq"].astype(dt))
    k_new = jnp.einsum("bsd,dhe->bshe", x, lp["wk"].astype(dt))
    v_new = jnp.einsum("bsd,dhe->bshe", x, lp["wv"].astype(dt))
    slopes = (lp["slopes"].astype(jnp.float32)
              if cfg.bias_kind == "alibi" else None)
    bidx = jnp.arange(b)
    i = jnp.arange(c)
    pos = offsets[:, None] + i[None, :]                   # (B, C) absolute
    valid = i[None, :] < chunk_lens[:, None]              # (B, C)

    def pad_rows(x_new, pool_like):
        # (B, C, KVH, hd) -> (B, C, KVH, hd_pad): chunk-sized, like decode's
        # one-row pad against lane-padded pools
        pad = pool_like.shape[-1] - x_new.shape[-1]
        if pad:
            x_new = jnp.pad(x_new, ((0, 0),) * 3 + ((0, pad),))
        return x_new

    if page_table is not None:                            # paged full cache
        if kernel_layout:                # (KVH, n_pages, ps, hd_pad)
            n_pages, ps = k_cache.shape[1], k_cache.shape[2]
        else:                            # (n_pages, ps, KVH, hd)
            n_pages, ps = k_cache.shape[0], k_cache.shape[1]
        page = jnp.where(valid, page_table[bidx[:, None], pos // ps], n_pages)
        flat_pg, flat_ix = page.reshape(-1), (pos % ps).reshape(-1)
        if kernel_layout:
            kvh = k_new.shape[2]
            def rows(x_new, pool):       # -> (KVH, B*C, hd_pad)
                r = pad_rows(x_new, pool)
                return r.transpose(2, 0, 1, 3).reshape(kvh, b * c, -1)
            k_cache = k_cache.at[:, flat_pg, flat_ix].set(
                rows(k_new, k_cache), mode="drop")
            v_cache = v_cache.at[:, flat_pg, flat_ix].set(
                rows(v_new, v_cache), mode="drop")
        else:
            k_cache = k_cache.at[flat_pg, flat_ix].set(
                k_new.reshape(b * c, *k_new.shape[2:]), mode="drop")
            v_cache = v_cache.at[flat_pg, flat_ix].set(
                v_new.reshape(b * c, *v_new.shape[2:]), mode="drop")
        o = kops.flash_chunk_attention(
            q, k_cache, v_cache, offsets, chunk_lens, slopes,
            impl=cfg.attn_impl, kv_layout=kv_layout, page_table=page_table,
            max_pages=max_pages)
        o = o[..., :v_new.shape[-1]]
    elif cfg.window and cfg.window == k_cache.shape[2 if kernel_layout
                                                    else 1]:  # ring (SWA)
        w = cfg.window
        assert c <= w, (c, w)            # distinct ring slots per chunk
        h = q.shape[2]
        e = q.shape[-1]
        kvh = k_new.shape[2]
        g = h // kvh
        scale = 1.0 / np.sqrt(e)
        # old keys from the PRE-write ring: slot s holds absolute position
        # p_old = old_last - ((old_last - s) mod W), valid iff >= 0
        slot = jnp.arange(w)
        old_last = (offsets - 1)[:, None]                 # (B, 1)
        p_old = old_last - ((old_last - slot[None, :]) % w)      # (B, W)
        slot_written = (p_old >= 0) & (offsets[:, None] > 0)
        qg = (q.reshape(b, c, kvh, g, e).transpose(0, 2, 3, 1, 4)
              .astype(jnp.float32))                       # (B,KVH,G,C,E)
        kf = k_cache if kernel_layout else k_cache.transpose(0, 2, 1, 3)
        vf = v_cache if kernel_layout else v_cache.transpose(0, 2, 1, 3)
        s_old = jnp.einsum("bkgce,bkwe->bkgcw", qg,
                           kf.astype(jnp.float32)) * scale
        kn = k_new.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B,KVH,C,E)
        vn = v_new.transpose(0, 2, 1, 3).astype(jnp.float32)
        s_in = jnp.einsum("bkgce,bkje->bkgcj", qg, kn) * scale
        if slopes is not None:
            sl = slopes.reshape(kvh, g)[None, :, :, None, None]
            rel_old = (p_old[:, None, :] - pos[:, :, None]).astype(jnp.float32)
            rel_in = (i[None, :] - i[:, None]).astype(jnp.float32)  # (C, C)
            s_old = s_old + sl * rel_old[:, None, None]
            s_in = s_in + sl * rel_in[None, None, None]
        # query at pos p sees old keys in (p - W, offsets) and chunk keys
        # j <= i within the window (all <= p by causality)
        m_old = slot_written[:, None, :] \
            & (p_old[:, None, :] > pos[:, :, None] - w)          # (B,C,W)
        m_in = ((i[None, :] <= i[:, None]) & (i[:, None] - i[None, :] < w)
                )[None] & (i[None, None, :] < chunk_lens[:, None, None])
        s_all = jnp.concatenate([
            jnp.where(m_old[:, None, None], s_old, -1e30),
            jnp.where(m_in[:, None, None], s_in, -1e30)], axis=-1)
        p_all = jax.nn.softmax(s_all, axis=-1)
        o = jnp.einsum("bkgcw,bkwe->bkgce", p_all[..., :w],
                       vf.astype(jnp.float32)) \
            + jnp.einsum("bkgcj,bkje->bkgce", p_all[..., w:], vn)
        o = (o.transpose(0, 3, 1, 2, 4).reshape(b, c, h, e)
             .astype(q.dtype))
        # rotate the chunk into the ring AFTER attending (frozen rows drop)
        ring_slot = jnp.where(valid, pos % w, w)
        if kernel_layout:
            k_cache = k_cache.at[bidx[:, None], :, ring_slot].set(
                k_new, mode="drop")
            v_cache = v_cache.at[bidx[:, None], :, ring_slot].set(
                v_new, mode="drop")
        else:
            k_cache = k_cache.at[bidx[:, None], ring_slot].set(
                k_new, mode="drop")
            v_cache = v_cache.at[bidx[:, None], ring_slot].set(
                v_new, mode="drop")
    else:                                                 # contiguous full
        sc = k_cache.shape[2 if kernel_layout else 1]
        pos_w = jnp.where(valid, pos, sc)
        if kernel_layout:
            k_cache = k_cache.at[bidx[:, None], :, pos_w].set(
                pad_rows(k_new, k_cache), mode="drop")
            v_cache = v_cache.at[bidx[:, None], :, pos_w].set(
                pad_rows(v_new, v_cache), mode="drop")
        else:
            k_cache = k_cache.at[bidx[:, None], pos_w].set(k_new, mode="drop")
            v_cache = v_cache.at[bidx[:, None], pos_w].set(v_new, mode="drop")
        o = kops.flash_chunk_attention(
            q, k_cache, v_cache, offsets, chunk_lens, slopes,
            impl=cfg.attn_impl, kv_layout=kv_layout)
        o = o[..., :v_new.shape[-1]]
    y = jnp.einsum("bshe,hed->bsd", o, lp["wo"].astype(dt))
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# MoE FFN (GShard-style capacity dispatch; EP over the model axis)
# ---------------------------------------------------------------------------

def _moe_capacity(cfg: ArchConfig, s: int) -> int:
    c = int(np.ceil(s * cfg.top_k / cfg.experts_padded * cfg.capacity_factor))
    return max(1, c)


def _moe_ffn(mp: dict, x: jax.Array, cfg: ArchConfig, valid=None):
    """Returns (y, aux_loss). x: (B, S, D).

    ``valid`` (B, S) bool marks real positions of a right-padded batch:
    invalid positions are dropped from dispatch entirely, so they consume
    no expert capacity and receive a zero update.
    """
    b, s, d = x.shape
    ep, k = cfg.experts_padded, cfg.top_k
    cap = _moe_capacity(cfg, s)
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, mp["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    if cfg.n_experts < ep:                     # padded experts never win
        iota = jnp.arange(ep)
        logits = jnp.where(iota >= cfg.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                     # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # slot-major one-hot: (B, K, S, E); positions assigned slot-0 first
    onehot = jax.nn.one_hot(gate_idx, ep, dtype=jnp.float32)    # (B,S,K,E)
    if valid is not None:
        onehot = onehot * valid[:, :, None, None].astype(jnp.float32)
    sel = onehot.transpose(0, 2, 1, 3)                          # (B,K,S,E)
    flat = sel.reshape(b, k * s, ep)
    pos = jnp.cumsum(flat, axis=1) - flat                       # pos within expert
    keep = (pos < cap) * flat                                   # drop overflow
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    disp = pos_oh.reshape(b, k, s, ep, cap).transpose(0, 2, 3, 4, 1)
    dispatch = disp.sum(-1)                                     # (B,S,E,C)
    gates = gate_vals.transpose(0, 2, 1)[..., None, None]       # (B,K,S,1,1)
    combine = (disp * gates.transpose(0, 2, 3, 4, 1)).sum(-1)   # (B,S,E,C)

    dispatch = constrain(dispatch.astype(dt), "batch", "seq", "expert", None)
    xin = jnp.einsum("bsec,bsd->becd", dispatch, x)             # (B,E,C,D)
    xin = constrain(xin, "batch", "expert", None, None)
    h2 = jnp.einsum("becd,edft->becft", xin, mp["wi"].astype(dt))
    h = jax.nn.silu(h2[..., 0]) * h2[..., 1]
    eo = jnp.einsum("becf,efd->becd", h, mp["wo"].astype(dt))   # (B,E,C,D)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(dt), eo)

    # load-balance aux (Switch): E * sum_e f_e * p_e over real experts
    frac = dispatch.astype(jnp.float32).sum((1, 3)) / max(s * cfg.top_k, 1)
    pmean = probs.mean(1)                                       # (B,E)
    aux = cfg.n_experts * jnp.mean((frac * pmean).sum(-1))
    return constrain(y, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# SSM branch (Mamba2 SSD)
# ---------------------------------------------------------------------------

def _ssm_proj(sp: dict, x: jax.Array):
    dt_ = x.dtype
    xs = jnp.einsum("bsd,dhp->bshp", x, sp["in_x"].astype(dt_))
    z = jnp.einsum("bsd,dhp->bshp", x, sp["in_z"].astype(dt_))
    bmat = jnp.einsum("bsd,dn->bsn", x, sp["in_b"].astype(dt_))
    cmat = jnp.einsum("bsd,dn->bsn", x, sp["in_c"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, sp["in_dt"].astype(dt_))
    return xs, z, bmat, cmat, dt


def _causal_conv(seq, w, tail=None, lengths=None):
    """Depthwise causal conv. seq: (B,S,...) w: (W, ...); tail: (B,W-1,...).

    With ``lengths`` (B,) the returned tail holds the last W-1 inputs at or
    before position ``lengths[b]-1`` (ragged right-padded prefill); position
    ``p`` lives at index ``p + W-1`` of the padded buffer, so the tail spans
    indices ``lengths[b] .. lengths[b]+W-2``.
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((seq.shape[0], width - 1) + seq.shape[2:], seq.dtype)
    full = jnp.concatenate([tail, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i] for i in range(width))
    if width == 1:
        new_tail = tail
    elif lengths is None:
        new_tail = full[:, -(width - 1):]
    else:
        idx = lengths[:, None].astype(jnp.int32) + jnp.arange(width - 1)
        idx = idx.reshape(idx.shape + (1,) * (full.ndim - 2))
        new_tail = jnp.take_along_axis(full, idx, axis=1)
    return out, new_tail


def _ssm_forward(sp: dict, x: jax.Array, cfg: ArchConfig, *, h0=None,
                 conv_tail_x=None, conv_tail_bc=None, lengths=None):
    """Full-sequence SSD. Returns (y (B,S,D), h_fin, tail_x, tail_bc).

    ``lengths`` (B,) marks the valid prefix of a right-padded batch: padded
    positions get dt = 0, which makes their state update the identity
    (decay exp(a*0) = 1, input term dt*x = 0), so ``h_fin`` and the conv
    tails are exactly the state after position ``lengths[b]-1``.
    """
    xs, z, bmat, cmat, dt = _ssm_proj(sp, x)
    dt_ = x.dtype
    xs, tail_x = _causal_conv(xs, sp["conv_w"].astype(dt_), conv_tail_x,
                              lengths=lengths)
    xs = jax.nn.silu(xs)
    bc = jnp.concatenate([bmat, cmat], axis=-1)
    bc, tail_bc = _causal_conv(bc, sp["conv_bc_w"].astype(dt_), conv_tail_bc,
                               lengths=lengths)
    bc = jax.nn.silu(bc)
    n = cfg.ssm_state
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + sp["dt_bias"].astype(jnp.float32))
    if lengths is not None:
        keep = jnp.arange(x.shape[1])[None, :] < lengths[:, None]
        dt = jnp.where(keep[:, :, None], dt, 0.0)
    a = -jnp.exp(sp["a_log"].astype(jnp.float32))
    y, h_fin = ssd.ssd_scan(xs.astype(jnp.float32), dt, a,
                            bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32),
                            chunk=cfg.ssd_chunk, h0=h0)
    y = y + sp["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32)))
    y = rmsnorm(y, sp["gate_norm"]).astype(dt_)
    out = jnp.einsum("bshp,hpd->bsd", y, sp["out"].astype(dt_))
    return constrain(out, "batch", "seq", None), h_fin, tail_x, tail_bc


def _ssm_decode(sp: dict, x: jax.Array, h, tail_x, tail_bc, cfg: ArchConfig):
    """One-token SSD update; x (B,1,D). Returns (y, h, tail_x, tail_bc)."""
    xs, z, bmat, cmat, dt = _ssm_proj(sp, x)
    dt_ = x.dtype
    xs, tail_x = _causal_conv(xs, sp["conv_w"].astype(dt_), tail_x)
    xs = jax.nn.silu(xs)
    bc = jnp.concatenate([bmat, cmat], axis=-1)
    bc, tail_bc = _causal_conv(bc, sp["conv_bc_w"].astype(dt_), tail_bc)
    bc = jax.nn.silu(bc)
    n = cfg.ssm_state
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + sp["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(sp["a_log"].astype(jnp.float32))
    y1, h = ssd.ssd_decode_step(h, xs[:, 0].astype(jnp.float32), dt[:, 0], a,
                                bmat[:, 0].astype(jnp.float32),
                                cmat[:, 0].astype(jnp.float32))
    y1 = y1 + sp["d_skip"].astype(jnp.float32)[None, :, None] \
        * xs[:, 0].astype(jnp.float32)
    y1 = y1 * jax.nn.silu(z[:, 0].astype(jnp.float32))
    y1 = rmsnorm(y1, sp["gate_norm"]).astype(dt_)
    out = jnp.einsum("bhp,hpd->bd", y1, sp["out"].astype(dt_))[:, None]
    return out, h, tail_x, tail_bc


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _layer_train(lp: dict, x: jax.Array, cfg: ArchConfig):
    """Full-sequence layer (train / prefill w/o cache emission)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, lp["ln1"])
    mask_kind = "local" if cfg.window else "causal"
    if cfg.family == "dense" or cfg.family == "moe":
        y, _, _ = _attention(lp["attn"], h, cfg, mask_kind=mask_kind)
        x = x + y
    elif cfg.family == "ssm":
        y, _, _, _ = _ssm_forward(lp["ssm"], h, cfg)
        x = x + y
    elif cfg.family == "hybrid":
        ya, _, _ = _attention(lp["attn"], h, cfg, mask_kind=mask_kind)
        ys, _, _, _ = _ssm_forward(lp["ssm"], h, cfg)
        y = 0.5 * (rmsnorm(ya, lp["branch_norm_attn"])
                   + rmsnorm(ys, lp["branch_norm_ssm"]))
        x = x + y
    if cfg.family == "moe":
        h2 = rmsnorm(x, lp["ln2"])
        y2, aux = _moe_ffn(lp["moe"], h2, cfg)
        x = x + y2
    elif cfg.family in ("dense", "hybrid"):
        h2 = rmsnorm(x, lp["ln2"])
        m = lp["mlp"]
        dt = x.dtype
        x = x + swiglu(h2, m["wi"].astype(dt), m["wo"].astype(dt))
    return x, aux


def _layer_prefill(lp: dict, x: jax.Array, cfg: ArchConfig, lengths=None):
    """Like _layer_train but emits this layer's cache entries.

    ``lengths`` (B,) enables ragged right-padded prefill: the causal mask
    already keeps padded keys out of real queries' attention, so only the
    state-carrying paths (SSM scan, conv tails, MoE capacity) need it.
    """
    cache = {}
    h = rmsnorm(x, lp["ln1"])
    mask_kind = "local" if cfg.window else "causal"
    if cfg.family in ("dense", "moe", "hybrid"):
        y, k, v = _attention(lp["attn"], h, cfg, mask_kind=mask_kind)
        cache["k"], cache["v"] = k, v
    if cfg.family in ("ssm", "hybrid"):
        ys, hf, tx, tbc = _ssm_forward(lp["ssm"], h, cfg, lengths=lengths)
        cache["ssm_h"], cache["conv_x"], cache["conv_bc"] = hf, tx, tbc
    if cfg.family in ("dense", "moe"):
        x = x + y
    elif cfg.family == "ssm":
        x = x + ys
    else:
        x = x + 0.5 * (rmsnorm(y, lp["branch_norm_attn"])
                       + rmsnorm(ys, lp["branch_norm_ssm"]))
    if cfg.family == "moe":
        valid = None
        if lengths is not None:
            valid = jnp.arange(x.shape[1])[None, :] < lengths[:, None]
        y2, _ = _moe_ffn(lp["moe"], rmsnorm(x, lp["ln2"]), cfg, valid=valid)
        x = x + y2
    elif cfg.family in ("dense", "hybrid"):
        m = lp["mlp"]
        dt = x.dtype
        x = x + swiglu(rmsnorm(x, lp["ln2"]), m["wi"].astype(dt),
                       m["wo"].astype(dt))
    return x, cache


def _layer_decode(lp: dict, cache_l: dict, x: jax.Array, lengths,
                  cfg: ArchConfig, *, active=None, page_table=None,
                  phi_pages=None, max_pages=None):
    new_cache = dict(cache_l)
    h = rmsnorm(x, lp["ln1"])
    if cfg.family in ("dense", "moe", "hybrid"):
        paged = "pages_k" in cache_l
        kk, vv = ("pages_k", "pages_v") if paged else ("k", "v")
        y, kc, vc = _attention_decode(
            lp["attn"], h, cache_l[kk], cache_l[vv], lengths, cfg,
            active=active, page_table=page_table if paged else None,
            phi_pages=phi_pages if paged else None, max_pages=max_pages)
        new_cache[kk], new_cache[vv] = kc, vc
    if cfg.family in ("ssm", "hybrid"):
        ys, hs, tx, tbc = _ssm_decode(lp["ssm"], h, cache_l["ssm_h"],
                                      cache_l["conv_x"], cache_l["conv_bc"],
                                      cfg)
        if active is not None:       # freeze retired slots' SSM state too
            hs = jnp.where(active[:, None, None, None], hs, cache_l["ssm_h"])
            tx = jnp.where(active[:, None, None, None], tx, cache_l["conv_x"])
            tbc = jnp.where(active[:, None, None], tbc, cache_l["conv_bc"])
        new_cache["ssm_h"], new_cache["conv_x"] = hs, tx
        new_cache["conv_bc"] = tbc
    if cfg.family in ("dense", "moe"):
        x = x + y
    elif cfg.family == "ssm":
        x = x + ys
    else:
        x = x + 0.5 * (rmsnorm(y, lp["branch_norm_attn"])
                       + rmsnorm(ys, lp["branch_norm_ssm"]))
    if cfg.family == "moe":
        y2, _ = _moe_ffn(lp["moe"], rmsnorm(x, lp["ln2"]), cfg)
        x = x + y2
    elif cfg.family in ("dense", "hybrid"):
        m = lp["mlp"]
        dt = x.dtype
        x = x + swiglu(rmsnorm(x, lp["ln2"]), m["wi"].astype(dt),
                       m["wo"].astype(dt))
    return x, new_cache


def _layer_chunk(lp: dict, cache_l: dict, x: jax.Array, cfg: ArchConfig, *,
                 offsets, chunk_lens, page_table=None, max_pages=None):
    """One layer of chunked prefill: C tokens appended against the slot
    cache. Mirrors ``_layer_decode``'s freeze discipline — rows with
    ``chunk_lens == 0`` keep their cache bit-identical."""
    new_cache = dict(cache_l)
    part = chunk_lens > 0                       # participating rows
    first = offsets == 0                        # rows starting a fresh prompt
    h = rmsnorm(x, lp["ln1"])
    if cfg.family in ("dense", "moe", "hybrid"):
        paged = "pages_k" in cache_l
        kk, vv = ("pages_k", "pages_v") if paged else ("k", "v")
        y, kc, vc = _attention_chunk(
            lp["attn"], h, cache_l[kk], cache_l[vv], cfg,
            offsets=offsets, chunk_lens=chunk_lens,
            page_table=page_table if paged else None, max_pages=max_pages)
        new_cache[kk], new_cache[vv] = kc, vc
    if cfg.family in ("ssm", "hybrid"):
        # a fresh prompt starts from zero state (the slot may hold a prior
        # occupant's state); continuation chunks carry the cached state.
        # _ssm_forward(lengths=chunk_lens) gives padded positions dt = 0,
        # so h_fin / conv tails land exactly after position chunk_lens-1;
        # non-participating rows are where-frozen like decode.
        h0 = jnp.where(first[:, None, None, None], 0.0, cache_l["ssm_h"])
        tx0 = jnp.where(first[:, None, None, None], 0.0, cache_l["conv_x"])
        tbc0 = jnp.where(first[:, None, None], 0.0, cache_l["conv_bc"])
        ys, hf, tx, tbc = _ssm_forward(lp["ssm"], h, cfg, h0=h0,
                                       conv_tail_x=tx0, conv_tail_bc=tbc0,
                                       lengths=chunk_lens)
        hf = jnp.where(part[:, None, None, None], hf, cache_l["ssm_h"])
        tx = jnp.where(part[:, None, None, None], tx, cache_l["conv_x"])
        tbc = jnp.where(part[:, None, None], tbc, cache_l["conv_bc"])
        new_cache["ssm_h"], new_cache["conv_x"] = hf, tx
        new_cache["conv_bc"] = tbc
    if cfg.family in ("dense", "moe"):
        x = x + y
    elif cfg.family == "ssm":
        x = x + ys
    else:
        x = x + 0.5 * (rmsnorm(y, lp["branch_norm_attn"])
                       + rmsnorm(ys, lp["branch_norm_ssm"]))
    if cfg.family == "moe":
        valid = jnp.arange(x.shape[1])[None, :] < chunk_lens[:, None]
        y2, _ = _moe_ffn(lp["moe"], rmsnorm(x, lp["ln2"]), cfg, valid=valid)
        x = x + y2
    elif cfg.family in ("dense", "hybrid"):
        m = lp["mlp"]
        dt = x.dtype
        x = x + swiglu(rmsnorm(x, lp["ln2"]), m["wi"].astype(dt),
                       m["wo"].astype(dt))
    return x, new_cache


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Full-model entry points
# ---------------------------------------------------------------------------

def _embed_in(params, tokens, frontend, cfg: ArchConfig):
    dt = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens).astype(dt)
    # float(): a NUMPY scalar is strongly typed and silently promotes the
    # whole residual stream to f32 (doubled every activation byte and
    # collective model-wide — EXPERIMENTS.md §Perf iteration 3).
    x = x * float(np.sqrt(cfg.d_model))
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(dt), x], axis=1)
    return constrain(x, "batch", "seq", None)


def _compute_layers(params, cfg: ArchConfig):
    """Cast stacked layer weights to the compute dtype BEFORE the scan so the
    per-layer FSDP all-gather moves bf16, not the fp32 master copy (halves
    the dominant collective in the train roofline).

    The cast copy is re-pinned to the parameter shardings. This matters for
    the BACKWARD pass: ``with_sharding_constraint`` is self-transposing, so
    the cotangent (the layer-scan transpose's gradient accumulator) inherits
    the same sharding — without it GSPMD materializes FULL per-device
    stacked gradients (measured 549 GB/device on command-r; §Perf iter 1)."""
    dt = jnp.dtype(cfg.dtype)
    casted = jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params["layers"])
    ctx = dshard.get_active_mesh()
    if ctx is None:
        return casted
    mesh, rules = ctx
    from jax.sharding import NamedSharding
    from repro.models.common import stack_layers
    tmpl = stack_layers(_layer_template(cfg), cfg.n_layers)

    def pin(x, pdef):
        spec = dshard.spec_for(pdef.axes, mesh, rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return jax.tree.map(pin, casted, tmpl)


def forward_hidden(params, tokens, cfg: ArchConfig, frontend=None):
    """Embed + layer scan + final norm. Returns (hidden (B,S,D), aux)."""
    x = _embed_in(params, tokens, frontend, cfg)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_train(lp, x, cfg)
        return (x, aux + a), None

    body = _maybe_remat(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               _compute_layers(params, cfg),
                               unroll=flags.scan_unroll(cfg.n_layers))
    return rmsnorm(x, params["final_norm"]), aux


def loss_fn(params, batch, cfg: ArchConfig):
    """Next-token CE (+ MoE aux) over the token region (frontend excluded)."""
    frontend = batch.get("frontend")
    hid, aux = forward_hidden(params, batch["tokens"], cfg, frontend)
    if frontend is not None:
        hid = hid[:, frontend.shape[1]:]
    logits = unembed_logits(hid, params["embed"].astype(hid.dtype))
    ce = cross_entropy_loss(logits, batch["labels"], cfg.vocab)
    return ce + 0.01 * aux


def prefill(params, batch, cfg: ArchConfig, *, max_len: Optional[int] = None,
            lengths=None):
    """Run the prompt; return (last-position logits, cache).

    The cache is allocated at ``max_len`` (>= prompt length + decode budget)
    or at ``window`` for sliding-window attention (ring buffer).

    ``lengths`` (B,) int32 enables RAGGED right-padded prefill: row ``b``'s
    valid prompt (frontend included) is positions ``0 .. lengths[b]-1``.
    Logits are gathered at each row's last valid position, the SSM state /
    conv tails freeze there, MoE capacity ignores padding, and the ring
    cache is filled per-request. Positions past ``lengths[b]`` hold junk
    that decode-time length masking never reads and decode writes overwrite.
    """
    tokens = batch["tokens"]
    frontend = batch.get("frontend")
    b, s = tokens.shape
    total = s + (frontend.shape[1] if frontend is not None else 0)
    max_len = max_len or total
    lengths = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    x = _embed_in(params, tokens, frontend, cfg)

    def body(x, lp):
        x, cache_l = _layer_prefill(lp, x, cfg, lengths=lengths)
        return x, cache_l

    x, caches = jax.lax.scan(body, x, _compute_layers(params, cfg),
                             unroll=flags.scan_unroll(cfg.n_layers))
    hid = rmsnorm(x, params["final_norm"])
    if lengths is None:
        last = hid[:, -1:]
    else:
        last = jnp.take_along_axis(hid, (lengths - 1)[:, None, None], axis=1)
    logits = unembed_logits(last, params["embed"].astype(hid.dtype))

    lens = (jnp.full((b,), total, jnp.int32) if lengths is None else lengths)
    cache = {"length": lens}
    if "k" in caches:
        sc = cfg.window if (cfg.window and cfg.window < max_len) else max_len
        # k/v ride in the cfg's cache layout straight out of _attention:
        # kernel (L,B,KVH,S,hd) — seq axis 3; legacy (L,B,S,KVH,hd) — axis 2
        kernel = cfg.cache_layout == "kernel"
        ring = bool(cfg.window) and cfg.window < max_len
        seq_ax = 3 if kernel else 2
        k, v = caches["k"], caches["v"]
        if sc >= total:
            # full caches AND ring caches whose prompt fits the window
            pad = [(0, 0)] * 5
            pad[seq_ax] = (0, sc - total)
            if kernel:   # match init_cache's once-at-allocation lane pad
                pad[4] = (0, _contig_hd_alloc(cfg, ring) - k.shape[-1])
            cache["k"] = jnp.pad(k, pad)
            cache["v"] = jnp.pad(v, pad)
        else:
            # ring invariant: slot s holds the last position p < len with
            # p ≡ s (mod window); slots with no such p >= 0 are junk the
            # decode-side validity test (pos >= 0) never reads.
            slot = jnp.arange(sc)
            last_pos = (lens - 1)[:, None]                     # (B, 1)
            pos = last_pos - ((last_pos - slot[None, :]) % sc)  # (B, sc)
            idx = jnp.clip(pos, 0, total - 1)
            shape = [1, b, 1, 1, 1]
            shape[seq_ax] = sc
            idx = idx.reshape(shape)
            cache["k"] = jnp.take_along_axis(k, idx, axis=seq_ax)
            cache["v"] = jnp.take_along_axis(v, idx, axis=seq_ax)
    for key in ("ssm_h", "conv_x", "conv_bc"):
        if key in caches:
            cache[key] = caches[key]
    return logits, cache


def decode_step(params, cache, tokens, cfg: ArchConfig, *, max_pages=None):
    """One decode step. tokens: (B, 1) — appended at position cache.length.

    Rows with ``cache["length"] == 0`` are INACTIVE (a freed serve slot, or
    a never-admitted lane) and are frozen: no KV/SSM write, no length
    advance. Prefill always leaves length >= 1, so length-0 is an exact
    idle marker — the serve engine zeroes a slot's length at retire and
    this mask keeps the lane inert until the slot is reused.

    ``max_pages`` (static) caps the pages any request can reference this
    step (paged caches only) — the serve engine passes a power-of-two
    rounding of its host-side longest live length, which bounds the paged
    XLA fallback's gather at Θ(longest request) instead of the full
    page-table width.
    """
    active = cache["length"] > 0
    lengths = cache["length"] + active.astype(jnp.int32)
    x = _embed_in(params, tokens, None, cfg)

    paged = "pages_k" in cache
    page_table = cache.get("page_table")
    leaf_keys = (("pages_k", "pages_v") if paged else ("k", "v")) \
        + ("ssm_h", "conv_x", "conv_bc")
    layer_cache = {k: cache[k] for k in leaf_keys if k in cache}

    new_cache = dict(cache)
    if paged and "pages_phi" in cache:
        # the key factor row for the new position is layer-independent —
        # write it once, outside the layer scan (frozen rows drop). The
        # kernel-layout slab is lane-padded: pad the row to match (the
        # trailing zeros are inert in the factor dot).
        phi_pages = cache["pages_phi"]
        n_pages, ps, r_slab = phi_pages.shape
        pos = lengths - 1
        page = page_table[jnp.arange(pos.shape[0]), pos // ps]
        page = jnp.where(active, page, n_pages)
        row = jnp.stack([jnp.ones_like(pos, jnp.float32),
                         pos.astype(jnp.float32)], axis=-1)
        if r_slab > 2:
            row = jnp.pad(row, ((0, 0), (0, r_slab - 2)))
        phi_pages = phi_pages.at[page, pos % ps].set(row, mode="drop")
        new_cache["pages_phi"] = phi_pages
    else:
        phi_pages = None

    def body(x, inp):
        lp, cl = inp
        x, ncl = _layer_decode(lp, cl, x, lengths, cfg, active=active,
                               page_table=page_table, phi_pages=phi_pages,
                               max_pages=max_pages)
        return x, ncl

    x, new_layer_cache = jax.lax.scan(body, x,
                                      (_compute_layers(params, cfg),
                                       layer_cache),
                                      unroll=flags.scan_unroll(cfg.n_layers))
    hid = rmsnorm(x, params["final_norm"])
    logits = unembed_logits(hid, params["embed"].astype(hid.dtype))
    new_cache.update(new_layer_cache)
    new_cache["length"] = lengths
    return logits, new_cache


def prefill_chunk(params, cache, tokens, cfg: ArchConfig, *, offsets,
                  chunk_lens, final_lens, max_pages=None):
    """One chunked-prefill step: append a C-token chunk per slot.

    The chunked-prefill contract (the serve backend's planner drives this):

    - ``tokens`` (B, C) int32 — one fixed-size chunk per slot row, right-
      padded; row ``b``'s valid tokens are ``tokens[b, :chunk_lens[b]]`` and
      land at absolute positions ``offsets[b] .. offsets[b]+chunk_lens[b]-1``
      of the slot's cache. ``chunk_lens[b] == 0`` marks a frozen lane (a
      live decoding slot or an empty slot riding the fixed batch): its cache
      stays bit-identical.
    - ``offsets[b] == 0`` starts a fresh prompt: SSM state / conv tails
      reset to zero (the slot may hold a prior occupant's state); KV needs
      no reset — the offset causal mask never reads past the written prefix.
    - ``final_lens`` (B,) int32 is the post-chunk ``cache["length"]`` where
      ``>= 0`` and "keep the current value" where negative. Mid-prompt
      chunks pass -1 for every row: ``length`` stays 0 until the LAST chunk,
      which keeps the lane frozen under interleaved ``decode_step`` calls
      (the length-0 idle contract) and invisible to host-side page-growth
      accounting. The final chunk passes the full prompt length.
    - Returns ``(logits, cache)`` with logits (B, 1, V) gathered at each
      row's last valid chunk position — meaningful only for final chunks
      (the first sampled token), garbage on frozen/mid-prompt rows.

    Works against every cache kind: contiguous full KV (offset scatter),
    ring KV (pre-write window read + chunk rotation — chunk size must be
    <= window), paged KV (scatter through the slot's page table, ``phi_k``
    factor rows at absolute positions, gather capped by static
    ``max_pages`` like decode), and SSM/hybrid state carry.
    """
    b, c = tokens.shape
    offsets = jnp.asarray(offsets, jnp.int32)
    chunk_lens = jnp.asarray(chunk_lens, jnp.int32)
    final_lens = jnp.asarray(final_lens, jnp.int32)
    x = _embed_in(params, tokens, None, cfg)

    paged = "pages_k" in cache
    page_table = cache.get("page_table")
    leaf_keys = (("pages_k", "pages_v") if paged else ("k", "v")) \
        + ("ssm_h", "conv_x", "conv_bc")
    layer_cache = {k: cache[k] for k in leaf_keys if k in cache}

    new_cache = dict(cache)
    if paged and "pages_phi" in cache:
        # layer-independent key factor rows [1, pos] for the whole chunk —
        # written once, outside the layer scan, exactly like decode_step
        phi_pages = cache["pages_phi"]
        n_pages, ps, r_slab = phi_pages.shape
        i = jnp.arange(c)
        pos = offsets[:, None] + i[None, :]
        valid = i[None, :] < chunk_lens[:, None]
        page = jnp.where(valid, page_table[jnp.arange(b)[:, None], pos // ps],
                         n_pages)
        row = jnp.stack([jnp.ones((b, c), jnp.float32),
                         pos.astype(jnp.float32)], axis=-1)
        if r_slab > 2:
            row = jnp.pad(row, ((0, 0), (0, 0), (0, r_slab - 2)))
        phi_pages = phi_pages.at[page.reshape(-1), (pos % ps).reshape(-1)].set(
            row.reshape(b * c, r_slab), mode="drop")
        new_cache["pages_phi"] = phi_pages

    def body(x, inp):
        lp, cl = inp
        x, ncl = _layer_chunk(lp, cl, x, cfg, offsets=offsets,
                              chunk_lens=chunk_lens, page_table=page_table,
                              max_pages=max_pages)
        return x, ncl

    x, new_layer_cache = jax.lax.scan(body, x,
                                      (_compute_layers(params, cfg),
                                       layer_cache),
                                      unroll=flags.scan_unroll(cfg.n_layers))
    hid = rmsnorm(x, params["final_norm"])
    last = jnp.take_along_axis(
        hid, jnp.clip(chunk_lens - 1, 0)[:, None, None], axis=1)
    logits = unembed_logits(last, params["embed"].astype(hid.dtype))
    new_cache.update(new_layer_cache)
    new_cache["length"] = jnp.where(final_lens >= 0, final_lens,
                                    cache["length"])
    return logits, new_cache


def _contig_hd_alloc(cfg: ArchConfig, ring: bool) -> int:
    """Stored head dim of a kernel-layout contiguous cache: 128-lane-padded
    when the Pallas kernel will consume it (pad once at allocation, never
    per step), raw ``hd`` for ring caches (dense XLA window path) and XLA
    backends (head-major einsums read unpadded pools directly)."""
    hd = cfg.resolved_head_dim
    if ring or not kops.resolve_impl(cfg.attn_impl).startswith("pallas"):
        return hd
    return -(-hd // 128) * 128


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               length: int = 0) -> dict:
    """Empty cache pytree (zeros) for decode-only dry-runs and serving.

    Layout follows ``cfg.cache_layout``: kernel-native kv-head-major
    ``(L, B, KVH, S, hd[_pad])`` (the flash-decode kernel reads it
    zero-copy — see ops.py's layout contract; like the paged pools, the
    head dim is 128-lane-padded HERE, ONCE, when a Pallas impl will run
    and the cache is full-KV — a non-aligned hd like stablelm's 160 would
    otherwise be re-padded every decode step, the exact Θ(pool) cost this
    layout deletes; ring caches feed the dense XLA window path and stay
    unpadded) or legacy canonical ``(L, B, S, KVH, hd)`` (the A/B +
    parity reference).
    """
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    cache = {"length": jnp.full((batch,), length, jnp.int32)}
    if cfg.family in ("dense", "moe", "hybrid"):
        ring = bool(cfg.window) and cfg.window < max_len
        sc = cfg.window if ring else max_len
        kvp, hd = cfg.kv_heads_padded, cfg.resolved_head_dim
        if cfg.cache_layout == "kernel":
            hd_alloc = _contig_hd_alloc(cfg, ring)
            cache["k"] = jnp.zeros((l, batch, kvp, sc, hd_alloc), dt)
            cache["v"] = jnp.zeros((l, batch, kvp, sc, hd_alloc), dt)
        else:
            cache["k"] = jnp.zeros((l, batch, sc, kvp, hd), dt)
            cache["v"] = jnp.zeros((l, batch, sc, kvp, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        hs, p, n = cfg.ssm_heads_padded, cfg.ssm_head_dim, cfg.ssm_state
        w = cfg.conv_width
        cache["ssm_h"] = jnp.zeros((l, batch, hs, p, n), jnp.float32)
        cache["conv_x"] = jnp.zeros((l, batch, w - 1, hs, p), dt)
        cache["conv_bc"] = jnp.zeros((l, batch, w - 1, 2 * n), dt)
    return cache


def init_paged_cache(cfg: ArchConfig, batch: int, n_pages: int,
                     page_size: int, pages_per_slot: Optional[int] = None
                     ) -> dict:
    """Paged cache pytree: a shared page pool + per-slot page tables.

    Every full-KV cache leaf is paged — K, V, and the per-page ``phi_k``
    factor slab (``pages_phi``, float32 so positions stay exact: the rank-2
    ALiBi key factor ``[1, pos]`` rides with k at Theta(N R) storage,
    FlashBias Thm 3.2 / Sec. 4.3). ``page_table`` maps each slot's logical
    block j to its physical page; unmapped entries may hold anything — the
    decode path clamps them and the length mask discards what they read.
    Ring-KV (sliding window) and SSM state are constant-size per slot and
    stay on the slot-contiguous discipline; SSM leaves of a hybrid arch
    ride along unchanged.

    Under ``cfg.cache_layout == "kernel"`` the pools are born in the
    flash-decode kernel's native layout — kv-head-major ``(L, KVH,
    n_pages, ps, hd[_pad])`` — and the slab stays layer- and kv-head-
    shared (the kv-head broadcast lives in the kernel's block index maps).
    The decode step then hands every pool to the kernel zero-copy. The
    128-lane pad on the trailing dim exists purely for the Pallas TPU
    tiles, so it is applied HERE, ONCE, and only when a Pallas impl will
    actually run (``resolve_impl``); the XLA fallback keeps unpadded pools
    and would otherwise gather real padding bytes every step. ``"legacy"``
    keeps the canonical ``(L, n_pages, ps, KVH, hd)`` pools + ``(n_pages,
    ps, 2)`` slab that ops re-lays-out per step (the layout_vs_legacy A/B
    baseline).
    """
    assert cfg.family in ("dense", "moe", "hybrid"), cfg.family
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    kvp, hd = cfg.kv_heads_padded, cfg.resolved_head_dim
    pps = pages_per_slot or n_pages
    kernel = cfg.cache_layout == "kernel"
    pallas = kops.resolve_impl(cfg.attn_impl).startswith("pallas")
    if kernel:
        hd_pad = (-(-hd // 128) * 128) if pallas else hd
        pool_shape = (l, kvp, n_pages, page_size, hd_pad)
    else:
        pool_shape = (l, n_pages, page_size, kvp, hd)
    cache = {
        "length": jnp.zeros((batch,), jnp.int32),
        "pages_k": jnp.zeros(pool_shape, dt),
        "pages_v": jnp.zeros(pool_shape, dt),
        "page_table": jnp.zeros((batch, pps), jnp.int32),
    }
    if cfg.bias_kind == "alibi":
        r_slab = 128 if (kernel and pallas) else 2
        cache["pages_phi"] = jnp.zeros((n_pages, page_size, r_slab),
                                       jnp.float32)
    if cfg.family == "hybrid":
        hs, p, n = cfg.ssm_heads_padded, cfg.ssm_head_dim, cfg.ssm_state
        w = cfg.conv_width
        cache["ssm_h"] = jnp.zeros((l, batch, hs, p, n), jnp.float32)
        cache["conv_x"] = jnp.zeros((l, batch, w - 1, hs, p), dt)
        cache["conv_bc"] = jnp.zeros((l, batch, w - 1, 2 * n), dt)
    return cache


def insert_paged_cache_at_slots(dst: dict, src: dict, slots, tables, *,
                                layout: str = "kernel") -> dict:
    """Scatter a prefilled wave into the paged cache, whole pages at a time.

    ``src`` is a contiguous wave cache from ``prefill`` whose sequence
    length S is a page multiple, in the same ``layout`` the pool uses
    (prefill emits it that way — kernel-layout pages scatter into the
    kernel-layout pool DIRECTLY, there is no post-hoc fixup pass).
    ``tables`` (W, pages_per_slot) int32 holds each wave row's full
    page-table row — the pages covering its prompt first, then any pages
    reserved for decode growth; unused entries carry an out-of-range id
    (>= n_pages) and the corresponding page writes are DROPPED, exactly
    like out-of-range ``slots`` drop whole rows. Prompt pages scatter K/V
    content and position factors into the pool; the page table and
    per-slot ``length`` scatter at ``slots``; SSM leaves (hybrid) ride the
    slot path of ``insert_cache_at_slots``.
    """
    assert layout in ("kernel", "legacy"), layout
    slots = jnp.asarray(slots, jnp.int32)
    tables = jnp.asarray(tables, jnp.int32)
    kernel = layout == "kernel"
    if kernel:          # pool (L, KVH, n_pages, ps, hd_pad); src (L,W,KVH,S,hd)
        n_pages, ps = dst["pages_k"].shape[2], dst["pages_k"].shape[3]
        s = src["k"].shape[3]
    else:               # pool (L, n_pages, ps, KVH, hd); src (L, W, S, KVH, hd)
        n_pages, ps = dst["pages_k"].shape[1], dst["pages_k"].shape[2]
        s = src["k"].shape[2]
    w = tables.shape[0]
    assert s % ps == 0, (s, ps)
    p_w = s // ps
    if tables.shape[1] >= p_w:
        content_ids = tables[:, :p_w]
    else:
        content_ids = jnp.pad(tables, ((0, 0), (0, p_w - tables.shape[1])),
                              constant_values=n_pages)
    flat_ids = content_ids.reshape(-1)                    # (W * P_w,)

    out = dict(dst)
    for key, pool_key in (("k", "pages_k"), ("v", "pages_v")):
        kv = src[key]
        l = kv.shape[0]
        if kernel:
            kvh, hd = kv.shape[2], kv.shape[4]
            pages = kv.reshape(l, w, kvh, p_w, ps, hd)
            pages = pages.transpose(0, 2, 1, 3, 4, 5)
            pages = pages.reshape(l, kvh, w * p_w, ps, hd)
            hd_pad = dst[pool_key].shape[-1]
            if hd_pad != hd:              # pool is lane-padded at init
                pages = jnp.pad(pages, ((0, 0),) * 4 + ((0, hd_pad - hd),))
            out[pool_key] = dst[pool_key].at[:, :, flat_ids].set(
                pages, mode="drop")
        else:
            pages = kv.reshape(l, w * p_w, ps, *kv.shape[3:])
            out[pool_key] = dst[pool_key].at[:, flat_ids].set(pages,
                                                              mode="drop")
    if "pages_phi" in dst:
        r_slab = dst["pages_phi"].shape[-1]
        pos = jnp.arange(s, dtype=jnp.float32)
        rows = jnp.stack([jnp.ones_like(pos), pos], -1)   # (S, 2): [1, pos]
        if r_slab > 2:                    # lane-padded slab (kernel layout)
            rows = jnp.pad(rows, ((0, 0), (0, r_slab - 2)))
        rows = jnp.broadcast_to(rows.reshape(1, p_w, ps, r_slab),
                                (w, p_w, ps, r_slab))
        out["pages_phi"] = dst["pages_phi"].at[flat_ids].set(
            rows.reshape(w * p_w, ps, r_slab), mode="drop")
    out["page_table"] = dst["page_table"].at[slots].set(tables, mode="drop")
    out["length"] = dst["length"].at[slots].set(src["length"], mode="drop")
    for key in ("ssm_h", "conv_x", "conv_bc"):
        if key in dst:
            out[key] = dst[key].at[:, slots].set(src[key], mode="drop")
    return out


def grow_page_tables_at_slots(dst: dict, slots, tables) -> dict:
    """Rewrite the page-table rows of slots that grew a page mid-flight.

    Lazy page growth (ISSUE 4) appends physical pages to a live request as
    its length crosses page boundaries. Only the int32 table rows move —
    the pages already holding K/V content and ``phi_k`` factor rows are
    NOT re-scattered (``insert_paged_cache_at_slots`` moves content; this
    is its growth-only complement). ``tables`` (W, pages_per_slot) carries
    each growing slot's FULL new row (existing pages + the appended ones,
    then out-of-range sentinels); rows whose ``slots`` entry is out of
    range (>= n_slots) are dropped, so a fixed-width growth batch compiles
    once per engine."""
    slots = jnp.asarray(slots, jnp.int32)
    tables = jnp.asarray(tables, jnp.int32)
    out = dict(dst)
    out["page_table"] = dst["page_table"].at[slots].set(tables, mode="drop")
    return out


def copy_paged_pages(dst: dict, src_ids, dst_ids, *,
                     layout: str = "kernel") -> dict:
    """Copy whole pages ``src_ids[i] -> dst_ids[i]`` across every paged
    pool leaf: K, V, and the ``pages_phi`` factor slab.

    The copy-on-write primitive for prefix caching (ISSUE 9): when a
    request must write into a page other holders share (its prompt re-run
    span or decode growth lands mid-page), the engine allocates a private
    page and copies the shared content here before any write. ``src_ids``
    and ``dst_ids`` are fixed-width ``(W,)`` int32 vectors; entries whose
    dst id is out of range (>= n_pages) are DROPPED and their src id is
    only clamped, so a fixed-width CoW batch compiles once per engine.
    All gathers read the pre-copy pool, so a batch may even reuse a
    just-evicted src page as another entry's dst. Theta(W * page) — never
    pool-sized, and no relayout of the pool itself (statcheck
    ``no-pool-relayout`` holds for this program)."""
    assert layout in ("kernel", "legacy"), layout
    src_ids = jnp.asarray(src_ids, jnp.int32)
    dst_ids = jnp.asarray(dst_ids, jnp.int32)
    page_axis = 2 if layout == "kernel" else 1
    out = dict(dst)
    for pool_key in ("pages_k", "pages_v"):
        pool = dst[pool_key]
        take = jnp.clip(src_ids, 0, pool.shape[page_axis] - 1)
        if page_axis == 2:      # kernel: (L, KVH, n_pages, ps, hd_pad)
            out[pool_key] = pool.at[:, :, dst_ids].set(pool[:, :, take],
                                                       mode="drop")
        else:                   # legacy: (L, n_pages, ps, KVH, hd)
            out[pool_key] = pool.at[:, dst_ids].set(pool[:, take],
                                                    mode="drop")
    if "pages_phi" in dst:
        phi = dst["pages_phi"]                  # (n_pages, ps, r_slab)
        take = jnp.clip(src_ids, 0, phi.shape[0] - 1)
        out["pages_phi"] = phi.at[dst_ids].set(phi[take], mode="drop")
    return out


def insert_cache_at_slots(dst: dict, src: dict, slots) -> dict:
    """Scatter wave-cache rows of ``src`` into batch slots of ``dst``.

    ``slots`` (W,) int32 gives the destination slot of each wave row; rows
    whose entry is out of range (>= n_slots) are DROPPED, so a fixed-size
    prefill wave can carry padding rows without a second compile. Works for
    every cache kind: ``length`` is per-slot, everything else is layer-major
    ``(L, B, ...)`` — including per-slot ``phi_k`` factor rows if a model
    caches them.
    """
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, v in dst.items():
        if key == "length":
            out[key] = v.at[slots].set(src[key], mode="drop")
        else:
            out[key] = v.at[:, slots].set(src[key], mode="drop")
    return out
