"""Parameter-template machinery + layers shared by every model.

A model is described by a *template*: a pytree whose leaves are ``PDef``
(shape, logical sharding axes, init law, dtype). One template drives

- ``init_params``      — materialize real arrays (tests, examples),
- ``abstract_params``  — ShapeDtypeStructs (the dry-run never allocates),
- ``param_shardings``  — NamedShardings from the logical-axis rules,

so shapes, shardings and init can never drift apart. ``PDef`` is a pytree
*leaf* (deliberately not registered as a container).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as dshard

__all__ = ["PDef", "is_pdef", "tree_map_pdef", "init_params",
           "abstract_params", "param_shardings", "param_specs", "stack_layers",
           "cast_floats", "param_bytes", "rmsnorm", "swiglu", "gelu_mlp",
           "embed_lookup", "unembed_logits", "cross_entropy_loss",
           "apply_rope", "count_params"]


@dataclasses.dataclass(frozen=True)
class PDef:
    """One parameter: shape + logical axes + init law.

    init: ("normal", stddev) | ("zeros",) | ("ones",) | ("slopes", n_real)
    — "slopes" materializes ALiBi slopes for the first ``n_real`` heads and
    zeros for TP padding heads.
    """
    shape: tuple
    axes: tuple
    init: tuple = ("normal", 0.02)
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def tree_map_pdef(fn, tmpl, *rest):
    return jax.tree.map(fn, tmpl, *rest, is_leaf=is_pdef)


def _materialize(pdef: PDef, key) -> jax.Array:
    kind = pdef.init[0]
    dt = jnp.dtype(pdef.dtype)
    if kind == "zeros":
        return jnp.zeros(pdef.shape, dt)
    if kind == "ones":
        return jnp.ones(pdef.shape, dt)
    if kind == "slopes":
        from repro.core.bias import alibi_slopes
        n_real = pdef.init[1]
        s = alibi_slopes(n_real)
        s = jnp.concatenate([s, jnp.zeros((pdef.shape[-1] - n_real,))])
        return jnp.broadcast_to(s, pdef.shape).astype(dt)
    if kind == "normal":
        return (pdef.init[1] * jax.random.normal(key, pdef.shape)).astype(dt)
    raise ValueError(pdef.init)


def init_params(tmpl, key):
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=is_pdef)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_materialize(p, k) for p, k in zip(leaves, keys)])


def abstract_params(tmpl):
    return tree_map_pdef(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)), tmpl)


def param_specs(tmpl, mesh, rules: dshard.Rules):
    return tree_map_pdef(
        lambda p: dshard.spec_for(p.axes, mesh, rules), tmpl)


def param_shardings(tmpl, mesh, rules: dshard.Rules):
    from jax.sharding import NamedSharding
    return tree_map_pdef(
        lambda p: NamedSharding(mesh, dshard.spec_for(p.axes, mesh, rules)),
        tmpl)


def stack_layers(layer_tmpl, n_layers: int):
    """Add a leading scanned-layers dim (never sharded) to every leaf."""
    return tree_map_pdef(
        lambda p: PDef((n_layers,) + p.shape, ("layers",) + p.axes,
                       p.init, p.dtype),
        layer_tmpl)


def cast_floats(tree, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def param_bytes(tmpl) -> int:
    leaves = jax.tree.leaves(tmpl, is_leaf=is_pdef)
    return sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize
               for p in leaves)


def count_params(tmpl) -> int:
    leaves = jax.tree.leaves(tmpl, is_leaf=is_pdef)
    return sum(int(np.prod(p.shape)) for p in leaves)


# ---------------------------------------------------------------------------
# Layers (pure functions over param dicts)
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def swiglu(x, wi_fused, wo):
    """SwiGLU FFN with FUSED gate+up projection.

    ``wi_fused``: (d, f, 2) — gate at [..., 0], up at [..., 1]; the fused
    dim is trailing so the TP-sharded f dim stays evenly sharded (a (d, 2f)
    concat would put each half on half the shards). One matmul instead of
    two means the backward dL/dx is ONE transpose matmul -> ONE partial-sum
    all-reduce over the model axis instead of a combined pair (halves the
    MLP's backward activation wire; EXPERIMENTS.md §Perf iteration 4).
    """
    h2 = jnp.einsum("bsd,dft->bsft", x, wi_fused)
    h2 = dshard.constrain(h2, "batch", "seq", "mlp", None)
    h = jax.nn.silu(h2[..., 0]) * h2[..., 1]
    h = dshard.constrain(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, wo)


def gelu_mlp(x, wi, wo):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, wi))
    return jnp.einsum("...f,fd->...d", h, wo)


def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Gather rows of a (possibly vocab-sharded) embedding table."""
    return jnp.take(table, tokens, axis=0)


def unembed_logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """Tied unembedding: (B,S,D) @ (V,D)^T -> (B,S,V), vocab TP-sharded."""
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return dshard.constrain(logits, "batch", "seq", "vocab")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab_real: int) -> jax.Array:
    """Mean next-token CE. Uses take-along-vocab (no one-hot materialized —
    the (B,S,V) one-hot would dwarf everything else at V=256k)."""
    logits = logits.astype(jnp.float32)
    # padded vocab rows exist but labels never point at them; mask anyway
    if vocab_real < logits.shape[-1]:
        iota = jnp.arange(logits.shape[-1])
        logits = jnp.where(iota >= vocab_real, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def apply_rope(x: jax.Array, positions: jax.Array,
               base: float = 10000.0) -> jax.Array:
    """RoPE on (B,S,H,D) with positions (B,S). Kept for the multiplicative-
    bias extension (App. I); assigned LM archs default to FlashBias-ALiBi."""
    b, s, h, d = x.shape
    half = d // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)
