"""Transformer PDE solver with learnable-scaled spatial-distance bias.

Paper Sec. 4.4 / Table 5: 8 layers, 128 channels, 8 heads, FFN 256; bias
``f(x_i, x_j) = alpha_i * ||x_i - x_j||^2`` with alpha a *learnable*
token-wise weight (per head, per layer). FlashBias folds alpha into phi_q
(exact, rank 3d, Example 3.5) so training never materializes (nor stores the
gradient of) the N x N bias — the property that lets Table 5 train at 32186
points where dense-bias attention OOMs.

``bias_mode="dense"`` materializes the bias (the paper's baseline; OOMs at
large N by design). alpha is produced by a learnable linear map of the
coordinates (a token-wise function — general-N version of the paper's
per-token table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.configs.base import ArchConfig
from repro.core.bias import sqdist_factors
from repro.kernels import ops as kops
from repro.models.common import PDef, gelu_mlp, rmsnorm, stack_layers

__all__ = ["pde_template", "forward", "regression_loss"]


def pde_template(cfg: ArchConfig) -> dict:
    d, h, f, cd = cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.coord_dim
    hd = cfg.resolved_head_dim
    layer = {
        "ln1": PDef((d,), (None,), ("zeros",)),
        "wqkv": PDef((d, 3, h, hd), ("fsdp", None, "heads", None)),
        "wo": PDef((h, hd, d), ("heads", None, "fsdp")),
        "alpha_w": PDef((cd, h), (None, "heads"), ("normal", 0.2)),
        "alpha_b": PDef((h,), ("heads",), ("ones",)),
        "ln2": PDef((d,), (None,), ("zeros",)),
        "wi": PDef((d, f), ("fsdp", "mlp")),
        "wo_mlp": PDef((f, d), ("mlp", "fsdp")),
    }
    return {
        "in_proj": PDef((cd, d), (None, "fsdp")),
        "layers": stack_layers(layer, cfg.n_layers),
        "final_norm": PDef((d,), (None,), ("zeros",)),
        "out_head": PDef((d, 4), ("fsdp", None)),   # pressure + 3 velocity
    }


def _pde_attention(lp, x, coords, cfg: ArchConfig):
    """x: (B, N, D); coords: (B, N, cd)."""
    dt = x.dtype
    qkv = jnp.einsum("bnd,dthe->tbnhe", x, lp["wqkv"].astype(dt))
    q, k, v = qkv[0], qkv[1], qkv[2]
    # token-wise learnable alpha (>0 via softplus), one per head
    alpha = jax.nn.softplus(
        jnp.einsum("bnc,ch->bnh", coords.astype(jnp.float32), lp["alpha_w"])
        + lp["alpha_b"])                                        # (B,N,H)
    if cfg.bias_mode == "flashbias":
        # Exact rank-3d factors (Example 3.5); alpha folds into phi_q, so the
        # bias stays exact AND differentiable without an N x N gradient.
        pq0, pk0 = sqdist_factors(coords.astype(jnp.float32),
                                  coords.astype(jnp.float32), negate=True)
        pq = alpha[..., None] * pq0[:, :, None, :]      # (B,N,H,3d)
        pk = pk0[:, :, None, :]                         # (B,N,1,3d)
        o = kops.flash_attention(q, k, v, pq.astype(jnp.float32),
                                 pk.astype(jnp.float32), impl=cfg.attn_impl)
    else:
        from repro.core.attention import attention as core_attn
        from repro.core.bias import scaled_sqdist_dense
        bias = scaled_sqdist_dense(
            coords.astype(jnp.float32)[:, None],
            coords.astype(jnp.float32)[:, None],
            alpha.transpose(0, 2, 1), negate=True)               # (B,H,N,N)
        o = core_attn(q, k, v, bias=bias, impl="chunked",
                      chunk_size=cfg.attn_chunk)
    return jnp.einsum("bnhe,hed->bnd", o, lp["wo"].astype(dt))


def forward(params, coords, cfg: ArchConfig):
    """coords: (B, N, coord_dim) mesh points -> (B, N, 4) physics fields."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bnc,cd->bnd", coords.astype(dt),
                   params["in_proj"].astype(dt))

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"])
        x = x + _pde_attention(lp, h, coords, cfg)
        h2 = rmsnorm(x, lp["ln2"])
        x = x + gelu_mlp(h2, lp["wi"].astype(dt), lp["wo_mlp"].astype(dt))
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"],
                     unroll=flags.scan_unroll(cfg.n_layers))
    x = rmsnorm(x, params["final_norm"])
    return jnp.einsum("bnd,do->bno", x, params["out_head"].astype(dt))


def regression_loss(params, batch, cfg: ArchConfig):
    pred = forward(params, batch["coords"], cfg).astype(jnp.float32)
    return jnp.mean((pred - batch["targets"].astype(jnp.float32)) ** 2)
