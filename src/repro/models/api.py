"""Uniform Model interface consumed by the trainer, server and dry-run.

``get_model(cfg)`` returns a ``Model`` with:

- ``template()``                     — PDef tree (shapes + sharding axes),
- ``loss(params, batch)``            — training loss,
- ``prefill(params, batch, max_len, lengths)`` — prompt -> (logits, cache);
  ``lengths`` (B,) enables ragged right-padded prompts (logits gathered at
  each row's last valid position, state paths freeze there),
- ``prefill_chunk(params, cache, tokens, offsets, chunk_lens, final_lens,
  max_pages=None)`` — chunked prefill (LM families): append one fixed-size
  chunk per slot row against the EXISTING slot cache; rows with
  ``chunk_lens == 0`` stay bit-identical (the serve backend interleaves
  one chunk batch with each decode step),
- ``decode(params, cache, tokens, max_pages=None)`` — one token ->
  (logits, cache); ``max_pages`` (static) caps the pages a paged decode
  step can reference (the serve engine derives it from host-side lengths),
- ``init_cache(batch, max_len)``     — zeroed cache pytree (stored in the
  kernel-native kv-head-major layout unless ``cfg.cache_layout="legacy"``
  — see ops.py's cache layout contract),
- ``insert_cache(dst, src, slots)``  — scatter prefilled wave rows into the
  serve engine's slot cache (out-of-range slot ids are dropped),
- ``init_paged_cache(batch, n_pages, page_size, pages_per_slot)`` — zeroed
  PAGED cache (shared K/V/phi-factor page pool + per-slot page tables) for
  full-KV decode families,
- ``insert_paged(dst, src, slots, tables)`` — scatter a prefilled wave into
  the paged cache whole pages at a time (``tables`` carries each row's
  page-table row; out-of-range page/slot ids are dropped),
- ``grow_page_table(dst, slots, tables)`` — rewrite page-table rows for
  slots that grew a page mid-flight (lazy growth); existing page CONTENT
  is not re-scattered, only the int32 rows move,
- ``copy_pages(dst, src_ids, dst_ids)`` — copy whole pages (K/V +
  ``pages_phi`` rows) between pool slots: the copy-on-write primitive for
  prefix caching (out-of-range dst ids are dropped),
- ``input_specs(shape)``             — ShapeDtypeStruct stand-ins for every
  model input of an assigned (shape) cell: weak-type-correct, shardable,
  never allocated. This is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import lm, pairformer, pde, swin

__all__ = ["Model", "get_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    template: Callable[[], dict]
    loss: Callable
    prefill: Optional[Callable] = None
    prefill_chunk: Optional[Callable] = None
    decode: Optional[Callable] = None
    init_cache: Optional[Callable] = None
    insert_cache: Optional[Callable] = None
    init_paged_cache: Optional[Callable] = None
    insert_paged: Optional[Callable] = None
    grow_page_table: Optional[Callable] = None
    copy_pages: Optional[Callable] = None
    input_specs: Optional[Callable] = None


def _lm_model(cfg: ArchConfig) -> Model:
    def input_specs(shape: ShapeSpec, *, abstract_cache: bool = True):
        """Inputs for one dry-run cell. For decode kinds this includes the
        KV/SSM cache as ShapeDtypeStructs (``serve_step`` takes it as input).
        """
        b, s = shape.global_batch, shape.seq_len
        tok = jnp.int32
        front = cfg.frontend_len
        specs: dict = {}
        if shape.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - front), tok)
            specs["labels"] = jax.ShapeDtypeStruct((b, s - front), tok)
            if front:
                specs["frontend"] = jax.ShapeDtypeStruct(
                    (b, front, cfg.d_model), jnp.dtype(cfg.dtype))
        elif shape.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - front), tok)
            if front:
                specs["frontend"] = jax.ShapeDtypeStruct(
                    (b, front, cfg.d_model), jnp.dtype(cfg.dtype))
        elif shape.kind == "decode":
            if abstract_cache:   # never allocates (command-r 32k cache = TBs)
                cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
            else:
                cache = lm.init_cache(cfg, b, s)
            specs["cache"] = cache
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), tok)
        return specs

    return Model(
        cfg=cfg,
        template=lambda: lm.lm_template(cfg),
        loss=lambda p, batch: lm.loss_fn(p, batch, cfg),
        prefill=lambda p, batch, max_len=None, lengths=None: lm.prefill(
            p, batch, cfg, max_len=max_len, lengths=lengths),
        # chunked prefill (ISSUE 7): append a C-token chunk per slot against
        # the EXISTING slot cache — offsets/chunk_lens/final_lens per row,
        # chunk_lens == 0 freezes a lane (see lm.prefill_chunk's contract)
        prefill_chunk=lambda p, cache, tokens, offsets, chunk_lens,
            final_lens, max_pages=None: lm.prefill_chunk(
                p, cache, tokens, cfg, offsets=offsets,
                chunk_lens=chunk_lens, final_lens=final_lens,
                max_pages=max_pages),
        decode=lambda p, cache, tokens, max_pages=None: lm.decode_step(
            p, cache, tokens, cfg, max_pages=max_pages),
        init_cache=lambda b, max_len, length=0: lm.init_cache(
            cfg, b, max_len, length=length),
        insert_cache=lm.insert_cache_at_slots,
        init_paged_cache=(
            (lambda b, n_pages, page_size, pages_per_slot=None:
             lm.init_paged_cache(cfg, b, n_pages, page_size, pages_per_slot))
            if cfg.family in ("dense", "moe", "hybrid") else None),
        insert_paged=(
            (lambda dst, src, slots, tables: lm.insert_paged_cache_at_slots(
                dst, src, slots, tables, layout=cfg.cache_layout))
            if cfg.family in ("dense", "moe", "hybrid") else None),
        grow_page_table=(lm.grow_page_tables_at_slots
                         if cfg.family in ("dense", "moe", "hybrid")
                         else None),
        copy_pages=(
            (lambda dst, src_ids, dst_ids: lm.copy_paged_pages(
                dst, src_ids, dst_ids, layout=cfg.cache_layout))
            if cfg.family in ("dense", "moe", "hybrid") else None),
        input_specs=input_specs,
    )


def _swin_model(cfg: ArchConfig) -> Model:
    def input_specs(shape: ShapeSpec, **_):
        b = shape.global_batch
        return {"patches": jax.ShapeDtypeStruct((b, 4, cfg.window, 48),
                                                jnp.float32),
                "labels": jax.ShapeDtypeStruct((b,), jnp.int32)}
    return Model(cfg=cfg,
                 template=lambda: swin.swin_template(cfg),
                 loss=lambda p, batch: swin.classify_loss(p, batch, cfg),
                 input_specs=input_specs)


def _pde_model(cfg: ArchConfig) -> Model:
    def input_specs(shape: ShapeSpec, **_):
        b, n = shape.global_batch, shape.seq_len
        return {"coords": jax.ShapeDtypeStruct((b, n, cfg.coord_dim),
                                               jnp.float32),
                "targets": jax.ShapeDtypeStruct((b, n, 4), jnp.float32)}
    return Model(cfg=cfg,
                 template=lambda: pde.pde_template(cfg),
                 loss=lambda p, batch: pde.regression_loss(p, batch, cfg),
                 input_specs=input_specs)


def _pairformer_model(cfg: ArchConfig) -> Model:
    def input_specs(shape: ShapeSpec, **_):
        b, n = shape.global_batch, shape.seq_len
        return {"feats": jax.ShapeDtypeStruct((b, n, 64), jnp.float32),
                "coords": jax.ShapeDtypeStruct((b, n, 3), jnp.float32)}
    return Model(
        cfg=cfg,
        template=lambda: pairformer.pairformer_template(cfg),
        loss=lambda p, batch: pairformer.denoise_loss(p, batch, cfg),
        # batched serve path (ISSUE 6): "prefill" is the admission trunk
        # pass capturing per-complex bias state, "decode" one refinement
        # iteration over the slot batch. ``factors`` (the fitted factor
        # MLPs) is backend state the PairBatchBackend closes over.
        prefill=lambda p, batch, max_len=None, lengths=None, factors=None:
            pairformer.serve_prefill(p, batch, cfg, factors,
                                     max_len=max_len, lengths=lengths),
        decode=lambda p, cache, tokens=None, max_pages=None:
            pairformer.serve_step(p, cache, cfg),
        init_cache=lambda b, max_len, length=0, factors=None:
            pairformer.init_serve_cache(cfg, b, max_len, factors=factors),
        insert_cache=pairformer.insert_serve_cache_at_slots,
        input_specs=input_specs)


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return _lm_model(cfg)
    if cfg.family == "swin":
        return _swin_model(cfg)
    if cfg.family == "pde":
        return _pde_model(cfg)
    if cfg.family == "pairformer":
        return _pairformer_model(cfg)
    raise ValueError(cfg.family)
