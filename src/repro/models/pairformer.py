"""Pairformer-lite: attention with pair-representation bias (AF3, Sec. 4.4).

Structure per block (a faithful-in-shape reduction of AF3's Pairformer):

1. triangle multiplicative update (outgoing) on the pair rep z (B,N,N,Dp),
2. single-rep attention whose logits take an additive bias PROJECTED FROM z
   — the dynamic, per-sample bias that motivates the paper's *neural
   decomposition* (Table 1 row c),
3. transition MLPs on both representations.

``bias_mode``:
- "dense"     — project z -> (B,H,N,N) bias and add to logits (official path),
- "flashbias" — token-wise factor MLPs phi_q/phi_k approximate the projected
  bias (Eq. 5); inputs are row/col summaries of z + the single rep, matching
  App. H Table 12 ("sum of row and column in pair representation" + single).

``fit_factor_mlps`` runs the paper's fine-tuning loop: freeze the trunk,
minimize || phi_q phi_k^T - bias ||^2 on sampled inputs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.kernels import ops as kops
from repro.models.common import PDef, gelu_mlp, rmsnorm, stack_layers

__all__ = ["pairformer_template", "forward", "denoise_loss",
           "factor_mlp_template", "fit_factor_mlps",
           "init_serve_cache", "serve_prefill", "serve_step",
           "insert_serve_cache_at_slots"]


def pairformer_template(cfg: ArchConfig) -> dict:
    d, dp, h, f = cfg.d_model, cfg.d_pair, cfg.n_heads, cfg.d_ff
    hd = cfg.resolved_head_dim
    layer = {
        # triangle multiplicative update (outgoing)
        "tri_ln": PDef((dp,), (None,), ("zeros",)),
        "tri_a": PDef((dp, dp), (None, None)),
        "tri_b": PDef((dp, dp), (None, None)),
        "tri_g": PDef((dp, dp), (None, None)),
        "tri_o": PDef((dp, dp), (None, None)),
        # single attention with pair bias
        "ln1": PDef((d,), (None,), ("zeros",)),
        "wqkv": PDef((d, 3, h, hd), ("fsdp", None, "heads", None)),
        "wo": PDef((h, hd, d), ("heads", None, "fsdp")),
        "pair_bias_ln": PDef((dp,), (None,), ("zeros",)),
        "pair_bias_w": PDef((dp, h), (None, "heads")),
        # transitions
        "ln2": PDef((d,), (None,), ("zeros",)),
        "wi": PDef((d, f), ("fsdp", "mlp")),
        "wo_mlp": PDef((f, d), ("mlp", "fsdp")),
        "pair_ln": PDef((dp,), (None,), ("zeros",)),
        "pair_wi": PDef((dp, 4 * dp), (None, None)),
        "pair_wo": PDef((4 * dp, dp), (None, None)),
    }
    return {
        "single_in": PDef((64, d), (None, "fsdp")),   # residue-feature stub
        "pair_in": PDef((64, dp), (None, None)),
        "layers": stack_layers(layer, cfg.n_layers),
        "final_norm": PDef((d,), (None,), ("zeros",)),
        "out_head": PDef((d, 3), ("fsdp", None)),     # coordinate denoise stub
    }


def factor_mlp_template(cfg: ArchConfig, hidden: int = 256) -> dict:
    """Token-wise factor MLPs (App. H Table 12): 3 linear layers, tanh."""
    h, r = cfg.n_heads, cfg.bias_rank
    din = cfg.d_pair + cfg.d_model          # row/col pair summary + single
    def mlp():
        return {
            "w0": PDef((din, hidden), (None, None)),
            "b0": PDef((hidden,), (None,), ("zeros",)),
            "w1": PDef((hidden, hidden), (None, None)),
            "b1": PDef((hidden,), (None,), ("zeros",)),
            "w2": PDef((hidden, h * r), (None, None)),
            "b2": PDef((h * r,), (None,), ("zeros",)),
        }
    return {"q": mlp(), "k": mlp()}


def _factor_apply(fp: dict, x: jax.Array, heads: int, rank: int):
    y = jnp.tanh(x @ fp["w0"] + fp["b0"])
    y = jnp.tanh(y @ fp["w1"] + fp["b1"])
    y = y @ fp["w2"] + fp["b2"]
    return y.reshape(*y.shape[:-1], heads, rank)


def _triangle_update(lp, z):
    """Outgoing triangle multiplicative update: z_ij += sum_k a_ik * b_jk."""
    zl = rmsnorm(z, lp["tri_ln"])
    a = jax.nn.sigmoid(zl @ lp["tri_g"]) * (zl @ lp["tri_a"])
    b = zl @ lp["tri_b"]
    upd = jnp.einsum("bikc,bjkc->bijc", a, b) / float(np.sqrt(z.shape[2]))
    return z + upd @ lp["tri_o"]


def _pair_bias(lp, z, n_heads):
    """Project pair rep -> per-head additive bias (B, H, N, N)."""
    zb = rmsnorm(z, lp["pair_bias_ln"])
    return jnp.einsum("bijc,ch->bhij", zb, lp["pair_bias_w"])


def _factor_inputs(z, s):
    """Row/col pair summaries + single rep (App. H Table 12)."""
    row = z.mean(axis=2)           # (B,N,Dp)
    col = z.mean(axis=1)           # (B,N,Dp)
    return jnp.concatenate([row + col, s], axis=-1)


def _single_attention(lp, s, z, cfg: ArchConfig, factors_l=None):
    dt = s.dtype
    h = rmsnorm(s, lp["ln1"])
    qkv = jnp.einsum("bnd,dthe->tbnhe", h, lp["wqkv"].astype(dt))
    q, k, v = qkv[0], qkv[1], qkv[2]
    if cfg.bias_mode == "flashbias" and factors_l is not None:
        feats = _factor_inputs(z, h).astype(jnp.float32)
        pq = _factor_apply(factors_l["q"], feats, cfg.n_heads, cfg.bias_rank)
        pk = _factor_apply(factors_l["k"], feats, cfg.n_heads, cfg.bias_rank)
        o = kops.flash_attention(q, k, v, pq.astype(jnp.float32),
                                 pk.astype(jnp.float32), impl=cfg.attn_impl)
    else:
        from repro.core.attention import attention as core_attn
        bias = _pair_bias(lp, z, cfg.n_heads).astype(jnp.float32)
        o = core_attn(q, k, v, bias=bias, impl="chunked",
                      chunk_size=cfg.attn_chunk)
    return s + jnp.einsum("bnhe,hed->bnd", o, lp["wo"].astype(dt))


def forward(params, feats, cfg: ArchConfig, factors: Optional[dict] = None):
    """feats: (B, N, 64) residue features (stub). Returns (B, N, 3) coords."""
    dt = jnp.dtype(cfg.dtype)
    s = jnp.einsum("bnf,fd->bnd", feats.astype(dt), params["single_in"].astype(dt))
    z = jnp.einsum("bnf,fc->bnc", feats.astype(dt), params["pair_in"].astype(dt))
    z = z[:, :, None, :] + z[:, None, :, :]        # outer-sum init

    def body(carry, inp):
        s, z = carry
        lp, fl = inp if factors is not None else (inp, None)
        z = _triangle_update(lp, z)
        s = _single_attention(lp, s, z, cfg, fl)
        s = s + gelu_mlp(rmsnorm(s, lp["ln2"]), lp["wi"].astype(dt),
                         lp["wo_mlp"].astype(dt))
        z = z + gelu_mlp(rmsnorm(z, lp["pair_ln"]), lp["pair_wi"],
                         lp["pair_wo"])
        return (s, z), None

    xs = (params["layers"], factors) if factors is not None else params["layers"]
    (s, z), _ = jax.lax.scan(body, (s, z), xs,
                         unroll=flags.scan_unroll(cfg.n_layers))
    s = rmsnorm(s, params["final_norm"])
    return jnp.einsum("bnd,dc->bnc", s, params["out_head"].astype(dt))


def denoise_loss(params, batch, cfg: ArchConfig, factors=None):
    pred = forward(params, batch["feats"], cfg, factors).astype(jnp.float32)
    return jnp.mean((pred - batch["coords"].astype(jnp.float32)) ** 2)


# ---------------------------------------------------------------------------
# Batched serve path (ISSUE 6): admission precomputes per-complex bias state
# ONCE; every refinement step reuses it from the slot cache.
#
# A serve "request" is one complex: its (n_res, 64) residue features. The
# admission trunk pass runs the full Pairformer once (triangle updates, pair
# transitions — z evolves exactly as in ``forward``) and captures, per layer,
# the attention bias STATE in one of three forms:
#
# - "mlp"   — factor MLP outputs phi_q/phi_k (L, B, N, H, R)  [Eq. 5],
# - "svd"   — truncated-SVD factors of the projected dense bias, same
#             shapes [Sec. 4.3; rank = cfg.bias_rank so the SVD jits],
# - "dense" — the projected bias itself (L, B, H, N, N)
#             [``bias_mode="dense"``] — the strongest dense baseline: one
#             projection amortized at admission, steps only stream it,
# - "pair"  — the per-layer pair rep itself (L, B, N, N, Dp)
#             [``bias_mode="dense_recompute"``] — the OFFICIAL dataflow
#             (the paper's Table 6 baseline): every step re-projects the
#             bias from z at use, exactly as AF3's pair-bias attention
#             does, trading Θ(N²·Dp·H) re-projection FLOPs + a Dp/H-times
#             larger cache for zero admission-time bias work.
#
# z is DISCARDED after admission (the memory win: Θ((N+M)R) per layer rides
# in the cache instead of Θ(N²) pair state + Θ(N²H) bias), and each serve
# step is one refinement iteration over the single representation: scan all
# L layers of pair-biased attention + transition with the frozen factors.
#
# Batching contract: every wave pads to the SAME n_res_max (the engine pins
# it to max_len), and every op here is batch-row independent, so a complex's
# trajectory is bit-identical whether it runs alone or packed with strangers
# — the Pairformer analogue of the LM path's pinned ``prefill_len``.
# Factor-MLP biases are nonzero at zero-padded residues (the MLPs carry
# biases), so attention masks keys at positions >= the slot's n_res via the
# ``lengths`` vector — exp(MASK - m) underflows to exactly 0.0 in f32, so
# padded keys contribute exact zero.
# ---------------------------------------------------------------------------


def _serve_mode(cfg: ArchConfig, factors) -> str:
    if cfg.bias_mode == "dense":
        return "dense"
    if cfg.bias_mode == "dense_recompute":
        return "pair"
    return "mlp" if factors is not None else "svd"


def _attend_cached(lp, s, bias_state, cfg: ArchConfig, lengths):
    """One pair-biased attention over the single rep from CACHED bias state
    (factor pair or dense bias) — shared verbatim by the admission trunk
    and the serve step, so the two can never diverge."""
    dt = s.dtype
    h = rmsnorm(s, lp["ln1"])
    qkv = jnp.einsum("bnd,dthe->tbnhe", h, lp["wqkv"].astype(dt))
    q, k, v = qkv[0], qkv[1], qkv[2]
    if isinstance(bias_state, tuple):
        pq, pk = bias_state                        # (B, N, H, R) f32
        o = kops.flash_attention(q, k, v, pq, pk, impl=cfg.attn_impl,
                                 lengths=lengths)
    else:
        from repro.core.attention import attention as core_attn
        o = core_attn(q, k, v, bias=bias_state, kv_length=lengths,
                      impl="chunked", chunk_size=cfg.attn_chunk)
    return s + jnp.einsum("bnhe,hed->bnd", o, lp["wo"].astype(dt))


def _serve_rank(cfg: ArchConfig, n: int, mode: str) -> int:
    """Factor width of the serve cache: the factor MLPs emit exactly
    ``bias_rank`` columns, but an SVD of an (n, n) bias has at most n."""
    return cfg.bias_rank if mode == "mlp" else min(cfg.bias_rank, n)


def init_serve_cache(cfg: ArchConfig, batch: int, max_len: int,
                     factors=None) -> dict:
    """Zeroed pair slot cache. ``length`` doubles as the active mask
    (0 = retired slot, frozen by ``serve_step``). ``factors`` only selects
    the factor width (MLP factors are fixed-rank; SVD rank caps at
    ``max_len``) — the fitted params themselves are not read here."""
    dt = jnp.dtype(cfg.dtype)
    ln, h, d = cfg.n_layers, cfg.n_heads, cfg.d_model
    cache = {"s": jnp.zeros((batch, max_len, d), dt),
             "length": jnp.zeros((batch,), jnp.int32)}
    mode = _serve_mode(cfg, factors)
    if mode == "dense":
        cache["bias"] = jnp.zeros((ln, batch, h, max_len, max_len),
                                  jnp.float32)
    elif mode == "pair":
        cache["z"] = jnp.zeros((ln, batch, max_len, max_len, cfg.d_pair),
                               dt)
    else:
        r = _serve_rank(cfg, max_len, mode)
        cache["phi_q"] = jnp.zeros((ln, batch, max_len, h, r), jnp.float32)
        cache["phi_k"] = jnp.zeros((ln, batch, max_len, h, r), jnp.float32)
    return cache


def serve_prefill(params, batch, cfg: ArchConfig, factors=None, *,
                  max_len=None, lengths=None):
    """Admission trunk pass over a padded wave of complexes.

    batch: {"feats": (B, N_pad, 64)} with rows zero-padded past each
    complex's n_res; ``lengths`` (B,) the true n_res (0 for padding rows).
    Returns (None, wave_cache) — the wave cache rows scatter into the slot
    cache via ``insert_serve_cache_at_slots``.
    """
    from repro.core.decomp import svd_factors

    feats = batch["feats"]
    b, n = feats.shape[0], feats.shape[1]
    dt = jnp.dtype(cfg.dtype)
    if lengths is None:
        lengths = jnp.full((b,), n, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    mode = _serve_mode(cfg, factors)

    valid = jnp.arange(n)[None, :] < lengths[:, None]          # (B, N)
    s = jnp.einsum("bnf,fd->bnd", feats.astype(dt),
                   params["single_in"].astype(dt))
    s = jnp.where(valid[..., None], s, 0)
    z = jnp.einsum("bnf,fc->bnc", feats.astype(dt),
                   params["pair_in"].astype(dt))
    z = z[:, :, None, :] + z[:, None, :, :]
    # zero the pair rep outside the valid n_res x n_res block: the outer
    # sum leaks z_i into (i, j_pad), and the triangle update contracts over
    # ALL k — unmasked, padded k would contaminate valid entries. Zeroed
    # once here it STAYS zero: rmsnorm(0) = 0 kills the triangle gates and
    # the pair transition has no biases.
    z = jnp.where((valid[:, :, None] & valid[:, None, :])[..., None], z, 0)

    def body(carry, inp):
        s, z = carry
        lp, fl = inp if mode == "mlp" else (inp, None)
        z = _triangle_update(lp, z)
        if mode == "mlp":
            h = rmsnorm(s, lp["ln1"])
            fx = _factor_inputs(z, h).astype(jnp.float32)
            state = (_factor_apply(fl["q"], fx, cfg.n_heads, cfg.bias_rank),
                     _factor_apply(fl["k"], fx, cfg.n_heads, cfg.bias_rank))
        elif mode == "svd":
            bias = _pair_bias(lp, z, cfg.n_heads).astype(jnp.float32)
            pq_h, pk_h = svd_factors(bias,
                                     rank=_serve_rank(cfg, n, mode))
            # (B, H, N, R) each -> residue-major (B, N, H, R)
            state = (pq_h.transpose(0, 2, 1, 3), pk_h.transpose(0, 2, 1, 3))
        elif mode == "pair":
            state = z                  # post-triangle z, as forward() uses
        else:
            state = _pair_bias(lp, z, cfg.n_heads).astype(jnp.float32)
        attn_state = (_pair_bias(lp, state, cfg.n_heads)
                      .astype(jnp.float32) if mode == "pair" else state)
        s = _attend_cached(lp, s, attn_state, cfg, lengths)
        s = s + gelu_mlp(rmsnorm(s, lp["ln2"]), lp["wi"].astype(dt),
                         lp["wo_mlp"].astype(dt))
        z = z + gelu_mlp(rmsnorm(z, lp["pair_ln"]), lp["pair_wi"],
                         lp["pair_wo"])
        return (s, z), state

    xs = ((params["layers"], factors) if mode == "mlp"
          else params["layers"])
    (s, _), states = jax.lax.scan(body, (s, z), xs,
                                  unroll=flags.scan_unroll(cfg.n_layers))
    cache = {"s": s, "length": lengths}
    if mode == "dense":
        cache["bias"] = states
    elif mode == "pair":
        cache["z"] = states
    else:
        cache["phi_q"], cache["phi_k"] = states
    return None, cache


def serve_step(params, cache, cfg: ArchConfig):
    """One refinement iteration over every live slot: scan all L layers of
    single-rep attention with the CACHED per-layer bias state (no triangle
    update, no factor recompute — the per-complex factors were paid for
    once at admission). Retired slots (length 0) are frozen."""
    s0, lengths = cache["s"], cache["length"]
    dt = s0.dtype
    if "bias" in cache:
        states = cache["bias"]
    elif "z" in cache:
        states = cache["z"]            # official dataflow: project at use
    else:
        states = (cache["phi_q"], cache["phi_k"])

    pair_mode = "z" in cache

    def body(s, inp):
        lp, state = inp
        if pair_mode:
            # (B, N, N, Dp) pair rep -> re-project the bias at use
            state = _pair_bias(lp, state, cfg.n_heads).astype(jnp.float32)
        s = _attend_cached(lp, s, state, cfg, lengths)
        s = s + gelu_mlp(rmsnorm(s, lp["ln2"]), lp["wi"].astype(dt),
                         lp["wo_mlp"].astype(dt))
        return s, None

    s, _ = jax.lax.scan(body, s0, (params["layers"], states),
                        unroll=flags.scan_unroll(cfg.n_layers))
    active = (lengths > 0)[:, None, None]
    return dict(cache, s=jnp.where(active, s, s0))


def insert_serve_cache_at_slots(dst: dict, src: dict, slots) -> dict:
    """Scatter prefilled wave rows into the slot cache. ``s``/``length``
    lead with the batch axis; bias state leads with the layer axis (the
    slot axis is second). Out-of-range slot ids drop (padding rows)."""
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, v in dst.items():
        if key in ("s", "length"):
            out[key] = v.at[slots].set(src[key].astype(v.dtype), mode="drop")
        else:
            out[key] = v.at[:, slots].set(src[key].astype(v.dtype),
                                          mode="drop")
    return out


def fit_factor_mlps(key, params, factor_params, sample_feats, cfg: ArchConfig,
                    *, steps: int = 300, lr: float = 1e-3):
    """Paper's fine-tuning (Eq. 5): match phi_q phi_k^T to the projected bias
    of every layer, trunk frozen. Returns (fitted factors, loss history)."""
    dt = jnp.dtype(cfg.dtype)

    def layer_ctx(feats):
        """Replay the trunk to collect (z, s) at each layer's attention."""
        s = jnp.einsum("bnf,fd->bnd", feats.astype(dt), params["single_in"].astype(dt))
        z = jnp.einsum("bnf,fc->bnc", feats.astype(dt), params["pair_in"].astype(dt))
        z = z[:, :, None, :] + z[:, None, :, :]
        ctxs = []
        n_layers = cfg.n_layers
        for i in range(n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            z = _triangle_update(lp, z)
            h = rmsnorm(s, lp["ln1"])
            ctxs.append((jnp.asarray(z), h, lp))
            s = _single_attention(lp, s, z, cfg, None)
            s = s + gelu_mlp(rmsnorm(s, lp["ln2"]), lp["wi"].astype(dt),
                             lp["wo_mlp"].astype(dt))
            z = z + gelu_mlp(rmsnorm(z, lp["pair_ln"]), lp["pair_wi"],
                             lp["pair_wo"])
        return ctxs

    ctxs = layer_ctx(sample_feats)

    def loss_fn(fp):
        total = 0.0
        for i, (z, h, lp) in enumerate(ctxs):
            fl = jax.tree.map(lambda p: p[i], fp)
            target = _pair_bias(lp, z, cfg.n_heads)          # (B,H,N,N)
            feats_i = _factor_inputs(z, h).astype(jnp.float32)
            pq = _factor_apply(fl["q"], feats_i, cfg.n_heads, cfg.bias_rank)
            pk = _factor_apply(fl["k"], feats_i, cfg.n_heads, cfg.bias_rank)
            pred = jnp.einsum("bnhr,bmhr->bhnm", pq, pk)
            total = total + jnp.mean((pred - target) ** 2)
        return total / len(ctxs)

    # plain Adam
    b1, b2, eps = 0.9, 0.999, 1e-8
    mu = jax.tree.map(jnp.zeros_like, factor_params)
    nu = jax.tree.map(jnp.zeros_like, factor_params)

    @jax.jit
    def step(fp, mu, nu, t):
        loss, g = jax.value_and_grad(loss_fn)(fp)
        mu = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, mu, g)
        nu = jax.tree.map(lambda n, gg: b2 * n + (1 - b2) * gg * gg, nu, g)
        def upd(p, m, n):
            mh = m / (1 - b1 ** t)
            nh = n / (1 - b2 ** t)
            return p - lr * mh / (jnp.sqrt(nh) + eps)
        return jax.tree.map(upd, fp, mu, nu), mu, nu, loss

    losses = []
    fp = factor_params
    for t in range(1, steps + 1):
        fp, mu, nu, loss = step(fp, mu, nu, jnp.asarray(t, jnp.float32))
        losses.append(float(loss))
    return fp, losses
