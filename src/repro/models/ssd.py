"""Mamba2 SSD (state-space duality) block — chunked scan + decode step.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks of length Q; within a chunk the output is the quadratic
"1-semiseparable attention" form, across chunks a linear recurrence carries
the (H, P, N) state. This is the sub-quadratic path that makes the
``long_500k`` cell runnable for SSM/hybrid archs.

Connection to the paper (DESIGN.md §Arch-applicability): the SSD decay mask
``L_ij = exp(sum_{j<t<=i} dt_t a)`` is itself a *structured low-rank* masked
attention surrogate — but there are no q k^T logits to add a FlashBias term
to, so the paper's technique is N/A for this family and the arch is built
without it.

Layout: x (B, S, H, P) heads/headdim; B, C (B, S, N) (ngroups=1);
dt (B, S, H); a (H,) negative decay rates. State h (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import flags
from repro.dist import sharding as dshard

__all__ = ["ssd_scan", "ssd_decode_step"]


def _chunk_cumsum(dta):
    """Inclusive cumsum of dt*a within each chunk. dta: (B, nc, Q, H)."""
    return jnp.cumsum(dta, axis=2)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 256,
             h0: jax.Array | None = None):
    """Chunked SSD forward.

    x: (B, S, H, P); dt: (B, S, H) (already softplus'd, >0); a: (H,) < 0;
    b, c: (B, S, N). Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n)
    cc = c.reshape(bsz, nc, q, n)

    dta = dtc * a[None, None, None, :]                  # (B,nc,Q,H) <= 0
    cum = _chunk_cumsum(dta)                            # inclusive
    # Intra-chunk quadratic ("attention") term:
    #   y_i += sum_{j<=i} (c_i . b_j) exp(cum_i - cum_j) dt_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # (B,nc,Qi,Qj)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]    # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # Chunk summaries: contribution of each chunk to the carried state
    #   state_c = sum_j exp(cum_last - cum_j) dt_j  b_j (x) x_j
    last = cum[:, :, -1:, :]                             # (B,nc,1,H)
    sdec = jnp.exp(last - cum)                           # (B,nc,Q,H)
    states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn",
                        sdec * dtc, xc, bc)              # (B,nc,H,P,N)
    # Whole-chunk decay factor
    chunk_decay = jnp.exp(last[:, :, 0, :])              # (B,nc,H)

    # Inter-chunk recurrence (sequential over chunks)
    def step(hprev, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev                               # emit state BEFORE chunk

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    h_fin, h_prevs = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=flags.scan_unroll(nc))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,P,N)

    # Inter-chunk output: y_i += (c_i . h_prev) decayed to position i
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(cum), cc, h_prevs)
    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)
    y = dshard.constrain(y, "batch", "seq", "heads", None)
    return y[:, :s], h_fin


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array):
    """One-token SSD update.

    h: (B,H,P,N) state; x: (B,H,P); dt: (B,H); b, c: (B,N).
    Returns (y (B,H,P), h_new).
    """
    da = jnp.exp(dt * a[None, :])                        # (B,H)
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dt, x, b)
    h_new = h * da[:, :, None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", h_new, c)
    return y, h_new
