"""SwinV2-style window attention with learnable relative-position bias.

The paper's Sec. 4.3 experiment: each layer owns a learnable bias table over
relative offsets; at inference FlashBias replaces the (H, W, W) materialized
bias with rank-R SVD factors computed offline (``svd_factorize``), riding
with q/k through the flash path. ``bias_mode``:

- "dense"     — materialize the table bias every layer (official-code path),
- "flashbias" — consume precomputed SVD factors (phi_q, phi_k per layer).

The model is an image-classification-shaped stack: window-partitioned tokens
(B, n_windows, W, D) with windows folded into the batch, mean-pool head.
The hierarchical pyramid of real Swin is orthogonal to the bias technique
and is not modeled (DESIGN.md §Changed assumptions).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.configs.base import ArchConfig
from repro.core import decomp
from repro.kernels import ops as kops
from repro.models.common import PDef, gelu_mlp, rmsnorm, stack_layers

__all__ = ["swin_template", "forward", "svd_factorize", "classify_loss"]


def swin_template(cfg: ArchConfig) -> dict:
    d, h, w, f = cfg.d_model, cfg.n_heads, cfg.window, cfg.d_ff
    hd = cfg.resolved_head_dim
    layer = {
        "ln1": PDef((d,), (None,), ("zeros",)),
        "wqkv": PDef((d, 3, h, hd), ("fsdp", None, "heads", None)),
        "wo": PDef((h, hd, d), ("heads", None, "fsdp")),
        # learnable relative-position bias table, materialized per window
        "bias_table": PDef((h, w, w), ("heads", None, None), ("normal", 0.5)),
        "ln2": PDef((d,), (None,), ("zeros",)),
        "wi": PDef((d, f), ("fsdp", "mlp")),
        "wo_mlp": PDef((f, d), ("mlp", "fsdp")),
    }
    return {
        "patch_embed": PDef((48, d), (None, "fsdp")),   # 4x4x3 patch stub
        "layers": stack_layers(layer, cfg.n_layers),
        "final_norm": PDef((d,), (None,), ("zeros",)),
        "head": PDef((d, 1000), ("fsdp", None)),
    }


def svd_factorize(params: dict, rank: int):
    """Offline SVD of every layer's bias table -> factor tensors.

    Returns {"phi_q": (L, H, W, R), "phi_k": (L, H, W, R)} — the paper's
    Table 1 row (b). Run ONCE per trained model (paper: 4.79 s for SwinV2-B).
    """
    tables = params["layers"]["bias_table"]      # (L, H, W, W)
    pq, pk = decomp.svd_factors(tables, rank=rank)
    return {"phi_q": pq, "phi_k": pk}


def _window_attention(lp, x, cfg: ArchConfig, factors_l=None):
    """x: (B*, W, D) with windows folded into batch."""
    dt = x.dtype
    qkv = jnp.einsum("bwd,dthe->tbwhe", x, lp["wqkv"].astype(dt))
    q, k, v = qkv[0], qkv[1], qkv[2]
    # Training always uses the dense table (SVD factors exist only for a
    # *trained* model — paper Sec. 4.3); inference passes factors explicitly.
    if cfg.bias_mode == "flashbias" and factors_l is not None:
        bsz, w = x.shape[0], x.shape[1]
        pq = jnp.broadcast_to(factors_l["phi_q"].transpose(1, 0, 2)[None],
                              (bsz, w, cfg.n_heads, factors_l["phi_q"].shape[-1]))
        pk = jnp.broadcast_to(factors_l["phi_k"].transpose(1, 0, 2)[None],
                              (bsz, w, cfg.n_heads, factors_l["phi_k"].shape[-1]))
        o = kops.flash_attention(q, k, v, pq.astype(dt), pk.astype(dt),
                                 impl=cfg.attn_impl)
    else:
        from repro.core.attention import MaskSpec, attention as core_attn
        o = core_attn(q, k, v, bias=lp["bias_table"][None].astype(jnp.float32),
                      impl="chunked", chunk_size=cfg.attn_chunk)
    return jnp.einsum("bwhe,hed->bwd", o, lp["wo"].astype(dt))


def forward(params, patches, cfg: ArchConfig, factors: Optional[dict] = None):
    """patches: (B, n_win, W, 48) raw patch pixels (stub). Returns logits."""
    b, nw, w, _ = patches.shape
    dt = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bnwp,pd->bnwd", patches.astype(dt),
                   params["patch_embed"].astype(dt))
    x = x.reshape(b * nw, w, cfg.d_model)

    n_layers = cfg.n_layers

    def body(x, inp):
        if factors is not None:
            lp, fl = inp
        else:
            lp, fl = inp, None
        h = rmsnorm(x, lp["ln1"])
        x = x + _window_attention(lp, h, cfg, fl)
        h2 = rmsnorm(x, lp["ln2"])
        x = x + gelu_mlp(h2, lp["wi"].astype(dt), lp["wo_mlp"].astype(dt))
        return x, None

    xs = (params["layers"], factors) if factors is not None else params["layers"]
    x, _ = jax.lax.scan(body, x, xs, unroll=flags.scan_unroll(cfg.n_layers))
    x = rmsnorm(x, params["final_norm"])
    pooled = x.reshape(b, nw * w, -1).mean(axis=1)
    return jnp.einsum("bd,dc->bc", pooled, params["head"].astype(dt))


def classify_loss(params, batch, cfg: ArchConfig, factors=None):
    logits = forward(params, batch["patches"], cfg, factors).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
    return jnp.mean(lse - gold)
