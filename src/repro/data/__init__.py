"""Deterministic synthetic data pipelines (stateless index -> batch).

Every pipeline is a pure function of (seed, step, shard); resumability and
elasticity are by construction — any host can compute any shard of any step,
so crash restarts and re-meshes never lose or duplicate data.
"""
from repro.data.pipeline import LMBatches, PatchBatches, PDEBatches

__all__ = ["LMBatches", "PDEBatches", "PatchBatches"]
