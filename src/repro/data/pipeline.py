"""Synthetic, deterministic, shard-aware batch generators.

Design rules (DESIGN.md §Fault tolerance):

- **Stateless**: ``batch(step)`` is a pure function of (seed, step, shard).
  The "data iterator state" in a checkpoint is just the integer step.
- **Shard-aware**: ``LMBatches(..., shard=(i, n))`` yields the i-th of n
  disjoint slices of the global batch, so each host materializes only its
  slice (the launcher maps hosts to shards).
- **Learnable**: token streams follow a noisy modular-increment process so
  examples can demonstrate a falling loss; PDE targets are smooth analytic
  fields of the coordinates.

Numpy's Philox gives counter-based determinism (seed x step) without
carrying RNG state across steps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["LMBatches", "PDEBatches", "PatchBatches"]


def _rng(seed: int, step: int, salt: int = 0) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=[step, salt, 0, 0]))


@dataclasses.dataclass(frozen=True)
class LMBatches:
    """Next-token LM batches: tokens[t+1] = (tokens[t] + stride) % vocab with
    p_noise random corruption. labels = next token."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_noise: float = 0.1
    frontend_len: int = 0
    d_model: int = 0
    shard: Tuple[int, int] = (0, 1)

    def _local_batch(self) -> int:
        i, n = self.shard
        assert self.global_batch % n == 0, (self.global_batch, n)
        return self.global_batch // n

    def batch(self, step: int) -> dict:
        b = self._local_batch()
        g = _rng(self.seed, step, salt=self.shard[0])
        start = g.integers(0, self.vocab, size=(b, 1))
        stride = g.integers(1, 8, size=(b, 1))
        t = np.arange(self.seq_len + 1)[None, :]
        seq = (start + stride * t) % self.vocab
        noise = g.random(seq.shape) < self.p_noise
        seq = np.where(noise, g.integers(0, self.vocab, size=seq.shape), seq)
        out = {"tokens": seq[:, :-1].astype(np.int32),
               "labels": seq[:, 1:].astype(np.int32)}
        if self.frontend_len:
            out["frontend"] = g.standard_normal(
                (b, self.frontend_len, self.d_model)).astype(np.float32)
        return out


@dataclasses.dataclass(frozen=True)
class PDEBatches:
    """Point clouds + analytic physics fields (pressure + 3 velocity)."""

    n_points: int
    global_batch: int
    seed: int = 0
    coord_dim: int = 3
    shard: Tuple[int, int] = (0, 1)

    def batch(self, step: int) -> dict:
        i, n = self.shard
        b = self.global_batch // n
        g = _rng(self.seed, step, salt=i)
        coords = g.standard_normal((b, self.n_points, self.coord_dim)).astype(np.float32)
        r2 = (coords ** 2).sum(-1, keepdims=True)
        pressure = np.exp(-r2) * np.sin(coords[..., :1] * 3.0)
        velocity = np.cos(coords * 2.0) * np.exp(-r2 / 2.0)
        targets = np.concatenate([pressure, velocity], axis=-1)[..., :4]
        return {"coords": coords, "targets": targets.astype(np.float32)}


@dataclasses.dataclass(frozen=True)
class PatchBatches:
    """Window-partitioned patch batches for the Swin stack."""

    n_windows: int
    window: int
    global_batch: int
    n_classes: int = 1000
    seed: int = 0
    shard: Tuple[int, int] = (0, 1)

    def batch(self, step: int) -> dict:
        i, n = self.shard
        b = self.global_batch // n
        g = _rng(self.seed, step, salt=i)
        labels = g.integers(0, self.n_classes, size=(b,)).astype(np.int32)
        patches = g.standard_normal(
            (b, self.n_windows, self.window, 48)).astype(np.float32)
        # class-dependent mean shift so the task is learnable
        patches += (labels[:, None, None, None] % 7 - 3) * 0.1
        return {"patches": patches, "labels": labels}
