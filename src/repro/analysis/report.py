"""Turn results/dryrun/*.json into the EXPERIMENTS.md §Roofline tables.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(d):
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(reports):
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful ratio | roofline frac | mem(xla) | temp/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(reports, key=lambda r: (r["arch_id"], r["shape"])):
        if r["mesh"] != "single" or "compute_s" not in r:
            continue
        mem = (r.get("memory") or {}).get("temp_bytes")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction'] * 100:.1f}% | "
            f"{fmt_s(r.get('memory_s_xla'))} | {fmt_b(mem)} |")
    return "\n".join(rows)


def multipod_table(reports):
    rows = ["| arch | shape | mesh | compile | peak mem/dev | status |",
            "|---|---|---|---|---|---|"]
    for r in sorted(reports, key=lambda r: (r["arch_id"], r["shape"],
                                            r["mesh"])):
        if r["mesh"] != "multi":
            continue
        mem = (r.get("memory") or {}).get("peak_bytes")
        rows.append(f"| {r['arch']} | {r['shape']} | 2x16x16 | "
                    f"{r['compile_s']:.1f}s | {fmt_b(mem)} | "
                    f"{'OK' if r.get('compile_ok') else '?'} |")
    return "\n".join(rows)


def collective_breakdown(reports, top=6):
    rows = ["| arch | shape | all-gather | all-reduce | reduce-scatter | "
            "all-to-all | permute |", "|---|---|---|---|---|---|---|"]
    sel = [r for r in reports if r["mesh"] == "single" and "collective_detail" in r]
    sel.sort(key=lambda r: -r.get("collective_s", 0))
    for r in sel[:top]:
        d = r["collective_detail"]
        rows.append(f"| {r['arch']} | {r['shape']} | {fmt_b(d['all-gather'])} "
                    f"| {fmt_b(d['all-reduce'])} | {fmt_b(d['reduce-scatter'])} "
                    f"| {fmt_b(d['all-to-all'])} | "
                    f"{fmt_b(d['collective-permute'])} |")
    return "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    reports = load(d)
    print("## Roofline (single pod, 16x16 = 256 chips)\n")
    print(roofline_table(reports))
    print("\n## Multi-pod compile proof (2x16x16 = 512 chips)\n")
    print(multipod_table(reports))
    print("\n## Collective breakdown (most collective-bound cells)\n")
    print(collective_breakdown(reports))


if __name__ == "__main__":
    main()
