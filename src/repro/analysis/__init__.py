"""Roofline analysis: cost/memory terms from compiled HLO + collective parser."""
from repro.analysis.roofline import (
    active_params,
    collective_bytes,
    model_flops,
    roofline_report,
)

__all__ = ["collective_bytes", "roofline_report", "active_params",
           "model_flops"]
