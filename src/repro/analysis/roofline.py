"""Roofline terms from a compiled dry-run artifact (no hardware needed).

Per (arch x shape x mesh) cell we derive three times-if-perfectly-overlapped:

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bandwidth
    collective_s = collective_operand_bytes_per_device / ICI_link_bandwidth

``compiled.cost_analysis()`` provides FLOPs and bytes of the *partitioned*
(per-device) module. Collective bytes are NOT in cost_analysis — we parse the
optimized HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (incl. async -start forms).

The dominant term is the bottleneck the §Perf loop iterates on.
``MODEL_FLOPS`` (6·N_active·tokens for training, 2·N_active·tokens for
inference; unpadded parameter counts, attention excluded per MFU convention)
over HLO_FLOPs exposes padding/remat/redundancy waste.
"""
from __future__ import annotations

import re
from typing import Optional

from repro.configs.base import ArchConfig, ShapeSpec
from repro.hw import TPU_V5E

__all__ = ["collective_bytes", "roofline_report", "active_params",
           "model_flops", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:                      # iota list: [num_groups, group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _LIST_GROUPS_RE.search(line)
    if m:                      # explicit list: size of the first group
        return max(len(m.group(1).split(",")), 1)
    return default


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    """Ring-algorithm bytes on the wire per device.

    all-gather:   each device receives (g-1)/g of the full result.
    all-reduce:   reduce-scatter + all-gather -> 2 x (g-1)/g x result.
    reduce-scatter: operand is g x result; (g-1)/g of it crosses the wire.
    all-to-all:   (g-1)/g of the buffer changes device.
    collective-permute: the whole buffer moves.
    """
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if op == "all-gather":
        return f * result_bytes
    if op == "all-reduce":
        return 2.0 * f * result_bytes
    if op == "reduce-scatter":
        return f * result_bytes * g
    if op == "all-to-all":
        return f * result_bytes
    return float(result_bytes)          # collective-permute


def collective_bytes(hlo_text: str, default_group: int = 1) -> dict:
    """Per-device collective wire bytes parsed from optimized HLO text.

    Optimized HLO writes operands as bare refs (``all-reduce(%dot.1)``), so
    sizes come from the *result* type (tuple types: sum of parts), converted
    to wire bytes by the ring model above. Async ``-start`` forms count;
    their ``-done`` twins are skipped. Returns {op: bytes, ..., "total": B,
    "counts": {op: n}}.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.(" in line:
            continue
        hit = None
        for op in _COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                hit = op
                break
        if hit is None:
            continue
        eq = line.find(" = ")
        opidx = line.find(f" {hit}")
        if eq < 0 or opidx <= eq:
            continue
        result_sec = line[eq + 3:opidx]
        rb = sum(_shape_bytes(m.group(1), m.group(2))
                 for m in _SHAPE_RE.finditer(result_sec))
        if f" {hit}-start(" in line:
            # tuple (operand, result): count the result half only
            rb = rb / 2
        g = _group_size(line, default_group)
        out[hit] += _wire_bytes(hit, rb, g)
        counts[hit] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful work) from the *unpadded* architecture figures
# ---------------------------------------------------------------------------

def active_params(cfg: ArchConfig) -> int:
    """Per-token-active matmul parameters, REAL (unpadded) figures."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, (cfg.n_kv_heads or cfg.n_heads)
    per_layer = 0
    if cfg.family in ("dense", "moe", "hybrid"):
        per_layer += d * hd * (2 * h + 2 * kv)              # wq, wo, wk, wv
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        per_layer += d * (2 * d_in + 2 * cfg.ssm_state + cfg.ssm_heads)
        per_layer += d_in * d
    if cfg.family == "moe":
        per_layer += cfg.top_k * 3 * d * cfg.d_ff + d * cfg.n_experts
    elif cfg.d_ff:
        per_layer += 3 * d * cfg.d_ff
    unembed = d * cfg.vocab
    return cfg.n_layers * per_layer + unembed


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (prefill/decode)."""
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch        # decode: one token per request


def attention_kv_reread_bytes(cfg: ArchConfig, shape: ShapeSpec,
                              n_data: int, *, block: int = 128) -> float:
    """Extra per-device HBM bytes of the Pallas flash kernel beyond
    read-once (the io_stub's footprint): each allowed (q-block, kv-block)
    tile pair re-reads the K/V tiles — the Theta(NM(C+R)^2/S) term of the
    paper's Cor. 3.7, instantiated for our 128x128 tiling.

    KV heads are replicated over the model axis (DESIGN §4), so every device
    reads its batch shard's full KV. Causal masks allow ~1/2 of pairs;
    sliding windows ~(window + 2*block)/N; decode reads the cache once (no
    reread). Train charges fwd + backward (~2x fwd IO).
    """
    if cfg.family not in ("dense", "moe", "hybrid") or shape.kind == "decode":
        return 0.0
    n = shape.seq_len
    b_loc = max(1, shape.global_batch // n_data)
    if cfg.window:
        frac = min(1.0, (cfg.window + 2 * block) / n)
    else:
        frac = 0.5
    rereads = frac * (n / block)
    kv_bytes = (n * cfg.kv_heads_padded * cfg.resolved_head_dim
                * 2 * 2)                     # k+v, bf16
    extra_per_layer = max(0.0, rereads - 1.0) * kv_bytes * b_loc
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + ~2x bwd
    if shape.kind == "train":
        mult *= 1.0                                # grad-accum already in b_loc
    return extra_per_layer * cfg.n_layers * mult


def attention_kernel_flops(cfg: ArchConfig, shape: ShapeSpec,
                           n_data: int, n_model: int,
                           *, block: int = 128) -> float:
    """Per-device FLOPs of the Pallas flash kernel (block-pruned masks).

    The XLA fallback computes the FULL N x M logits and masks with
    ``where`` — 2x waste for causal, ~N/window x for sliding windows. The
    kernel skips disallowed blocks (``pl.when``), so deployment FLOPs are
    ``mask_frac * (2*hd[qk] + 2*hd[pv] + 2*r[bias-tile]) * B*H*N*M``.
    """
    if cfg.family not in ("dense", "moe", "hybrid") or shape.kind == "decode":
        return 0.0
    n = shape.seq_len
    b_loc = max(1, shape.global_batch // n_data)
    h_loc = max(1, cfg.heads_padded // n_model)
    if cfg.window:
        frac = min(1.0, (cfg.window + 2 * block) / n)
    else:
        frac = 0.5
    r = 2 if cfg.bias_kind == "alibi" else 0
    hd = cfg.resolved_head_dim
    per_pair = 2 * hd + 2 * hd + 2 * r
    fwd = frac * b_loc * h_loc * n * n * per_pair
    mult = 3.0 if shape.kind == "train" else 1.0
    return fwd * cfg.n_layers * mult


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

def roofline_report(*, flops_per_device: float, bytes_per_device: float,
                    coll_bytes_per_device: float, cfg: ArchConfig,
                    shape: ShapeSpec, n_devices: int,
                    coll_detail: Optional[dict] = None,
                    adjusted_bytes_per_device: Optional[float] = None,
                    adjusted_flops_per_device: Optional[float] = None) -> dict:
    """``adjusted_*_per_device``: the DEPLOYMENT path — the Pallas kernels'
    true HBM footprint (VMEM-resident softmax, in-place cache update, Cor 3.7
    KV rereads) and block-pruned attention FLOPs substituted for the XLA
    fallback's full-quadratic numbers. When present, the dominant term and
    roofline fraction use the adjusted terms; raw XLA numbers are reported
    alongside."""
    hw = TPU_V5E
    compute_s_xla = flops_per_device / hw.peak_flops_bf16
    memory_s = bytes_per_device / hw.hbm_bandwidth
    collective_s = coll_bytes_per_device / hw.ici_link_bandwidth
    memory_s_adj = (adjusted_bytes_per_device / hw.hbm_bandwidth
                    if adjusted_bytes_per_device is not None else None)
    compute_s_adj = (adjusted_flops_per_device / hw.peak_flops_bf16
                     if adjusted_flops_per_device is not None else None)
    eff_memory = memory_s_adj if memory_s_adj is not None else memory_s
    eff_compute = compute_s_adj if compute_s_adj is not None else compute_s_xla
    terms = {"compute_s": eff_compute, "memory_s": eff_memory,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_per_device = mf / n_devices
    total = max(terms.values())
    eff_flops = (adjusted_flops_per_device
                 if adjusted_flops_per_device is not None
                 else flops_per_device)
    return {
        "arch": cfg.name, "shape": shape.name, "devices": n_devices,
        "compute_s": eff_compute, "compute_s_xla": compute_s_xla,
        "memory_s": eff_memory,
        "memory_s_xla": memory_s, "memory_s_adjusted": memory_s_adj,
        "collective_s": collective_s,
        "dominant": dominant,
        "hlo_flops_per_device": flops_per_device,
        "adjusted_flops_per_device": adjusted_flops_per_device,
        "hlo_bytes_per_device": bytes_per_device,
        "adjusted_bytes_per_device": adjusted_bytes_per_device,
        "collective_bytes_per_device": coll_bytes_per_device,
        "collective_detail": coll_detail or {},
        "model_flops_total": mf,
        "model_flops_per_device": mf_per_device,
        "useful_flops_ratio": (mf_per_device / eff_flops
                               if eff_flops else 0.0),
        # fraction of compute-roofline achieved if the dominant term were the
        # exact step time (the score §Perf drives up):
        "roofline_fraction": ((mf_per_device / hw.peak_flops_bf16) / total
                              if total else 0.0),
    }
