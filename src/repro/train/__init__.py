"""Training substrate: step factory + fault-tolerant loop."""
from repro.train.loop import TrainLoop
from repro.train.step import make_train_step

__all__ = ["make_train_step", "TrainLoop"]
