"""Train-step factory: loss -> jitted (params, opt_state, batch) update.

- **Microbatch gradient accumulation** via ``lax.scan`` over a leading
  microbatch axis — the scan structure lets XLA overlap the FSDP all-gather
  of the next microbatch's layer weights with the current compute.
- **Donation** of params/opt_state buffers (in-place update on device).
- Works identically under a mesh (pjit'd by shardings on the arguments) and
  on a single CPU device (tests).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW

__all__ = ["make_train_step"]


def make_train_step(loss_fn: Callable, optimizer: AdamW, *,
                    grad_accum: int = 1, jit: bool = True,
                    in_shardings=None, out_shardings=None,
                    donate: bool = True, grad_shardings=None):
    """loss_fn(params, batch) -> scalar. Returns step(params, opt, batch).

    With ``grad_accum > 1`` the batch's leading axis must be divisible by it;
    the batch is reshaped to (A, B/A, ...) and grads averaged over A.

    ``grad_shardings`` (pytree of NamedSharding mirroring params): constrain
    gradients to the parameter shardings before the optimizer update. Under
    FSDP this turns the data-parallel gradient all-reduce into a
    reduce-scatter (each device reduces only its parameter shard — ZeRO-2):
    without the constraint GSPMD materializes FULL per-device gradients
    (416 GB/device for command-r-plus; see EXPERIMENTS.md §Perf).
    """

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        # Constrain INSIDE the accumulation so each microbatch's gradient is
        # reduce-scattered into a sharded accumulator; constraining only the
        # final result leaves a full-size (replicated) carry and changes
        # nothing (measured: EXPERIMENTS.md §Perf iteration 1).
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def compute_grads(params, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, constrain_grads(grads)

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), b)

        microbatches = micro(batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(jnp.add, grad_acc, constrain_grads(grads))
            grad_acc = constrain_grads(grad_acc)
            return (loss_acc + loss, grad_acc), None

        zeros = constrain_grads(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), microbatches)
        inv = 1.0 / grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        params, opt_state, metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    if not jit:
        return step
    kwargs = {}
    if in_shardings is not None:
        kwargs["in_shardings"] = in_shardings
    if out_shardings is not None:
        kwargs["out_shardings"] = out_shardings
    return jax.jit(step, donate_argnums=(0, 1) if donate else (), **kwargs)
