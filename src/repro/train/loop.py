"""Fault-tolerant training loop.

Scale features (DESIGN.md §Fault tolerance):

- **Checkpoint/restart**: periodic saves + save-on-SIGTERM (preemption);
  ``TrainLoop.run`` first restores the latest checkpoint if one exists, so a
  crashed/preempted job resumes bit-exactly (data pipeline is stateless-
  indexed — the restored integer step is the full iterator state).
- **Straggler watchdog**: per-step wall times tracked; steps slower than
  ``straggler_factor x`` the running median are counted and surfaced in
  metrics. In a synchronous SPMD job a persistent straggler cannot be
  dropped mid-run — the mitigation path is an early checkpoint + re-mesh
  (elastic restore onto the healthy node set), which the watchdog triggers
  via ``on_straggler``.
- **Metrics**: JSONL per step (loss, grad-norm, lr, wall time, stragglers).
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["TrainLoop"]


@dataclasses.dataclass
class TrainLoop:
    train_step: Callable                 # (params, opt, batch) -> (params, opt, metrics)
    data_fn: Callable[[int], dict]      # step -> batch (stateless)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_n: int = 3
    log_path: Optional[str] = None
    straggler_factor: float = 3.0
    straggler_floor_s: float = 0.01
    on_straggler: Optional[Callable[[int, float], None]] = None

    def run(self, params, opt_state, num_steps: int, *, start_step: int = 0,
            shardings=None):
        """Run up to ``num_steps`` total steps; resumes from checkpoints."""
        step = start_step
        if self.ckpt_dir and latest_step(self.ckpt_dir) is not None:
            (params, opt_state), extras = restore_checkpoint(
                self.ckpt_dir, None, (params, opt_state), shardings=shardings)
            step = int(extras["step"]) + 1

        preempted = {"flag": False}

        def _sigterm(signum, frame):       # preemption notice
            preempted["flag"] = True

        prev_handler = signal.signal(signal.SIGTERM, _sigterm)
        times: list[float] = []       # every step (final p50/p99)
        window: list[float] = []      # outlier-excluded (straggler median)
        stragglers = 0
        consec_outliers = 0
        log_f = open(self.log_path, "a") if self.log_path else None
        try:
            while step < num_steps:
                t0 = time.monotonic()
                batch = self.data_fn(step)
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0

                # A flagged step's duration must NOT enter the median window
                # (one 3x outlier would otherwise drag the median up and/or
                # leave sub-ms noise flagging the NEXT step too), and the
                # median is floored so microsecond-scale steps don't turn
                # timer jitter into false stragglers. But a RUN of flags is
                # a regime change (longer seqs, degraded node), not a
                # straggler — after 3 consecutive flags the durations are
                # admitted so the baseline re-adapts instead of firing
                # on_straggler every step forever.
                is_straggler = False
                if len(window) >= 5:
                    med = max(float(np.median(window[-50:])),
                              self.straggler_floor_s)
                    if dt > self.straggler_factor * med:
                        is_straggler = True
                        stragglers += 1
                        if self.on_straggler:
                            self.on_straggler(step, dt / med)
                if is_straggler:
                    consec_outliers += 1
                if not is_straggler or consec_outliers > 3:
                    window.append(dt)
                if not is_straggler:
                    consec_outliers = 0
                times.append(dt)

                rec = {k: float(v) for k, v in metrics.items()}
                rec.update(step=step, wall_s=dt, stragglers=stragglers)
                if log_f:
                    log_f.write(json.dumps(rec) + "\n")
                    log_f.flush()

                must_save = (self.ckpt_dir and
                             ((step + 1) % self.ckpt_every == 0
                              or preempted["flag"]
                              or step + 1 == num_steps))
                if must_save:
                    save_checkpoint(self.ckpt_dir, step,
                                    (params, opt_state),
                                    extras={"step": step}, keep_n=self.keep_n)
                if preempted["flag"]:
                    break
                step += 1
        finally:
            signal.signal(signal.SIGTERM, prev_handler)
            if log_f:
                log_f.close()

        p50 = float(np.median(times)) if times else 0.0
        p99 = float(np.percentile(times, 99)) if times else 0.0
        return params, opt_state, {
            "final_step": step, "p50_s": p50, "p99_s": p99,
            "stragglers": stragglers, "preempted": preempted["flag"]}
