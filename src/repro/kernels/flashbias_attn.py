"""Pallas TPU kernel: fused flash attention with low-rank (FlashBias) bias.

TPU adaptation of the paper's Triton kernel (Sec. 4.1 "Implementation
choices"), re-derived for the TPU memory hierarchy:

- The logits tile is computed as **two MXU contractions per tile**:
  ``s = (q @ k^T) * scale + phi_q @ phi_k^T`` — the factor tensors live in
  their own VMEM tiles instead of being concatenated onto q/k in HBM
  (which would re-write (N+M)(C+R) bytes and disturb existing layouts).
- Online softmax state (m, l, acc) is carried in VMEM scratch across the
  innermost (kv) grid axis; TPU grids are sequential so the revisiting
  accumulation pattern is well-defined.
- Masks (causal / sliding window) are *computed* from ``broadcasted_iota``
  — never read from HBM — and fully-masked kv blocks skip all compute via
  ``pl.when`` (the TPU analogue of mask-block pruning).
- ``bias_mode="alibi"`` additionally generates the rank-2 ALiBi bias
  *in-kernel* from per-head slopes (App. C's JIT trick): zero factor IO.

Block shapes are (block_q x D) / (block_k x D) with D, R padded to the
128-lane boundary by the ``ops.py`` wrapper; block_q/block_k default to 128
(= MXU systolic dim), giving a VMEM working set of
``(2*block_q + 2*block_k)*(D+R)*4`` bytes ≪ 128 MiB v5e VMEM.

The head-major (B, H, N, D) layout this kernel reads is the repo-wide
cache/compute layout contract (ops.py module docstring): since ISSUE 5 the
models project q/k/v head-major directly (``flash_attention(layout=
"bhsd")``), so no transpose stands between the projections and these
blocks.

Forward-only: training uses the XLA chunked path (mirroring the paper, which
uses the Triton kernel for inference and SDPA for training). ``ops.py`` wires
this kernel as the forward of a ``jax.custom_vjp`` whose backward is the
chunked path's VJP.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.attention import DEFAULT_MASK_VALUE

__all__ = ["flashbias_attention_fwd"]


def _attn_kernel(
    # refs (inputs in BlockSpec order, then outputs, then scratch)
    q_ref, k_ref, v_ref, phi_q_ref, phi_k_ref, slopes_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    mask_kind: str,
    window: int,
    kv_len: int,
    bias_mode: str,
):
    i = pl.program_id(2)          # q block index
    j = pl.program_id(3)          # kv block index
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * block_q
    k_start = j * block_k

    # ---- whole-block mask pruning (computed, not loaded) ----------------
    if mask_kind == "causal":
        run_block = k_start <= q_start + block_q - 1
    elif mask_kind == "local":
        run_block = jnp.logical_and(
            k_start <= q_start + block_q - 1,                 # causal side
            k_start + block_k - 1 >= q_start - (window - 1),  # window side
        )
    else:
        run_block = k_start < kv_len

    @pl.when(run_block)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # (bq, bk)

        if bias_mode == "phi":
            pq = phi_q_ref[0, 0].astype(jnp.float32)  # (bq, R)
            pk = phi_k_ref[0, 0].astype(jnp.float32)  # (bk, R)
            s += jax.lax.dot_general(
                pq, pk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

        if bias_mode == "alibi":
            slope = slopes_ref[0, 0]
            s += slope * (k_pos - q_pos).astype(jnp.float32)

        allowed = k_pos < kv_len
        if mask_kind == "causal":
            allowed = jnp.logical_and(allowed, q_pos >= k_pos)
        elif mask_kind == "local":
            allowed = jnp.logical_and(allowed, q_pos >= k_pos)
            allowed = jnp.logical_and(allowed, q_pos - k_pos < window)
        s = jnp.where(allowed, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[...]                            # (bq, 1)... stored (bq, 128) lanes
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, Dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, Dv)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _attn_kernel_ragged(lengths_ref, *refs, **kw):
    """Per-batch-length variant: ``lengths`` arrives via scalar prefetch
    (SMEM, same idiom as flash_decode) and replaces the static ``kv_len``
    bound — each batch row masks (and block-skips) at its OWN length, which
    is what the serve engine's padded wave of variable-n_res complexes
    needs. The body is the static kernel verbatim with a traced bound."""
    kw.pop("kv_len", None)
    _attn_kernel(*refs, kv_len=lengths_ref[pl.program_id(0)], **kw)


def flashbias_attention_fwd(
    q: jax.Array,            # (B, H, N, D)
    k: jax.Array,            # (B, K, M, D)
    v: jax.Array,            # (B, K, M, Dv)
    phi_q: Optional[jax.Array] = None,   # (B, H, N, R)
    phi_k: Optional[jax.Array] = None,   # (B, H, M, R)
    slopes: Optional[jax.Array] = None,  # (H, 1) for bias_mode="alibi"
    *,
    scale: float,
    mask_kind: str = "none",
    window: int = 0,
    kv_len: Optional[int] = None,
    lengths: Optional[jax.Array] = None,  # (B,) int32 per-batch kv bound
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel entry — shapes must already be tile-aligned (see ops.py)."""
    b, h, n, d = q.shape
    _, kvh, m, _ = k.shape
    dv = v.shape[-1]
    group = h // kvh
    kv_len = m if kv_len is None else kv_len
    bias_mode = "phi" if phi_q is not None else ("alibi" if slopes is not None else "none")

    grid = (b, h, n // block_q, m // block_k)

    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j, *_: (b_, h_, i, 0)),
        pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j, *_: (b_, h_ // group, j, 0)),
        pl.BlockSpec((1, 1, block_k, dv), lambda b_, h_, i, j, *_: (b_, h_ // group, j, 0)),
    ]
    args = [q, k, v]
    if bias_mode == "phi":
        r = phi_q.shape[-1]
        in_specs += [
            pl.BlockSpec((1, 1, block_q, r), lambda b_, h_, i, j, *_: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, r), lambda b_, h_, i, j, *_: (b_, h_, j, 0)),
        ]
        args += [phi_q, phi_k]
    else:
        in_specs += [None, None]
        args += [None, None]
    if bias_mode == "alibi":
        in_specs.append(pl.BlockSpec((1, 1), lambda b_, h_, i, j, *_: (h_, 0)))
        args.append(slopes)
    else:
        in_specs.append(None)
        args.append(None)

    static = {"scale": scale, "block_q": block_q, "block_k": block_k,
              "mask_kind": mask_kind, "window": window,
              "bias_mode": bias_mode}
    out_spec = pl.BlockSpec((1, 1, block_q, dv),
                            lambda b_, h_, i, j, *_: (b_, h_, i, 0))
    scratch = [
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, 1), jnp.float32),
        pltpu.VMEM((block_q, dv), jnp.float32),
    ]
    out_shape = jax.ShapeDtypeStruct((b, h, n, dv), q.dtype)

    if lengths is not None:
        kernel = functools.partial(_attn_kernel_ragged, **static)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_spec, scratch_shapes=scratch)
        return pl.pallas_call(kernel, grid_spec=grid_spec,
                              out_shape=out_shape, interpret=interpret)(
            lengths.astype(jnp.int32), *args)

    kernel = functools.partial(_attn_kernel, kv_len=kv_len, **static)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    return out
