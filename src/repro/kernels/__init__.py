"""Pallas TPU kernels for the FlashBias hot spots + jnp oracles.

- ``flashbias_attn``: fused flash attention with low-rank (factored) bias,
  in-kernel ALiBi, causal/local masks computed from iota.
- ``flash_decode``: KV-cache decode with grouped q-heads as tile rows,
  scalar-prefetched per-request lengths, low-rank bias factors.
- ``ops``: public jit'd wrappers (padding, layout, dispatch, custom_vjp).
- ``ssd_scan``: fused Mamba2 SSD chunk scan (state in VMEM scratch).
- ``ref``: pure-jnp oracles the kernels are allclose-tested against.

The callables live in ``ops``: ``ops.flash_attention`` / ``ops.flash_decode``
(re-exported here as ``flash_attention`` / ``flash_decode_op`` so the
``flash_decode`` *module* name stays importable).
"""
from repro.kernels import (  # noqa: F401
    flash_decode,
    flashbias_attn,
    ops,
    ref,
    ssd_scan,
)
from repro.kernels.ops import flash_attention
from repro.kernels.ops import flash_decode as flash_decode_op

__all__ = ["flash_decode", "flashbias_attn", "ops", "ref",
           "flash_attention", "flash_decode_op"]
