"""Pallas TPU kernel: fused Mamba2 SSD chunk scan.

Identified in EXPERIMENTS.md §Perf (hymba/mamba cells) as the remaining
memory hot spot: the XLA SSD path materializes the (B, nc, Q, Q, H) decay /
weight tensors to HBM-visible buffers; this kernel keeps the whole
intra-chunk pipeline in VMEM and carries the (P, N) state in scratch across
the sequential chunk axis — the same revisiting-accumulator pattern as the
flash attention kernel.

Per (batch, head, chunk) tile:

    cum   = cumsum(dt * a)                 (Q,)     VMEM
    L     = tril(exp(cum_i - cum_j))       (Q, Q)   VMEM, never HBM
    w     = (c b^T) . L . dt_j             (Q, Q)
    y     = w @ x  +  exp(cum) c @ h       (Q, P)   two MXU calls
    h     = exp(cum_Q) h + (dt*sdec*b)^T @ x        state update in scratch

HBM traffic: read x, dt, b, c once; write y once; h never leaves VMEM —
exactly the io_stub accounting the roofline's adjusted memory term assumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_fwd"]


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref,
                h_scr,
                *, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, 1) -> (Q,)
    dt = dt[:, 0]
    a = a_ref[0, 0]                              # scalar decay rate (<0)
    bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    cum = jnp.cumsum(dt * a)                     # (Q,) inclusive
    seg = cum[:, None] - cum[None, :]            # (Q, Q)
    q = x.shape[0]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * decay * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y += exp(cum) * (c @ h^T);  h: (P, N)
    h = h_scr[...]
    ch = jax.lax.dot_general(cm, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, P)
    y = y + jnp.exp(cum)[:, None] * ch

    # state update: h' = exp(cum_Q) h + sum_j sdec_j dt_j x_j b_j^T
    sdec = jnp.exp(cum[-1] - cum) * dt                            # (Q,)
    xw = x * sdec[:, None]                                        # (Q, P)
    upd = jax.lax.dot_general(xw, bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_scr[...] = jnp.exp(cum[-1]) * h + upd

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan_fwd(x: jax.Array,      # (B, H, S, P)
                 dt: jax.Array,     # (B, H, S, 1)  (softplus'd, > 0)
                 a: jax.Array,      # (H, 1) negative decay rates
                 b: jax.Array,      # (B, 1|H, S, N)
                 c: jax.Array,      # (B, 1|H, S, N)
                 *,
                 chunk: int = 256,
                 interpret: bool = False) -> jax.Array:
    """Raw kernel entry — S must be a multiple of ``chunk`` (pad upstream).

    Returns y (B, H, S, P). b/c with a singleton head dim are broadcast
    (ngroups=1, the assigned configs' setting).
    """
    bsz, h, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    if b.shape[1] == 1:
        b = jnp.broadcast_to(b, (bsz, h, s, n))
        c = jnp.broadcast_to(c, (bsz, h, s, n))
    nc = s // chunk

    grid = (bsz, h, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, j: (h_, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b_, h_, j: (b_, h_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda b_, h_, j: (b_, h_, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return out
