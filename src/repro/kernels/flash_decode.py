"""Pallas TPU kernel: flash-decoding with a KV cache and low-rank bias.

One new token per request attends to a cache of up to S keys. TPU adaptation:

- The G q-heads sharing one kv head form the *rows* of the logits tile
  (``(G, block_k)``), so GQA turns the tiny N=1 decode matmul into an MXU-
  shaped one — the TPU analogue of GPU flash-decoding's split-K blocks.
- The cache sequence axis is the innermost grid axis; online-softmax state
  (m, l, acc) rides in VMEM scratch (TPU grids are sequential, so the
  accumulate-across-j pattern is exact, no cross-block reduction pass).
- Per-request lengths arrive via scalar prefetch (SMEM); blocks past the
  length are skipped entirely (``pl.when``) — compute *and* the copy of the
  skipped KV block are elided on real hardware by block-index aliasing.
- FlashBias factors: ``phi_q`` is (G, R) per kv head, ``phi_k`` rides with the
  cache at (block_k, R) — rank-R bias costs R/D extra MXU depth, never NM IO.
- ``slopes`` mode generates the rank-2 ALiBi bias in-kernel (App. C JIT
  trick): zero bias IO at all.

``flash_decode_paged_fwd`` is the PAGED variant: the KV cache (and the
per-page ``phi_k`` factor slab) lives in a shared page pool and each
request's pages are resolved through a scalar-prefetched page table. The
kernel BODY is shared with the contiguous path — grid axis j is the
*logical* block index (page_size == block_k), so position math and the
length-based block skipping are unchanged; only the block index maps
differ (they read ``page_table[b, j]`` to find the physical page). Blocks
past the request length clamp to the last mapped page, so skipped and
unmapped pages alias the previous block's index and their copies are
elided on hardware exactly like the contiguous path's skipped blocks.

Cache layout contract (ISSUE 5): both kernels read KV in the layouts
declared below — ``(B, KVH, S, *)`` contiguous, ``(KVH, n_pages, ps, *)``
paged — and since ISSUE 5 the model caches are *stored* in exactly these
layouts (lane-padded at allocation), so ``ops.py`` passes them zero-copy:
nobody owns a per-step transpose anymore. The paged ``phi_pages`` factor
slab may carry a leading kv-head axis of 1 (``(1, n_pages, ps, R)``): the
kv-head broadcast then happens in its block index map (every kv head reads
the same physical page block), which is what lets the slab stay a single
layer- and head-shared copy in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.attention import DEFAULT_MASK_VALUE

__all__ = ["flash_decode_fwd", "flash_decode_paged_fwd"]


def _decode_kernel(
    lengths_ref,                      # scalar prefetch: (B,) int32 in SMEM
    q_ref, k_ref, v_ref, phi_q_ref, phi_k_ref, slopes_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_k: int,
    bias_mode: str,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    k_start = j * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)

        if bias_mode == "phi":
            pq = phi_q_ref[0, 0].astype(jnp.float32)      # (G, R)
            pk = phi_k_ref[0, 0].astype(jnp.float32)      # (bk, R)
            s += jax.lax.dot_general(
                pq, pk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

        g = s.shape[0]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
        if bias_mode == "alibi":
            slope = slopes_ref[0].astype(jnp.float32)     # (G,)
            rel = (k_pos - (length - 1)).astype(jnp.float32)
            s += slope[:, None] * rel

        s = jnp.where(k_pos < length, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, Dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_fwd(
    q: jax.Array,                         # (B, KVH, G, D)
    k_cache: jax.Array,                   # (B, KVH, S, D)
    v_cache: jax.Array,                   # (B, KVH, S, Dv)
    lengths: jax.Array,                   # (B,) int32
    phi_q: Optional[jax.Array] = None,    # (B, KVH, G, R)
    phi_k: Optional[jax.Array] = None,    # (B, KVH, S, R)
    slopes: Optional[jax.Array] = None,   # (KVH, G)
    *,
    scale: float,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw decode kernel — S must be a multiple of block_k (see ops.py)."""
    b, kvh, g, d = q.shape
    s_len = k_cache.shape[2]
    dv = v_cache.shape[-1]
    assert s_len % block_k == 0, (s_len, block_k)
    bias_mode = ("phi" if phi_q is not None
                 else ("alibi" if slopes is not None else "none"))

    grid = (b, kvh, s_len // block_k)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, j, *_: (b_, h_, j, 0)),
        pl.BlockSpec((1, 1, block_k, dv), lambda b_, h_, j, *_: (b_, h_, j, 0)),
    ]
    args = [q, k_cache, v_cache]
    if bias_mode == "phi":
        r = phi_q.shape[-1]
        in_specs += [
            pl.BlockSpec((1, 1, g, r), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, r), lambda b_, h_, j, *_: (b_, h_, j, 0)),
        ]
        args += [phi_q, phi_k]
    else:
        in_specs += [None, None]
        args += [None, None]
    if bias_mode == "alibi":
        in_specs.append(pl.BlockSpec((1, g), lambda b_, h_, j, *_: (h_, 0)))
        args.append(slopes)
    else:
        in_specs.append(None)
        args.append(None)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               bias_mode=bias_mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), *args)
    return out


def _paged_decode_kernel(lengths_ref, page_table_ref, *rest, **kw):
    # page resolution happens entirely in the block index maps; the body is
    # the contiguous kernel verbatim (j stays the LOGICAL block index)
    del page_table_ref
    _decode_kernel(lengths_ref, *rest, **kw)


def flash_decode_paged_fwd(
    q: jax.Array,                         # (B, KVH, G, D)
    k_pages: jax.Array,                   # (KVH, n_pages, ps, D)
    v_pages: jax.Array,                   # (KVH, n_pages, ps, Dv)
    lengths: jax.Array,                   # (B,) int32
    page_table: jax.Array,                # (B, P) int32 page ids
    phi_q: Optional[jax.Array] = None,    # (B, KVH, G, R)
    phi_pages: Optional[jax.Array] = None,  # (KVH|1, n_pages, ps, R)
    slopes: Optional[jax.Array] = None,   # (KVH, G)
    *,
    scale: float,
    interpret: bool = False,
) -> jax.Array:
    """Paged decode kernel: block_k == page_size, pages via scalar prefetch.

    ``page_table[b, j]`` holds the physical page of request b's j-th logical
    block; entries past the request's mapped prefix may be anything (they
    are clamped to the last in-length block, whose compute ``pl.when``
    skips). Every page id is clamped into the pool, so a stale table can
    never fault — at worst it reads a page the length mask then discards.

    ``phi_pages`` with a leading kv-head axis of 1 is the layer/kv-head-
    shared factor slab: its index map pins the head coordinate to 0, so the
    kv-head broadcast costs nothing (same block, every head).
    """
    b, kvh, g, d = q.shape
    n_pages, ps = k_pages.shape[1], k_pages.shape[2]
    p_max = page_table.shape[1]
    dv = v_pages.shape[-1]
    bias_mode = ("phi" if phi_q is not None
                 else ("alibi" if slopes is not None else "none"))

    def _page_map(h_of):
        def m(b_, h_, j, lens_ref, pt_ref):
            # clamp j to the last in-length block so skipped/unmapped blocks
            # alias the previous DMA; clamp the id so stale tables stay
            # in-pool
            last = jnp.maximum(lens_ref[b_] - 1, 0) // ps
            page = pt_ref[b_, jnp.minimum(j, last)]
            return (h_of(h_), jnp.clip(page, 0, n_pages - 1), 0, 0)
        return m

    page_map = _page_map(lambda h_: h_)

    grid = (b, kvh, p_max)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, ps, d), page_map),
        pl.BlockSpec((1, 1, ps, dv), page_map),
    ]
    args = [q, k_pages, v_pages]
    if bias_mode == "phi":
        r = phi_q.shape[-1]
        # kv-head-shared slab (leading axis 1): broadcast via the index map
        phi_map = (_page_map(lambda h_: 0) if phi_pages.shape[0] == 1
                   else page_map)
        in_specs += [
            pl.BlockSpec((1, 1, g, r), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ps, r), phi_map),
        ]
        args += [phi_q, phi_pages]
    else:
        in_specs += [None, None]
        args += [None, None]
    if bias_mode == "alibi":
        in_specs.append(pl.BlockSpec((1, g), lambda b_, h_, j, *_: (h_, 0)))
        args.append(slopes)
    else:
        in_specs.append(None)
        args.append(None)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, block_k=ps,
                               bias_mode=bias_mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), *args)
    return out
