"""Pallas TPU kernel: flash-decoding with a KV cache and low-rank bias.

One new token per request attends to a cache of up to S keys. TPU adaptation:

- The G q-heads sharing one kv head form the *rows* of the logits tile
  (``(G, block_k)``), so GQA turns the tiny N=1 decode matmul into an MXU-
  shaped one — the TPU analogue of GPU flash-decoding's split-K blocks.
- The cache sequence axis is the innermost grid axis; online-softmax state
  (m, l, acc) rides in VMEM scratch (TPU grids are sequential, so the
  accumulate-across-j pattern is exact, no cross-block reduction pass).
- Per-request lengths arrive via scalar prefetch (SMEM); blocks past the
  length are skipped entirely (``pl.when``) — compute *and* the copy of the
  skipped KV block are elided on real hardware by block-index aliasing.
- FlashBias factors: ``phi_q`` is (G, R) per kv head, ``phi_k`` rides with the
  cache at (block_k, R) — rank-R bias costs R/D extra MXU depth, never NM IO.
- ``slopes`` mode generates the rank-2 ALiBi bias in-kernel (App. C JIT
  trick): zero bias IO at all.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.attention import DEFAULT_MASK_VALUE

__all__ = ["flash_decode_fwd"]


def _decode_kernel(
    lengths_ref,                      # scalar prefetch: (B,) int32 in SMEM
    q_ref, k_ref, v_ref, phi_q_ref, phi_k_ref, slopes_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    block_k: int,
    bias_mode: str,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    k_start = j * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bk)

        if bias_mode == "phi":
            pq = phi_q_ref[0, 0].astype(jnp.float32)      # (G, R)
            pk = phi_k_ref[0, 0].astype(jnp.float32)      # (bk, R)
            s += jax.lax.dot_general(
                pq, pk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

        g = s.shape[0]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
        if bias_mode == "alibi":
            slope = slopes_ref[0].astype(jnp.float32)     # (G,)
            rel = (k_pos - (length - 1)).astype(jnp.float32)
            s += slope[:, None] * rel

        s = jnp.where(k_pos < length, s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, Dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode_fwd(
    q: jax.Array,                         # (B, KVH, G, D)
    k_cache: jax.Array,                   # (B, KVH, S, D)
    v_cache: jax.Array,                   # (B, KVH, S, Dv)
    lengths: jax.Array,                   # (B,) int32
    phi_q: Optional[jax.Array] = None,    # (B, KVH, G, R)
    phi_k: Optional[jax.Array] = None,    # (B, KVH, S, R)
    slopes: Optional[jax.Array] = None,   # (KVH, G)
    *,
    scale: float,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw decode kernel — S must be a multiple of block_k (see ops.py)."""
    b, kvh, g, d = q.shape
    s_len = k_cache.shape[2]
    dv = v_cache.shape[-1]
    assert s_len % block_k == 0, (s_len, block_k)
    bias_mode = ("phi" if phi_q is not None
                 else ("alibi" if slopes is not None else "none"))

    grid = (b, kvh, s_len // block_k)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, j, *_: (b_, h_, j, 0)),
        pl.BlockSpec((1, 1, block_k, dv), lambda b_, h_, j, *_: (b_, h_, j, 0)),
    ]
    args = [q, k_cache, v_cache]
    if bias_mode == "phi":
        r = phi_q.shape[-1]
        in_specs += [
            pl.BlockSpec((1, 1, g, r), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, r), lambda b_, h_, j, *_: (b_, h_, j, 0)),
        ]
        args += [phi_q, phi_k]
    else:
        in_specs += [None, None]
        args += [None, None]
    if bias_mode == "alibi":
        in_specs.append(pl.BlockSpec((1, g), lambda b_, h_, j, *_: (h_, 0)))
        args.append(slopes)
    else:
        in_specs.append(None)
        args.append(None)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               bias_mode=bias_mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda b_, h_, j, *_: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, dv), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), *args)
    return out
