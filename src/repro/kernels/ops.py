"""Jit'd public wrappers around the Pallas kernels.

``flash_attention`` / ``flash_decode`` are the entry points the models call.
They:

- accept the canonical (B, S, H, D) layout and transpose to the kernels'
  head-major layout;
- pad every tile dim to TPU alignment (seq -> block multiple, channels/rank
  -> 128-lane multiple) with mathematically inert zeros, slicing the result
  back;
- dispatch between the Pallas kernel (TPU, or ``interpret=True`` on CPU for
  tests) and the pure-XLA chunked path in ``repro.core.attention`` (which is
  what the multi-pod dry-run lowers — Pallas does not lower to the CPU
  backend);
- expose a ``jax.custom_vjp``: the backward pass re-runs attention via the
  XLA chunked path's VJP (flash-style recompute — the paper likewise uses
  the Triton kernel for inference and SDPA autograd for training).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as attn_mod
from repro.core.attention import MaskSpec
from repro.kernels import flash_decode as _fd
from repro.kernels import flashbias_attn as _fa

__all__ = ["flash_attention", "flash_decode", "IMPLS"]

IMPLS = ("xla", "pallas", "pallas_interpret", "io_stub")

_LANE = 128


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    assert impl in IMPLS, impl
    return impl


# ---------------------------------------------------------------------------
# Full (training / prefill) attention
# ---------------------------------------------------------------------------

def _xla_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale,
              chunk_size=512):
    if slopes is not None:
        # materialize rank-2 ALiBi factors (cheap: (N+M)*2 elements)
        n, m, h = q.shape[1], k.shape[1], q.shape[2]
        qi = jnp.arange(n, dtype=jnp.float32)
        kj = jnp.arange(m, dtype=jnp.float32)
        pq = jnp.stack([-qi, jnp.ones_like(qi)], -1)[None, :, None, :]
        pq = pq * slopes.reshape(1, 1, h, 1)
        pk = jnp.stack([jnp.ones_like(kj), kj], -1)[None, :, None, :]
        phi_q = jnp.broadcast_to(pq, (q.shape[0], n, h, 2)).astype(jnp.float32)
        phi_k = jnp.broadcast_to(pk, (q.shape[0], m, 1, 2)).astype(jnp.float32)
    if phi_k is not None and phi_k.shape[2] not in (1, q.shape[2]):
        # per-kv-head factors (B, M, KVH, R): expand each kv head's factor
        # row over its group of query heads. (Collapsing to head 0 here
        # would silently mis-bias every non-first kv group under GQA.)
        kvh_pk = phi_k.shape[2]
        assert q.shape[2] % kvh_pk == 0, (phi_k.shape, q.shape)
        phi_k = jnp.repeat(phi_k, q.shape[2] // kvh_pk, axis=2)
    if phi_k is not None and phi_k.shape[2] == 1:
        phi_k = jnp.broadcast_to(
            phi_k, (*phi_k.shape[:2], q.shape[2], phi_k.shape[3]))
    return attn_mod.attention(
        q, k, v, mask=MaskSpec(mask_kind, window), scale=scale,
        phi_q=phi_q, phi_k=phi_k, impl="chunked", chunk_size=chunk_size)


def _pallas_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale,
                 block_q, block_k, interpret):
    b, n, h, d = q.shape
    m, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    n_p, m_p = _ceil_to(n, block_q), _ceil_to(m, block_k)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)

    qt = _pad_axis(_pad_axis(q, 1, n_p), 3, d_p).transpose(0, 2, 1, 3)
    kt = _pad_axis(_pad_axis(k, 1, m_p), 3, d_p).transpose(0, 2, 1, 3)
    vt = _pad_axis(_pad_axis(v, 1, m_p), 3, dv_p).transpose(0, 2, 1, 3)

    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        if phi_k.shape[2] not in (1, h):     # per-kv-head: expand per group
            assert h % phi_k.shape[2] == 0, (phi_k.shape, h)
            phi_k = jnp.repeat(phi_k, h // phi_k.shape[2], axis=2)
        phi_k_full = jnp.broadcast_to(phi_k, (b, m, h, r))
        pqt = _pad_axis(_pad_axis(phi_q, 1, n_p), 3, r_p).transpose(0, 2, 1, 3)
        pkt = _pad_axis(_pad_axis(phi_k_full, 1, m_p), 3, r_p).transpose(0, 2, 1, 3)
    slopes2 = slopes.reshape(h, 1) if slopes is not None else None

    out = _fa.flashbias_attention_fwd(
        qt, kt, vt, pqt, pkt, slopes2, scale=scale, mask_kind=mask_kind,
        window=window, kv_len=m, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :n, :, :dv]


def _io_stub_path(q, k, v, phi_q, phi_k):
    """Deployment-IO accounting stub (dry-run only, ``impl="io_stub"``).

    The Pallas kernel's HBM traffic is exactly: read q, k, v (+ factors)
    once, write o once — logits/softmax live in VMEM. This stub has the
    same HBM footprint and output shape but trivial FLOPs, so a cost
    lowering with it measures the *deployment* memory term (the XLA chunked
    fallback materializes its softmax pipeline, inflating bytes ~10x).
    Every input is consumed through a full-read reduction so XLA cannot
    DCE the loads.
    """
    b, n, h, d = q.shape
    dv = v.shape[-1]
    eps = jnp.asarray(1e-30, jnp.float32)
    dep = (jnp.sum(k.astype(jnp.float32)) + jnp.sum(v.astype(jnp.float32)))
    if phi_q is not None:
        dep = dep + jnp.sum(phi_q.astype(jnp.float32)) \
            + jnp.sum(phi_k.astype(jnp.float32))
    o = q[..., :1].astype(jnp.float32) * eps + dep * eps
    o = jnp.broadcast_to(o, (b, n, h, dv))
    return o.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11))
def _flash_attention_core(q, k, v, phi_q, phi_k, slopes,
                          mask_kind, window, scale, impl, block_q, block_k):
    if impl == "io_stub":
        return _io_stub_path(q, k, v, phi_q, phi_k)
    if impl == "xla":
        return _xla_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                         scale)
    return _pallas_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                        scale, block_q, block_k,
                        interpret=(impl == "pallas_interpret"))


def _fwd(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale, impl,
         block_q, block_k):
    out = _flash_attention_core(q, k, v, phi_q, phi_k, slopes, mask_kind,
                                window, scale, impl, block_q, block_k)
    return out, (q, k, v, phi_q, phi_k, slopes)


def _bwd(mask_kind, window, scale, impl, block_q, block_k, res, g):
    q, k, v, phi_q, phi_k, slopes = res
    if impl == "io_stub":
        # deployment backward IO: the flash backward re-reads q,k,v(,phi) and
        # the cotangent once and writes dq,dk,dv(,dphi) once — the stub's own
        # vjp has exactly that HBM footprint.
        def fs(q, k, v, phi_q, phi_k):
            return _io_stub_path(q, k, v, phi_q, phi_k)
        _, vjp = jax.vjp(fs, q, k, v, phi_q, phi_k)
        return vjp(g) + (None,)

    # Recompute forward through the differentiable XLA path (flash recompute).
    def f(q, k, v, phi_q, phi_k, slopes):
        return _xla_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                         scale)
    _, vjp = jax.vjp(f, q, k, v, phi_q, phi_k, slopes)
    return vjp(g)


_flash_attention_core.defvjp(_fwd, _bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    phi_q: Optional[jax.Array] = None,
    phi_k: Optional[jax.Array] = None,
    slopes: Optional[jax.Array] = None,
    *,
    mask_kind: str = "none",
    window: int = 0,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """FlashBias attention, (B, N, H, D) layout.

    Exactly one of {phi_q+phi_k, slopes, neither} selects the bias mode
    (factored / in-kernel ALiBi / none). Differentiable in q, k, v, phi_*.
    """
    scale = (1.0 / float(np.sqrt(q.shape[-1]))) if scale is None else scale
    assert not (phi_q is not None and slopes is not None)
    return _flash_attention_core(q, k, v, phi_q, phi_k, slopes, mask_kind,
                                 window, scale, _resolve_impl(impl),
                                 block_q, block_k)


# ---------------------------------------------------------------------------
# Decode (one token, KV cache) — inference only, no vjp needed
# ---------------------------------------------------------------------------

def flash_decode(
    q: jax.Array,                        # (B, 1, H, D)
    k_cache: jax.Array,                  # (B, S, KVH, D); paged: (P, ps, KVH, D)
    v_cache: jax.Array,                  # (B, S, KVH, Dv); paged: (P, ps, KVH, Dv)
    lengths: jax.Array,                  # (B,) int32
    phi_q: Optional[jax.Array] = None,   # (B, 1, H, R)
    phi_k: Optional[jax.Array] = None,   # (B, S, KVH|H|1, R);
                                         # paged slab: (P, ps, R) | (P, ps, KVH, R)
    slopes: Optional[jax.Array] = None,  # (H,)
    *,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_k: int = 512,
    page_table: Optional[jax.Array] = None,  # (B, P_slot) int32 -> paged mode
) -> jax.Array:
    """Single-token decode against a KV cache. Returns (B, 1, H, Dv).

    With ``page_table`` the caches are a shared PAGE POOL: ``k_cache`` /
    ``v_cache`` are ``(n_pages, page_size, KVH, *)`` and ``phi_k`` (if any)
    is the per-page factor slab — ``(n_pages, page_size, R)`` shared across
    kv heads or ``(n_pages, page_size, KVH, R)``. ``page_table[b, j]`` maps
    request b's j-th logical block to its physical page; entries beyond the
    mapped prefix are ignored (clamped + length-masked). The Pallas path
    resolves pages through scalar-prefetched block index maps (skipped and
    unmapped pages alias their neighbour's copy); the XLA/io_stub paths
    gather the pool into each request's logical view first.
    """
    if page_table is not None:
        return _flash_decode_paged(q, k_cache, v_cache, lengths, page_table,
                                   phi_q, phi_k, slopes, scale=scale,
                                   impl=impl, block_k=block_k)
    b, _, h, d = q.shape
    s_len, kvh = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    impl = _resolve_impl(impl)

    if impl == "io_stub":
        # deployment IO of the decode kernel: read cache + q once, write o
        dep = (jnp.sum(k_cache.astype(jnp.float32))
               + jnp.sum(v_cache.astype(jnp.float32)))
        if phi_k is not None:
            dep = dep + jnp.sum(phi_k.astype(jnp.float32))
        eps = jnp.asarray(1e-30, jnp.float32)
        o = q[..., :1].astype(jnp.float32) * eps + dep * eps
        return jnp.broadcast_to(o, (b, 1, h, dv)).astype(q.dtype)

    if impl == "xla":
        phi_k_x = phi_k
        if phi_k_x is not None and phi_k_x.shape[2] not in (1, h):
            # per-kv-head factors: expand over each kv head's query group
            assert h % phi_k_x.shape[2] == 0, (phi_k_x.shape, h)
            phi_k_x = jnp.repeat(phi_k_x, h // phi_k_x.shape[2], axis=2)
        if phi_k_x is not None and phi_k_x.shape[2] == 1:
            phi_k_x = jnp.broadcast_to(phi_k_x, (b, s_len, h, phi_k_x.shape[-1]))
        if slopes is not None:
            # ALiBi factors for the decode row: q at position lengths-1.
            qpos = (lengths.astype(jnp.float32) - 1.0)[:, None, None, None]
            pq = jnp.concatenate([-jnp.broadcast_to(qpos, (b, 1, h, 1)),
                                  jnp.ones((b, 1, h, 1), jnp.float32)], -1)
            pq = pq * slopes.reshape(1, 1, h, 1)
            kj = jnp.arange(s_len, dtype=jnp.float32)
            pk = jnp.stack([jnp.ones_like(kj), kj], -1)[None, :, None, :]
            phi_k_x = jnp.broadcast_to(pk, (b, s_len, h, 2))
            phi_q = pq
        return attn_mod.attention(
            q, k_cache, v_cache, mask=MaskSpec("none"), scale=scale,
            phi_q=phi_q, phi_k=phi_k_x, kv_length=lengths,
            impl="chunked", chunk_size=min(block_k, s_len))

    # Pallas path: head-major grouped layout, padded tiles.
    g = h // kvh
    block_k = min(block_k, s_len)
    s_p = _ceil_to(s_len, block_k)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)
    g_p = _ceil_to(g, 8)

    def to_grouped_q(x, last_p):
        # (B, 1, H, E) -> (B, KVH, G, E) padded
        x = x[:, 0].reshape(b, kvh, g, x.shape[-1])
        x = _pad_axis(_pad_axis(x, 2, g_p), 3, last_p)
        return x

    def to_cache(x, last_p):
        # (B, S, KVH, E) -> (B, KVH, S_p, E)
        x = _pad_axis(_pad_axis(x.transpose(0, 2, 1, 3), 2, s_p), 3, last_p)
        return x

    qt = to_grouped_q(q, d_p)
    kt = to_cache(k_cache, d_p)
    vt = to_cache(v_cache, dv_p)
    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        # The grouped-key layout carries ONE key factor per kv head:
        # per-kv-head (B, S, KVH, R) rides as-is, head-shared broadcasts.
        # PER-Q-HEAD factors (B, S, H, R) can differ within a GQA group,
        # which the grouped layout cannot express — route to the XLA path
        # (the old code silently took each group's first head: ISSUE 3).
        kvh_pk = phi_k.shape[2]
        if kvh_pk not in (kvh, 1):
            assert kvh_pk == h, (phi_k.shape, h, kvh)
            return flash_decode(q, k_cache, v_cache, lengths, phi_q, phi_k,
                                slopes, scale=scale, impl="xla",
                                block_k=block_k)
        pqt = to_grouped_q(phi_q, r_p)
        if kvh_pk == kvh:
            pk_kv = phi_k
        else:
            pk_kv = jnp.broadcast_to(phi_k, (b, s_len, kvh, r))
        pkt = to_cache(pk_kv, r_p)
    slopes_g = None
    if slopes is not None:
        slopes_g = _pad_axis(slopes.reshape(kvh, g), 1, g_p)

    out = _fd.flash_decode_fwd(
        qt, kt, vt, lengths, pqt, pkt, slopes_g, scale=scale,
        block_k=block_k, interpret=(impl == "pallas_interpret"))
    out = out[:, :, :g, :dv].reshape(b, 1, h, dv)
    return out


def _flash_decode_paged(q, k_pages, v_pages, lengths, page_table,
                        phi_q, phi_k, slopes, *, scale, impl, block_k):
    """Paged dispatch for ``flash_decode`` (see its docstring for layouts)."""
    b, _, h, d = q.shape
    n_pages, ps, kvh = k_pages.shape[:3]
    dv = v_pages.shape[-1]
    p_slot = page_table.shape[1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    impl = _resolve_impl(impl)
    pt = jnp.clip(page_table.astype(jnp.int32), 0, n_pages - 1)

    if impl in ("xla", "io_stub"):
        # gather each request's pages into its logical contiguous view and
        # reuse the contiguous path (masking past ``lengths`` is identical)
        def view(pool):
            g = pool[pt]                          # (B, P_slot, ps, KVH, E)
            return g.reshape(b, p_slot * ps, *pool.shape[2:])
        phi_view = None
        if phi_k is not None:
            slab = phi_k if phi_k.ndim == 4 else phi_k[:, :, None, :]
            phi_view = view(slab)                 # (B, S_view, KVH|1, R)
        return flash_decode(q, view(k_pages), view(v_pages), lengths,
                            phi_q, phi_view, slopes, scale=scale, impl=impl,
                            block_k=block_k)

    # Pallas path: pools go kv-head-major, pages resolved in the kernel's
    # scalar-prefetch block index maps (no gather, no view materialization).
    g = h // kvh
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)
    g_p = _ceil_to(g, 8)

    def to_grouped_q(x, last_p):
        x = x[:, 0].reshape(b, kvh, g, x.shape[-1])
        return _pad_axis(_pad_axis(x, 2, g_p), 3, last_p)

    def to_pool(x, last_p):
        # (n_pages, ps, KVH, E) -> (KVH, n_pages, ps, E_pad)
        return _pad_axis(x.transpose(2, 0, 1, 3), 3, last_p)

    qt = to_grouped_q(q, d_p)
    kt = to_pool(k_pages, d_p)
    vt = to_pool(v_pages, dv_p)
    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        assert phi_q.shape[2] in (h, kvh), (phi_q.shape, h, kvh)
        if phi_q.shape[2] == kvh and kvh != h:    # shared within each group
            phi_q = jnp.repeat(phi_q, g, axis=2)
        pqt = to_grouped_q(phi_q, r_p)
        slab = phi_k if phi_k.ndim == 4 else phi_k[:, :, None, :]
        assert slab.shape[2] in (kvh, 1), (phi_k.shape, kvh)
        slab = jnp.broadcast_to(slab, (n_pages, ps, kvh, r))
        pkt = to_pool(slab, r_p)
    slopes_g = None
    if slopes is not None:
        slopes_g = _pad_axis(slopes.reshape(kvh, g), 1, g_p)

    out = _fd.flash_decode_paged_fwd(
        qt, kt, vt, lengths, pt, pqt, pkt, slopes_g, scale=scale,
        interpret=(impl == "pallas_interpret"))
    return out[:, :, :g, :dv].reshape(b, 1, h, dv)
