"""Jit'd public wrappers around the Pallas kernels.

``flash_attention`` / ``flash_decode`` are the entry points the models call.
They dispatch between the Pallas kernel (TPU, or ``interpret=True`` on CPU
for tests) and the pure-XLA paths, pad tile dims to TPU alignment with
mathematically inert zeros, and (for training) expose a ``jax.custom_vjp``
whose backward re-runs attention via the XLA chunked path's VJP
(flash-style recompute — the paper likewise uses the Triton kernel for
inference and SDPA autograd for training).

Cache layout contract (the decode hot path)
-------------------------------------------

The kernels consume KV in **kv-head-major** layout, and since ISSUE 5 the
caches are *stored* that way from allocation, so the jitted decode step
hands them over zero-copy — there is no per-step transpose, lane-pad or
factor broadcast of anything pool-sized:

- contiguous / ring KV: ``(B, KVH, S, hd)`` per layer (``kv_layout="bhsd"``).
  Store ``hd`` as a 128-lane multiple and ``S`` as a multiple of the decode
  block (128 is always safe) for the zero-copy guarantee; other shapes fall
  back to a documented pad (correctness, not speed).
- paged KV: pools ``(KVH, n_pages, ps, hd_pad)`` per layer, ``hd_pad`` lane-
  padded at ``init_paged_cache``; the per-page ``phi_k`` factor slab stays
  layer- AND kv-head-shared at ``(n_pages, ps, r_pad)`` — the kv-head
  broadcast happens in the kernel's block index maps, never as a
  ``broadcast_to`` on the pool.

Nobody owns a transpose anymore: allocation writes the kernel layout, every
writer (token scatter, prefill page scatter, ring rotation) writes it, and
the kernels read it. The canonical ``(B, S, KVH, hd)`` layout remains
accepted (``kv_layout="bshd"``, the default for direct callers) and is the
``layout_vs_legacy`` A/B + parity reference: it adapts per call, paying
exactly the per-step cost the kernel layout deletes.

The XLA fallbacks take cheap views of the kernel layout (head-major
einsums; the paged gather is capped at ``ceil(max(lengths)/page_size)``
pages when a static bound is known — pass ``max_pages`` from a host-side
length mirror, or call with concrete ``lengths``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention as attn_mod
from repro.core.attention import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels import flash_decode as _fd
from repro.kernels import flashbias_attn as _fa

__all__ = ["flash_attention", "flash_chunk_attention", "flash_decode",
           "resolve_impl", "IMPLS"]

IMPLS = ("xla", "pallas", "pallas_interpret", "io_stub")

_LANE = 128


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    """Public impl resolution ("auto" -> "pallas" on TPU, "xla" elsewhere).

    Models use this to pick the compute layout that will be zero-copy for
    the impl that actually runs (head-major for the Pallas kernels)."""
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    assert impl in IMPLS, impl
    return impl


def _pick_block(s_len: int, want: int) -> int:
    """Largest multiple-of-8 divisor of ``s_len`` that is <= ``want``
    (8 = TPU sublane: Mosaic rejects blocks whose second-minor dim isn't a
    multiple of it). Returns ``s_len`` itself below 8 (single tiny block,
    same as the canonical path's ``min(block_k, S)``) and 0 when no
    aligned divisor exists — the caller then pads the seq axis once.

    Under the cache layout contract (S a multiple of 128, or S <= want)
    this finds >= min(want, 128), so the kernel-layout decode path never
    pads the cache sequence axis. Trace-time Python: <= want/8 steps."""
    if s_len < 8:
        return s_len
    b = (min(want, s_len) // 8) * 8
    while b >= 8:
        if s_len % b == 0:
            return b
        b -= 8
    return 0


# ---------------------------------------------------------------------------
# Full (training / prefill) attention
# ---------------------------------------------------------------------------

def _xla_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale,
              chunk_size=512, lengths=None):
    if slopes is not None:
        # materialize rank-2 ALiBi factors (cheap: (N+M)*2 elements)
        n, m, h = q.shape[1], k.shape[1], q.shape[2]
        qi = jnp.arange(n, dtype=jnp.float32)
        kj = jnp.arange(m, dtype=jnp.float32)
        pq = jnp.stack([-qi, jnp.ones_like(qi)], -1)[None, :, None, :]
        pq = pq * slopes.reshape(1, 1, h, 1)
        pk = jnp.stack([jnp.ones_like(kj), kj], -1)[None, :, None, :]
        phi_q = jnp.broadcast_to(pq, (q.shape[0], n, h, 2)).astype(jnp.float32)
        phi_k = jnp.broadcast_to(pk, (q.shape[0], m, 1, 2)).astype(jnp.float32)
    if phi_k is not None and phi_k.shape[2] not in (1, q.shape[2]):
        # per-kv-head factors (B, M, KVH, R): expand each kv head's factor
        # row over its group of query heads. (Collapsing to head 0 here
        # would silently mis-bias every non-first kv group under GQA.)
        kvh_pk = phi_k.shape[2]
        assert q.shape[2] % kvh_pk == 0, (phi_k.shape, q.shape)
        phi_k = jnp.repeat(phi_k, q.shape[2] // kvh_pk, axis=2)
    if phi_k is not None and phi_k.shape[2] == 1:
        phi_k = jnp.broadcast_to(
            phi_k, (*phi_k.shape[:2], q.shape[2], phi_k.shape[3]))
    return attn_mod.attention(
        q, k, v, mask=MaskSpec(mask_kind, window), scale=scale,
        phi_q=phi_q, phi_k=phi_k, kv_length=lengths, impl="chunked",
        chunk_size=chunk_size)


def _pallas_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale,
                 block_q, block_k, interpret, lengths=None):
    b, n, h, d = q.shape
    m, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    n_p, m_p = _ceil_to(n, block_q), _ceil_to(m, block_k)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)

    qt = _pad_axis(_pad_axis(q, 1, n_p), 3, d_p).transpose(0, 2, 1, 3)
    kt = _pad_axis(_pad_axis(k, 1, m_p), 3, d_p).transpose(0, 2, 1, 3)
    vt = _pad_axis(_pad_axis(v, 1, m_p), 3, dv_p).transpose(0, 2, 1, 3)

    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        if phi_k.shape[2] not in (1, h):     # per-kv-head: expand per group
            assert h % phi_k.shape[2] == 0, (phi_k.shape, h)
            phi_k = jnp.repeat(phi_k, h // phi_k.shape[2], axis=2)
        phi_k_full = jnp.broadcast_to(phi_k, (b, m, h, r))
        pqt = _pad_axis(_pad_axis(phi_q, 1, n_p), 3, r_p).transpose(0, 2, 1, 3)
        pkt = _pad_axis(_pad_axis(phi_k_full, 1, m_p), 3, r_p).transpose(0, 2, 1, 3)
    slopes2 = slopes.reshape(h, 1) if slopes is not None else None

    out = _fa.flashbias_attention_fwd(
        qt, kt, vt, pqt, pkt, slopes2, scale=scale, mask_kind=mask_kind,
        window=window, kv_len=m, lengths=lengths, block_q=block_q,
        block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :n, :, :dv]


def _pallas_path_hm(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale,
                    block_q, block_k, interpret, lengths=None):
    """Head-major (``layout="bhsd"``) Pallas dispatch: the kernel's native
    layout arrives from the caller, so only tile padding remains (token-
    and channel-sized, never a whole-tensor transpose)."""
    b, h, n, d = q.shape
    kvh, m = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    n_p, m_p = _ceil_to(n, block_q), _ceil_to(m, block_k)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)

    qt = _pad_axis(_pad_axis(q, 2, n_p), 3, d_p)
    kt = _pad_axis(_pad_axis(k, 2, m_p), 3, d_p)
    vt = _pad_axis(_pad_axis(v, 2, m_p), 3, dv_p)

    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        if phi_k.shape[1] not in (1, h):     # per-kv-head: expand per group
            assert h % phi_k.shape[1] == 0, (phi_k.shape, h)
            phi_k = jnp.repeat(phi_k, h // phi_k.shape[1], axis=1)
        phi_k_full = jnp.broadcast_to(phi_k, (b, h, m, r))
        pqt = _pad_axis(_pad_axis(phi_q, 2, n_p), 3, r_p)
        pkt = _pad_axis(_pad_axis(phi_k_full, 2, m_p), 3, r_p)
    slopes2 = slopes.reshape(h, 1) if slopes is not None else None

    out = _fa.flashbias_attention_fwd(
        qt, kt, vt, pqt, pkt, slopes2, scale=scale, mask_kind=mask_kind,
        window=window, kv_len=m, lengths=lengths, block_q=block_q,
        block_k=block_k, interpret=interpret)
    return out[:, :, :n, :dv]


def _io_stub_path(q, k, v, phi_q, phi_k, dv):
    """Deployment-IO accounting stub (dry-run only, ``impl="io_stub"``).

    The Pallas kernel's HBM traffic is exactly: read q, k, v (+ factors)
    once, write o once — logits/softmax live in VMEM. This stub has the
    same HBM footprint and output shape but trivial FLOPs, so a cost
    lowering with it measures the *deployment* memory term (the XLA chunked
    fallback materializes its softmax pipeline, inflating bytes ~10x).
    Every input is consumed through a full-read reduction so XLA cannot
    DCE the loads. Layout-agnostic: the output mirrors q's leading axes.
    """
    eps = jnp.asarray(1e-30, jnp.float32)
    dep = (jnp.sum(k.astype(jnp.float32)) + jnp.sum(v.astype(jnp.float32)))
    if phi_q is not None:
        dep = dep + jnp.sum(phi_q.astype(jnp.float32)) \
            + jnp.sum(phi_k.astype(jnp.float32))
    o = q[..., :1].astype(jnp.float32) * eps + dep * eps
    o = jnp.broadcast_to(o, (*q.shape[:3], dv))
    return o.astype(q.dtype)


def _to_bshd(x):
    return None if x is None else x.transpose(0, 2, 1, 3)


def _xla_path_any_layout(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                         scale, layout, lengths=None):
    """XLA chunked fallback for either layout — the single canonicalize
    point for ``"bhsd"`` inputs (cheap views in, transposed view out;
    prefill-sized, one-time). The custom_vjp forward AND its backward
    recompute both go through here, so they can never desynchronize."""
    if layout == "bhsd":
        o = _xla_path(_to_bshd(q), _to_bshd(k), _to_bshd(v),
                      _to_bshd(phi_q), _to_bshd(phi_k), slopes,
                      mask_kind, window, scale, lengths=lengths)
        return o.transpose(0, 2, 1, 3)
    return _xla_path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                     scale, lengths=lengths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12))
def _flash_attention_core(q, k, v, phi_q, phi_k, slopes,
                          mask_kind, window, scale, impl, block_q, block_k,
                          layout):
    if impl == "io_stub":
        return _io_stub_path(q, k, v, phi_q, phi_k, v.shape[-1])
    if impl == "xla":
        return _xla_path_any_layout(q, k, v, phi_q, phi_k, slopes,
                                    mask_kind, window, scale, layout)
    path = _pallas_path_hm if layout == "bhsd" else _pallas_path
    return path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                scale, block_q, block_k,
                interpret=(impl == "pallas_interpret"))


def _fwd(q, k, v, phi_q, phi_k, slopes, mask_kind, window, scale, impl,
         block_q, block_k, layout):
    out = _flash_attention_core(q, k, v, phi_q, phi_k, slopes, mask_kind,
                                window, scale, impl, block_q, block_k, layout)
    return out, (q, k, v, phi_q, phi_k, slopes)


def _bwd(mask_kind, window, scale, impl, block_q, block_k, layout, res, g):
    q, k, v, phi_q, phi_k, slopes = res
    if impl == "io_stub":
        # deployment backward IO: the flash backward re-reads q,k,v(,phi) and
        # the cotangent once and writes dq,dk,dv(,dphi) once — the stub's own
        # vjp has exactly that HBM footprint.
        def fs(q, k, v, phi_q, phi_k):
            return _io_stub_path(q, k, v, phi_q, phi_k, v.shape[-1])
        _, vjp = jax.vjp(fs, q, k, v, phi_q, phi_k)
        return vjp(g) + (None,)

    # Recompute forward through the differentiable XLA path (flash
    # recompute); head-major inputs flow through the canonicalizing views,
    # so their cotangents come back head-major automatically.
    def f(q, k, v, phi_q, phi_k, slopes):
        return _xla_path_any_layout(q, k, v, phi_q, phi_k, slopes,
                                    mask_kind, window, scale, layout)
    _, vjp = jax.vjp(f, q, k, v, phi_q, phi_k, slopes)
    return vjp(g)


_flash_attention_core.defvjp(_fwd, _bwd)


def _flash_attention_ragged(q, k, v, phi_q, phi_k, slopes, lengths,
                            mask_kind, window, scale, impl, block_q,
                            block_k, layout):
    """Non-causal ragged-batch path (``lengths`` per batch row): the serve
    engine's padded wave of variable-length requests — each row masks keys
    at positions >= its own length, so zero-padded rows (whose factor-MLP
    biases are NOT zero) contribute exact zero.

    Lives outside the custom_vjp (an int32 array can't ride its residual
    contract): the XLA branch is natively differentiable, the Pallas branch
    is forward-only — which is the only way the serve engine calls it.
    """
    if impl == "io_stub":
        return _io_stub_path(q, k, v, phi_q, phi_k, v.shape[-1])
    if impl == "xla":
        return _xla_path_any_layout(q, k, v, phi_q, phi_k, slopes,
                                    mask_kind, window, scale, layout,
                                    lengths=lengths)
    path = _pallas_path_hm if layout == "bhsd" else _pallas_path
    return path(q, k, v, phi_q, phi_k, slopes, mask_kind, window,
                scale, block_q, block_k,
                interpret=(impl == "pallas_interpret"), lengths=lengths)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    phi_q: Optional[jax.Array] = None,
    phi_k: Optional[jax.Array] = None,
    slopes: Optional[jax.Array] = None,
    *,
    mask_kind: str = "none",
    window: int = 0,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
    layout: str = "bshd",
    lengths: Optional[jax.Array] = None,
) -> jax.Array:
    """FlashBias attention.

    ``layout="bshd"`` (default): canonical (B, N, H, D) in and out.
    ``layout="bhsd"``: the kernels' head-major (B, H, N, D) in and out —
    zero-copy into the Pallas kernel (models that keep kernel-layout caches
    project straight into this layout; see the module docstring).

    Exactly one of {phi_q+phi_k, slopes, neither} selects the bias mode
    (factored / in-kernel ALiBi / none). Differentiable in q, k, v, phi_*.

    ``lengths`` (B,) int32 opts into the RAGGED BATCH path: row b attends
    only to keys at positions < lengths[b] (the serve engine's padded wave
    of variable-length requests). Rows with length 0 output zeros.
    Differentiable via the XLA path; the Pallas ragged kernel is
    forward-only (inference — the only way the serve engine calls it).
    """
    assert layout in ("bshd", "bhsd"), layout
    scale = (1.0 / float(np.sqrt(q.shape[-1]))) if scale is None else scale
    assert not (phi_q is not None and slopes is not None)
    if lengths is not None:
        return _flash_attention_ragged(q, k, v, phi_q, phi_k, slopes,
                                       jnp.asarray(lengths, jnp.int32),
                                       mask_kind, window, scale,
                                       resolve_impl(impl), block_q, block_k,
                                       layout)
    return _flash_attention_core(q, k, v, phi_q, phi_k, slopes, mask_kind,
                                 window, scale, resolve_impl(impl),
                                 block_q, block_k, layout)


# ---------------------------------------------------------------------------
# Decode (one token, KV cache) — inference only, no vjp needed
# ---------------------------------------------------------------------------

def _static_page_cap(lengths, ps: int, p_slot: int,
                     max_pages: Optional[int]) -> int:
    """Static bound on the pages any request can reference this step.

    Preference order: an explicit ``max_pages`` (the serve engine derives
    one from its host-side length mirror), else ``ceil(max(lengths)/ps)``
    when ``lengths`` is concrete (eager callers/tests), else the full
    page-table width (nothing static is known under tracing)."""
    if max_pages is not None:
        return max(1, min(int(max_pages), p_slot))
    try:
        longest = int(jax.device_get(jnp.max(lengths)))
    except jax.errors.ConcretizationTypeError:
        return p_slot
    return max(1, min(-(-longest // ps), p_slot))


def _xla_decode_head_major(q, k_cache, v_cache, lengths, phi_q, phi_k,
                           slopes, scale):
    """XLA decode over kernel-layout caches — head-major einsums, no
    transpose or per-head factor materialization of anything pool-sized.

    q (B,1,H,D); k/v (B,KVH,S,E); phi_k (B,KVH,S,R) or kv-head-shared
    (B,S,R); slopes (H,). Factor ranks align by slicing the wider operand
    (stored factor slabs are zero-padded to the lane boundary, so slicing
    them back is exact)."""
    b, _, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kvh
    qg = q[:, 0].reshape(b, kvh, g, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    if kf.shape[-1] > d:                      # lane-padded pool vs raw q
        kf = kf[..., :d]
    s = jnp.einsum("bkgd,bksd->bkgs", qg, kf) * scale
    if phi_q is not None:
        r = min(phi_q.shape[-1], phi_k.shape[-1])
        pq = phi_q[:, 0].reshape(b, kvh, g, -1)[..., :r].astype(jnp.float32)
        pk = phi_k[..., :r].astype(jnp.float32)
        if pk.ndim == 3:                      # (B, S, R) kv-head-shared
            s = s + jnp.einsum("bkgr,bsr->bkgs", pq, pk)
        else:                                 # (B, KVH, S, R)
            s = s + jnp.einsum("bkgr,bksr->bkgs", pq, pk)
    k_pos = jnp.arange(s_len)
    if slopes is not None:
        rel = (k_pos[None] - (lengths - 1)[:, None]).astype(jnp.float32)
        s = s + slopes.reshape(kvh, g)[None, :, :, None] * rel[:, None, None]
    valid = k_pos[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, DEFAULT_MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bkse->bkge", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, dv).astype(q.dtype)


def flash_decode(
    q: jax.Array,                        # (B, 1, H, D)
    k_cache: jax.Array,                  # see kv_layout below
    v_cache: jax.Array,
    lengths: jax.Array,                  # (B,) int32
    phi_q: Optional[jax.Array] = None,   # (B, 1, H, R)
    phi_k: Optional[jax.Array] = None,
    slopes: Optional[jax.Array] = None,  # (H,)
    *,
    scale: Optional[float] = None,
    impl: str = "auto",
    block_k: int = 512,
    page_table: Optional[jax.Array] = None,  # (B, P_slot) int32 -> paged mode
    kv_layout: str = "bshd",
    max_pages: Optional[int] = None,
) -> jax.Array:
    """Single-token decode against a KV cache. Returns (B, 1, H, Dv).

    ``kv_layout`` selects the cache layout (module docstring has the full
    contract):

    - ``"bshd"`` (canonical, the parity/legacy reference): ``k_cache`` /
      ``v_cache`` are ``(B, S, KVH, *)``; paged pools ``(n_pages, ps, KVH,
      *)``; ``phi_k`` ``(B, S, KVH|H|1, R)`` or the paged slab
      ``(n_pages, ps, R)`` / ``(n_pages, ps, KVH, R)``. Adapted to the
      kernels per call (the cost the kernel layout deletes).
    - ``"bhsd"`` (kernel-native, what the models store): ``(B, KVH, S, *)``;
      paged pools ``(KVH, n_pages, ps, *)`` handed to the Pallas kernel
      zero-copy; ``phi_k`` ``(B, KVH, S, R)`` or the layer/kv-head-shared
      paged slab ``(n_pages, ps, r_pad)`` (kv-head broadcast happens in the
      kernel block index maps).

    With ``page_table`` the caches are a shared PAGE POOL; ``page_table[b,
    j]`` maps request b's j-th logical block to its physical page; entries
    beyond the mapped prefix are ignored (clamped + length-masked). The
    XLA fallback gathers each request's logical view first, capped at
    ``ceil(max(lengths)/page_size)`` pages (see ``max_pages``) instead of
    the full table width.
    """
    assert kv_layout in ("bshd", "bhsd"), kv_layout
    if page_table is not None:
        return _flash_decode_paged(q, k_cache, v_cache, lengths, page_table,
                                   phi_q, phi_k, slopes, scale=scale,
                                   impl=impl, block_k=block_k,
                                   kv_layout=kv_layout, max_pages=max_pages)
    b, _, h, d = q.shape
    if kv_layout == "bhsd":
        kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    else:
        s_len, kvh = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    impl = resolve_impl(impl)

    if impl == "io_stub":
        # deployment IO of the decode kernel: read cache + q once, write o
        dep = (jnp.sum(k_cache.astype(jnp.float32))
               + jnp.sum(v_cache.astype(jnp.float32)))
        if phi_k is not None:
            dep = dep + jnp.sum(phi_k.astype(jnp.float32))
        eps = jnp.asarray(1e-30, jnp.float32)
        o = q[..., :1].astype(jnp.float32) * eps + dep * eps
        return jnp.broadcast_to(o, (b, 1, h, dv)).astype(q.dtype)

    if kv_layout == "bhsd":
        if impl == "xla":
            return _xla_decode_head_major(q, k_cache, v_cache, lengths,
                                          phi_q, phi_k, slopes, scale)
        return _pallas_decode_hm(q, k_cache, v_cache, lengths, phi_q, phi_k,
                                 slopes, scale, block_k,
                                 interpret=(impl == "pallas_interpret"))

    if impl == "xla":
        phi_k_x = phi_k
        if phi_k_x is not None and phi_k_x.shape[2] not in (1, h):
            # per-kv-head factors: expand over each kv head's query group
            assert h % phi_k_x.shape[2] == 0, (phi_k_x.shape, h)
            phi_k_x = jnp.repeat(phi_k_x, h // phi_k_x.shape[2], axis=2)
        if phi_k_x is not None and phi_k_x.shape[2] == 1:
            phi_k_x = jnp.broadcast_to(phi_k_x, (b, s_len, h, phi_k_x.shape[-1]))
        if slopes is not None:
            # ALiBi factors for the decode row: q at position lengths-1.
            qpos = (lengths.astype(jnp.float32) - 1.0)[:, None, None, None]
            pq = jnp.concatenate([-jnp.broadcast_to(qpos, (b, 1, h, 1)),
                                  jnp.ones((b, 1, h, 1), jnp.float32)], -1)
            pq = pq * slopes.reshape(1, 1, h, 1)
            kj = jnp.arange(s_len, dtype=jnp.float32)
            pk = jnp.stack([jnp.ones_like(kj), kj], -1)[None, :, None, :]
            phi_k_x = jnp.broadcast_to(pk, (b, s_len, h, 2))
            phi_q = pq
        return attn_mod.attention(
            q, k_cache, v_cache, mask=MaskSpec("none"), scale=scale,
            phi_q=phi_q, phi_k=phi_k_x, kv_length=lengths,
            impl="chunked", chunk_size=min(block_k, s_len))

    # Pallas path, canonical layout: adapt to head-major grouped layout
    # with padded tiles — this per-call transpose is what kernel-layout
    # caches (kv_layout="bhsd") avoid.
    g = h // kvh
    block_k = min(block_k, s_len)
    s_p = _ceil_to(s_len, block_k)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)
    g_p = _ceil_to(g, 8)

    def to_grouped_q(x, last_p):
        # (B, 1, H, E) -> (B, KVH, G, E) padded
        x = x[:, 0].reshape(b, kvh, g, x.shape[-1])
        x = _pad_axis(_pad_axis(x, 2, g_p), 3, last_p)
        return x

    def to_cache(x, last_p):
        # (B, S, KVH, E) -> (B, KVH, S_p, E)
        x = _pad_axis(_pad_axis(x.transpose(0, 2, 1, 3), 2, s_p), 3, last_p)
        return x

    qt = to_grouped_q(q, d_p)
    kt = to_cache(k_cache, d_p)
    vt = to_cache(v_cache, dv_p)
    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        r_p = _ceil_to(r, _LANE)
        # The grouped-key layout carries ONE key factor per kv head:
        # per-kv-head (B, S, KVH, R) rides as-is, head-shared broadcasts.
        # PER-Q-HEAD factors (B, S, H, R) can differ within a GQA group,
        # which the grouped layout cannot express — route to the XLA path
        # (the old code silently took each group's first head: ISSUE 3).
        kvh_pk = phi_k.shape[2]
        if kvh_pk not in (kvh, 1):
            assert kvh_pk == h, (phi_k.shape, h, kvh)
            return flash_decode(q, k_cache, v_cache, lengths, phi_q, phi_k,
                                slopes, scale=scale, impl="xla",
                                block_k=block_k)
        pqt = to_grouped_q(phi_q, r_p)
        if kvh_pk == kvh:
            pk_kv = phi_k
        else:
            pk_kv = jnp.broadcast_to(phi_k, (b, s_len, kvh, r))
        pkt = to_cache(pk_kv, r_p)
    slopes_g = None
    if slopes is not None:
        slopes_g = _pad_axis(slopes.reshape(kvh, g), 1, g_p)

    out = _fd.flash_decode_fwd(
        qt, kt, vt, lengths, pqt, pkt, slopes_g, scale=scale,
        block_k=block_k, interpret=(impl == "pallas_interpret"))
    out = out[:, :, :g, :dv].reshape(b, 1, h, dv)
    return out


def _pallas_decode_hm(q, k_cache, v_cache, lengths, phi_q, phi_k, slopes,
                      scale, block_k, interpret):
    """Kernel-layout contiguous Pallas decode: the cache IS the kernel
    layout — q-side reshapes/pads are token-sized, and under the layout
    contract (lane-aligned hd, block-divisible S) the cache tensors pass
    through untouched. Off-contract shapes fall back to a correctness pad
    (tiny test caches; never the serve engine)."""
    b, _, h, d = q.shape
    kvh, s_len = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // kvh
    g_p = _ceil_to(g, 8)
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)

    bk = _pick_block(s_len, min(block_k, s_len))
    if bk == 0:
        # off-contract S (no sublane-aligned divisor): pad the seq axis
        # once to an aligned block — correctness fallback, never the
        # serve engine (its caches satisfy the layout contract)
        bk = _ceil_to(min(block_k, s_len), 8)
        s_p = _ceil_to(s_len, bk)
        k_cache = _pad_axis(k_cache, 2, s_p)
        v_cache = _pad_axis(v_cache, 2, s_p)
        if phi_k is not None:
            phi_k = _pad_axis(phi_k, 2, s_p)
    k_cache = _pad_axis(k_cache, 3, d_p)       # no-op on lane-aligned caches
    v_cache = _pad_axis(v_cache, 3, dv_p)

    def to_grouped_q(x, last_p):
        x = x[:, 0].reshape(b, kvh, g, x.shape[-1])
        return _pad_axis(_pad_axis(x, 2, g_p), 3, last_p)

    qt = to_grouped_q(_pad_axis(q, 3, d_p), d_p)
    pqt = pkt = None
    if phi_q is not None:
        r_p = _ceil_to(max(phi_q.shape[-1], phi_k.shape[-1]), _LANE)
        pqt = to_grouped_q(_pad_axis(phi_q, 3, r_p), r_p)
        pkt = _pad_axis(phi_k, 3, r_p)         # no-op on padded factor caches
    slopes_g = None
    if slopes is not None:
        slopes_g = _pad_axis(slopes.reshape(kvh, g), 1, g_p)

    out = _fd.flash_decode_fwd(
        qt, k_cache, v_cache, lengths, pqt, pkt, slopes_g, scale=scale,
        block_k=bk, interpret=interpret)
    return out[:, :, :g, :dv].reshape(b, 1, h, dv)


def _flash_decode_paged(q, k_pages, v_pages, lengths, page_table,
                        phi_q, phi_k, slopes, *, scale, impl, block_k,
                        kv_layout="bshd", max_pages=None):
    """Paged dispatch for ``flash_decode`` (see its docstring for layouts)."""
    b, _, h, d = q.shape
    if kv_layout == "bhsd":
        kvh, n_pages, ps = k_pages.shape[:3]
    else:
        n_pages, ps, kvh = k_pages.shape[:3]
    dv = v_pages.shape[-1]
    p_slot = page_table.shape[1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    impl = resolve_impl(impl)
    p_cap = _static_page_cap(lengths, ps, p_slot, max_pages)
    pt = jnp.clip(page_table.astype(jnp.int32), 0, n_pages - 1)[:, :p_cap]

    if kv_layout == "bhsd" and impl in ("xla", "io_stub"):
        # logical views of the pool, gathered page-granular and capped at
        # p_cap pages — Θ(longest request), not Θ(table width). Everything
        # pool-sized stays in the gather's native (KVH, B, S, E) axis
        # order end to end; only token-sized tensors (q, the output)
        # transpose, so XLA never copies the view.
        def view(pool):                           # -> (KVH, B, S_view, E)
            return pool[:, pt].reshape(kvh, b, p_cap * ps, pool.shape[-1])
        gk, gv = view(k_pages[..., :d]), view(v_pages[..., :dv])
        if impl == "io_stub":
            dep = gk.astype(jnp.float32).sum() + gv.astype(jnp.float32).sum()
            if phi_k is not None:
                # page axis: 0 on the shared 3-dim slab, 1 on the
                # per-kv-head (KVH, n_pages, ps, R) form
                gphi = phi_k[pt] if phi_k.ndim == 3 else phi_k[:, pt]
                dep = dep + jnp.sum(gphi.astype(jnp.float32))
            eps = jnp.asarray(1e-30, jnp.float32)
            o = q[..., :1].astype(jnp.float32) * eps + dep * eps
            return jnp.broadcast_to(o, (b, 1, h, dv)).astype(q.dtype)
        g = h // kvh
        qg = (q[:, 0].reshape(b, kvh, g, d).transpose(1, 0, 2, 3)
              .astype(jnp.float32))               # (KVH, B, G, D): tiny
        s = jnp.einsum("kbgd,kbsd->kbgs", qg,
                       gk.astype(jnp.float32)) * scale
        if phi_q is not None:
            if phi_k.ndim == 3:                   # (n_pages, ps, r_pad) slab
                gphi = phi_k[pt].reshape(b, p_cap * ps, phi_k.shape[-1])
                r = min(phi_q.shape[-1], gphi.shape[-1])
                pq = (phi_q[:, 0].reshape(b, kvh, g, -1)[..., :r]
                      .transpose(1, 0, 2, 3))
                s = s + jnp.einsum("kbgr,bsr->kbgs",
                                   pq.astype(jnp.float32),
                                   gphi[..., :r].astype(jnp.float32))
            else:                                 # (KVH, n_pages, ps, R)
                gphi = phi_k[:, pt].reshape(kvh, b, p_cap * ps,
                                            phi_k.shape[-1])
                r = min(phi_q.shape[-1], gphi.shape[-1])
                pq = (phi_q[:, 0].reshape(b, kvh, g, -1)[..., :r]
                      .transpose(1, 0, 2, 3))
                s = s + jnp.einsum("kbgr,kbsr->kbgs",
                                   pq.astype(jnp.float32),
                                   gphi[..., :r].astype(jnp.float32))
        k_pos = jnp.arange(p_cap * ps)
        if slopes is not None:
            rel = (k_pos[None] - (lengths - 1)[:, None]).astype(jnp.float32)
            s = s + slopes.reshape(kvh, g)[:, None, :, None] \
                * rel[None, :, None]
        valid = k_pos[None] < lengths[:, None]
        s = jnp.where(valid[None, :, None], s, DEFAULT_MASK_VALUE)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("kbgs,kbse->kbge", p, gv.astype(jnp.float32))
        return (o.transpose(1, 0, 2, 3).reshape(b, 1, h, dv)
                .astype(q.dtype))

    if impl in ("xla", "io_stub"):               # canonical pools
        # gather each request's pages into its logical contiguous view and
        # reuse the contiguous path (masking past ``lengths`` is identical)
        def view(pool):
            gth = pool[pt]                        # (B, P_cap, ps, KVH, E)
            return gth.reshape(b, p_cap * ps, *pool.shape[2:])
        phi_view = None
        if phi_k is not None:
            slab = phi_k if phi_k.ndim == 4 else phi_k[:, :, None, :]
            phi_view = view(slab)                 # (B, S_view, KVH|1, R)
        return flash_decode(q, view(k_pages), view(v_pages), lengths,
                            phi_q, phi_view, slopes, scale=scale, impl=impl,
                            block_k=block_k)

    # Pallas path: kv-head-major pools, pages resolved in the kernel's
    # scalar-prefetch block index maps (no gather, no view materialization).
    # Kernel layout hands the pools (and the shared phi slab) over as-is;
    # canonical pools adapt per call (transpose + lane pad + kv-head
    # broadcast — the legacy cost).
    g = h // kvh
    d_p, dv_p = _ceil_to(d, _LANE), _ceil_to(dv, _LANE)
    g_p = _ceil_to(g, 8)

    def to_grouped_q(x, last_p):
        x = x[:, 0].reshape(b, kvh, g, x.shape[-1])
        return _pad_axis(_pad_axis(x, 2, g_p), 3, last_p)

    if kv_layout == "bhsd":
        kt = _pad_axis(k_pages, 3, d_p)          # no-op: pools lane-padded
        vt = _pad_axis(v_pages, 3, dv_p)
    else:
        def to_pool(x, last_p):
            # (n_pages, ps, KVH, E) -> (KVH, n_pages, ps, E_pad)
            return _pad_axis(x.transpose(2, 0, 1, 3), 3, last_p)
        kt = to_pool(k_pages, d_p)
        vt = to_pool(v_pages, dv_p)

    qt = to_grouped_q(q, d_p)
    pqt = pkt = None
    if phi_q is not None:
        r = phi_q.shape[-1]
        assert phi_q.shape[2] in (h, kvh), (phi_q.shape, h, kvh)
        if phi_q.shape[2] == kvh and kvh != h:    # shared within each group
            phi_q = jnp.repeat(phi_q, g, axis=2)
        if kv_layout == "bhsd":
            if phi_k.ndim == 3:                   # layer/kv-head-shared slab
                pkt = phi_k[None]                 # (1, n_pages, ps, r_pad)
            else:
                pkt = phi_k                       # (KVH, n_pages, ps, r_pad)
            r_p = _ceil_to(max(r, pkt.shape[-1]), _LANE)
            pkt = _pad_axis(pkt, 3, r_p)          # no-op on padded slabs
        else:
            slab = phi_k if phi_k.ndim == 4 else phi_k[:, :, None, :]
            assert slab.shape[2] in (kvh, 1), (phi_k.shape, kvh)
            r_p = _ceil_to(max(r, slab.shape[-1]), _LANE)
            # canonical slab: (n_pages, ps, KVH|1, R) -> kv-head-major; the
            # kv-head-shared case stays a single copy (broadcast happens in
            # the kernel's block index maps, not here)
            pkt = _pad_axis(slab.transpose(2, 0, 1, 3), 3, r_p)
        pqt = to_grouped_q(_pad_axis(phi_q, 3, r_p), r_p)
    slopes_g = None
    if slopes is not None:
        slopes_g = _pad_axis(slopes.reshape(kvh, g), 1, g_p)

    out = _fd.flash_decode_paged_fwd(
        qt, kt, vt, lengths, pt, pqt, pkt, slopes_g, scale=scale,
        interpret=(impl == "pallas_interpret"))
    return out[:, :, :g, :dv].reshape(b, 1, h, dv)


# ---------------------------------------------------------------------------
# Chunked prefill (C queries, offset causal mask, KV cache) — inference only
# ---------------------------------------------------------------------------

def flash_chunk_attention(
    q: jax.Array,                        # (B, C, H, D) chunk queries
    k_cache: jax.Array,                  # cache/pool, see kv_layout
    v_cache: jax.Array,
    offsets: jax.Array,                  # (B,) int32: abs position of q[:, 0]
    chunk_lens: jax.Array,               # (B,) int32: valid queries (0=frozen)
    slopes: Optional[jax.Array] = None,  # (H,) ALiBi
    *,
    scale: Optional[float] = None,
    impl: str = "auto",
    kv_layout: str = "bshd",
    page_table: Optional[jax.Array] = None,
    max_pages: Optional[int] = None,
) -> jax.Array:
    """Offset-masked chunk attention for chunked prefill.

    A fixed-size chunk of C queries per slot attends against the slot's KV
    cache — the chunk's own keys must already be scattered into the cache
    (write-then-attend), so the mask is purely positional: query i of row b
    sits at absolute position ``q_pos = offsets[b] + i`` and sees exactly the
    keys at positions ``<= q_pos`` (the offset causal mask; everything past
    the row's written prefix is masked by causality). Rows with
    ``chunk_lens[b] == 0`` are frozen lanes riding in the fixed slot batch —
    their output is unused by construction (the model gathers logits only at
    valid positions and freezes cache state elsewhere).

    ALiBi enters as ``slopes * (k_pos - q_pos)`` from absolute positions —
    the rank-2 factored form specialized in-place, matching
    ``core.bias.alibi_factors(q_offset=...)`` exactly.

    Layouts mirror ``flash_decode``: contiguous ``kv_layout="bhsd"`` caches
    ``(B, KVH, S, E)`` / canonical ``(B, S, KVH, E)``; with ``page_table``
    the caches are page pools (``(KVH, n_pages, ps, E)`` head-major or
    ``(n_pages, ps, KVH, E)`` canonical) gathered into capped logical views
    (``max_pages`` from the host-side length mirror, like decode).

    Chunk attention is an ADMISSION-path program (runs once per chunk, not
    per token), so every impl routes to the head-major XLA path today — the
    decode hot path keeps its Pallas kernels. Returns (B, C, H, Dv_cache);
    lane-padded caches yield a lane-padded Dv for the caller to slice.
    """
    assert kv_layout in ("bshd", "bhsd"), kv_layout
    b, c, h, d = q.shape
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    resolve_impl(impl)                   # validate; all impls -> XLA here
    offsets = jnp.asarray(offsets, jnp.int32)
    chunk_lens = jnp.asarray(chunk_lens, jnp.int32)

    if page_table is not None:
        if kv_layout == "bhsd":
            kvh, n_pages, ps = k_cache.shape[:3]
        else:
            n_pages, ps, kvh = k_cache.shape[:3]
        p_slot = page_table.shape[1]
        p_cap = _static_page_cap(offsets + chunk_lens, ps, p_slot, max_pages)
        pt = jnp.clip(page_table.astype(jnp.int32), 0, n_pages - 1)[:, :p_cap]
        if kv_layout == "bhsd":
            def view(pool):              # (KVH, B, S_view, E) -> (B, KVH, ...)
                gth = pool[:, pt].reshape(kvh, b, p_cap * ps, pool.shape[-1])
                return gth.transpose(1, 0, 2, 3)
        else:
            def view(pool):
                gth = pool[pt].reshape(b, p_cap * ps, kvh, pool.shape[-1])
                return gth.transpose(0, 2, 1, 3)
        kv, vv = view(k_cache), view(v_cache)
    elif kv_layout == "bhsd":
        kv, vv = k_cache, v_cache        # (B, KVH, S, E) native
    else:
        kv = k_cache.transpose(0, 2, 1, 3)
        vv = v_cache.transpose(0, 2, 1, 3)

    kvh, s_len = kv.shape[1], kv.shape[2]
    dv = vv.shape[-1]
    g = h // kvh
    kf = kv.astype(jnp.float32)
    if kf.shape[-1] > d:                 # lane-padded pool vs raw q
        kf = kf[..., :d]
    qg = (q.reshape(b, c, kvh, g, d).transpose(0, 2, 3, 1, 4)
          .astype(jnp.float32))          # (B, KVH, G, C, D): chunk-sized
    s = jnp.einsum("bkgcd,bksd->bkgcs", qg, kf) * scale
    k_pos = jnp.arange(s_len)
    q_pos = offsets[:, None] + jnp.arange(c)[None, :]          # (B, C)
    if slopes is not None:
        rel = (k_pos[None, None] - q_pos[:, :, None]).astype(jnp.float32)
        s = s + slopes.reshape(kvh, g)[None, :, :, None, None] \
            * rel[:, None, None]
    valid = k_pos[None, None] <= q_pos[:, :, None]             # (B, C, S)
    s = jnp.where(valid[:, None, None], s, DEFAULT_MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgcs,bkse->bkgce", p, vv.astype(jnp.float32))
    return (o.transpose(0, 3, 1, 2, 4).reshape(b, c, h, dv)
            .astype(q.dtype))
