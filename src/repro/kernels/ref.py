"""Pure-jnp oracles for the Pallas kernels.

Everything here is deliberately naive: dense logits, dense softmax, no
chunking, fp32 throughout. The kernels (and the XLA chunked path) are tested
``assert_allclose`` against these across shape/dtype sweeps.

Layout: q (B, N, H, D); k, v (B, M, K, D) with H % K == 0 (GQA).
Factors phi_q (B, N, H, R); phi_k (B, M, H|1, R). Dense bias (B|1, H, N, M).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)

__all__ = ["mha_reference", "decode_reference"]


def _expand_kv(x: jax.Array, h: int) -> jax.Array:
    """(B, M, K, D) -> (B, M, H, D) repeating each kv head over its group."""
    b, m, kvh, d = x.shape
    if kvh == h:
        return x
    assert h % kvh == 0
    return jnp.repeat(x, h // kvh, axis=2)


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bias: Optional[jax.Array] = None,
    phi_q: Optional[jax.Array] = None,
    phi_k: Optional[jax.Array] = None,
    mask_kind: str = "none",
    window: int = 0,
    q_offset: int = 0,
    kv_length: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Dense-softmax oracle for (FlashBias) attention. Returns (B, N, H, Dv)."""
    b, n, h, d = q.shape
    m = k.shape[1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    kf = _expand_kv(k, h).astype(jnp.float32)
    vf = _expand_kv(v, h).astype(jnp.float32)
    s = jnp.einsum("bnhd,bmhd->bhnm", q.astype(jnp.float32), kf) * scale
    if phi_q is not None:
        pk = jnp.broadcast_to(phi_k, (b, m, h, phi_k.shape[-1]))
        s = s + jnp.einsum("bnhr,bmhr->bhnm", phi_q.astype(jnp.float32),
                           pk.astype(jnp.float32))
    if bias is not None:
        bias4 = bias if bias.ndim == 4 else bias[None]
        s = s + bias4.astype(jnp.float32)
    q_pos = jnp.arange(n) + q_offset
    k_pos = jnp.arange(m)
    allowed = jnp.ones((n, m), bool)
    if mask_kind in ("causal", "local"):
        allowed &= q_pos[:, None] >= k_pos[None, :]
    if mask_kind == "local":
        allowed &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_length is not None:
        allowed &= (k_pos < kv_length)[None, :]
    s = jnp.where(allowed[None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhnm,bmhd->bnhd", p, vf)
    return o.astype(q.dtype)


def decode_reference(
    q: jax.Array,            # (B, 1, H, D) — one new token
    k_cache: jax.Array,      # (B, S, K, D)
    v_cache: jax.Array,      # (B, S, K, Dv)
    lengths: jax.Array,      # (B,) int32 — valid cache entries per request
    *,
    phi_q: Optional[jax.Array] = None,   # (B, 1, H, R)
    phi_k: Optional[jax.Array] = None,   # (B, S, H|1, R)
    slopes: Optional[jax.Array] = None,  # (H,) ALiBi slopes (in-kernel bias)
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode oracle. The query sits at position lengths[b]-1."""
    b, _, h, d = q.shape
    s_len = k_cache.shape[1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    kf = _expand_kv(k_cache, h).astype(jnp.float32)
    vf = _expand_kv(v_cache, h).astype(jnp.float32)
    s = jnp.einsum("bhd,bmhd->bhm", q[:, 0].astype(jnp.float32), kf) * scale
    if phi_q is not None:
        pk = jnp.broadcast_to(phi_k, (b, s_len, h, phi_k.shape[-1]))
        s = s + jnp.einsum("bhr,bmhr->bhm", phi_q[:, 0].astype(jnp.float32),
                           pk.astype(jnp.float32))
    k_pos = jnp.arange(s_len)
    if slopes is not None:
        q_pos = (lengths - 1)[:, None]                        # (B, 1)
        rel = (k_pos[None, :] - q_pos).astype(jnp.float32)    # (B, S) <= 0
        s = s + slopes[None, :, None] * rel[:, None, :]
    allowed = k_pos[None, :] < lengths[:, None]               # (B, S)
    s = jnp.where(allowed[:, None, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhm,bmhd->bhd", p, vf)
    return o[:, None].astype(q.dtype)
