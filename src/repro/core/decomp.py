"""SVD and neural decompositions of attention biases (Table 1, rows b & c).

- ``svd_factors``: offline truncated SVD of a *learnable-parameter* bias table
  (SwinV2 relative-position tables, Pangu-Weather). Run once after training;
  the factors then ride with q/k at inference (Sec. 4.3).
- ``NeuralDecomposition``: token-wise factor MLPs ``phi_hat_q, phi_hat_k``
  trained with Eq. (5) ``min || phi_q(x_q) phi_k(x_k)^T - f(x_q, x_k) ||^2``
  for dynamic, data-dependent biases (AlphaFold pair bias, App. G gravity /
  spherical-distance biases). Three linear layers with tanh in between,
  matching App. H Table 12.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowrank

__all__ = ["svd_factors", "NeuralDecompParams", "neural_decomp_init",
           "neural_decomp_apply", "fit_neural_decomposition",
           "reconstruction_error"]


# ---------------------------------------------------------------------------
# SVD decomposition
# ---------------------------------------------------------------------------

def svd_factors(table: jax.Array, rank: Optional[int] = None,
                energy: float = 0.99) -> Tuple[jax.Array, jax.Array]:
    """Truncated-SVD factors of a (possibly per-head) dense bias table.

    table: (N, M) or (H, N, M). Returns (phi_q, phi_k) with shapes
    (..., N, R) and (..., M, R) such that phi_q @ phi_k^T is the best
    rank-R approximation (Eckart–Young). If ``rank`` is None it is chosen
    per ``energy`` (Remark 3.8: R maintaining e.g. 99% of sigma^2 mass),
    taking the max over heads so every slice meets the target.

    Singular values are split evenly (sqrt) between the two factors to keep
    their magnitudes balanced — this matters for bf16 kernels downstream.
    """
    mat = table.astype(jnp.float32)
    if rank is None:
        rank = lowrank.rank_for_energy(mat, energy)
    u, s, vt = jnp.linalg.svd(mat, full_matrices=False)
    r = int(min(rank, s.shape[-1]))
    sq = jnp.sqrt(s[..., :r])
    phi_q = u[..., :, :r] * sq[..., None, :]
    phi_k = jnp.swapaxes(vt[..., :r, :], -1, -2) * sq[..., None, :]
    return phi_q, phi_k


def reconstruction_error(table: jax.Array, phi_q: jax.Array,
                         phi_k: jax.Array) -> float:
    """Relative Frobenius error of the factored reconstruction."""
    approx = phi_q @ jnp.swapaxes(phi_k, -1, -2)
    num = jnp.linalg.norm((approx - table).reshape(-1))
    den = jnp.linalg.norm(table.reshape(-1))
    return float(num / jnp.maximum(den, 1e-30))


# ---------------------------------------------------------------------------
# Neural decomposition (Eq. 5) — token-wise factor MLPs
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NeuralDecompParams:
    """Two 3-layer tanh MLPs: R^{C'} -> R^{H*R} (App. H Table 12)."""
    q_layers: tuple  # tuple of (w, b)
    k_layers: tuple
    heads: int = dataclasses.field(metadata={"static": True}, default=1)
    rank: int = dataclasses.field(metadata={"static": True}, default=8)

    def tree_flatten(self):
        return (self.q_layers, self.k_layers), (self.heads, self.rank)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], heads=aux[0], rank=aux[1])


def _mlp_init(key, dims):
    layers = []
    for din, dout in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (din, dout), jnp.float32) / np.sqrt(din)
        layers.append((w, jnp.zeros((dout,), jnp.float32)))
    return tuple(layers)


def _mlp_apply(layers, x):
    for i, (w, b) in enumerate(layers):
        x = x @ w + b
        if i < len(layers) - 1:
            x = jnp.tanh(x)
    return x


def neural_decomp_init(key, in_dim_q: int, in_dim_k: int, *, hidden: int = 256,
                       heads: int = 1, rank: int = 8) -> NeuralDecompParams:
    kq, kk = jax.random.split(key)
    return NeuralDecompParams(
        q_layers=_mlp_init(kq, (in_dim_q, hidden, hidden, heads * rank)),
        k_layers=_mlp_init(kk, (in_dim_k, hidden, hidden, heads * rank)),
        heads=heads, rank=rank)


def neural_decomp_apply(params: NeuralDecompParams, x_q: jax.Array,
                        x_k: jax.Array):
    """Factor tensors from source features.

    x_q: (..., N, C'_q), x_k: (..., M, C'_k) ->
    phi_q: (..., N, H, R), phi_k: (..., M, H, R).
    """
    def reshape(out):
        return out.reshape(*out.shape[:-1], params.heads, params.rank)
    return (reshape(_mlp_apply(params.q_layers, x_q)),
            reshape(_mlp_apply(params.k_layers, x_k)))


def predicted_bias(params: NeuralDecompParams, x_q, x_k):
    """(..., H, N, M) reconstruction phi_q phi_k^T."""
    pq, pk = neural_decomp_apply(params, x_q, x_k)
    return jnp.einsum("...nhr,...mhr->...hnm", pq, pk)


def fit_neural_decomposition(
    key: jax.Array,
    params: NeuralDecompParams,
    sample_fn: Callable[[jax.Array], Tuple[jax.Array, jax.Array, jax.Array]],
    *,
    steps: int = 1000,
    lr: float = 1e-3,
    lr_decay: float = 0.95,
    lr_decay_every: int = 50,
) -> Tuple[NeuralDecompParams, jax.Array]:
    """Optimize Eq. (5) with Adam on minibatches drawn by ``sample_fn``.

    sample_fn(key) -> (x_q (N, C'), x_k (M, C'), target_bias (H, N, M)).
    Mirrors App. H Table 12's schedule: Adam, lr decayed by ``lr_decay``
    every ``lr_decay_every`` steps. Returns (fitted params, loss history).
    """
    def loss_fn(p, xq, xk, target):
        pred = predicted_bias(p, xq, xk)
        return jnp.mean((pred - target) ** 2)

    b1, b2, eps = 0.9, 0.999, 1e-8
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = (params, zeros, jax.tree.map(jnp.zeros_like, params))

    @jax.jit
    def step(state, key, i):
        p, mu, nu = state
        xq, xk, target = sample_fn(key)
        loss, g = jax.value_and_grad(loss_fn)(p, xq, xk, target)
        cur_lr = lr * (lr_decay ** (i // lr_decay_every))
        mu = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, mu, g)
        nu = jax.tree.map(lambda n, gg: b2 * n + (1 - b2) * gg * gg, nu, g)
        t = i + 1.0
        def upd(pp, m, n):
            mhat = m / (1 - b1 ** t)
            nhat = n / (1 - b2 ** t)
            return pp - cur_lr * mhat / (jnp.sqrt(nhat) + eps)
        p = jax.tree.map(upd, p, mu, nu)
        return (p, mu, nu), loss

    losses = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        state, loss = step(state, sub, jnp.asarray(i, jnp.float32))
        losses.append(loss)
    return state[0], jnp.stack(losses)
