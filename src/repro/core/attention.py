"""Attention with bias: reference, FlashAttention-style chunked, and FlashBias.

The three execution paths implement the paper's comparison matrix:

==================  ==========================  =================================
path                bias handling               corresponds to (paper)
==================  ==========================  =================================
``impl="dense"``    adds a materialized N x M   "standard attention" baseline
``impl="chunked"``  streams dense bias blocks   "FlashAttention with Bias"
  + ``bias=...``    (NM bytes of HBM traffic)
``impl="chunked"``  rank-R factors ride with    **FlashBias** (Eq. 3): bias IO
  + ``phi_*=...``   q/k, two MXU calls/tile     drops from Theta(NM) to
                                                Theta((N+M)R)
==================  ==========================  =================================

The Pallas TPU kernels in ``repro.kernels`` are drop-in replacements for the
chunked path on real hardware; the chunked path here is pure ``jax.lax`` so it
lowers on any backend (and is what the multi-pod dry-run compiles).

Layouts (MaxText convention): q ``(B, N, H, D)``; k, v ``(B, M, K, D)`` with
``H % K == 0`` (GQA); factors ``phi_q (B, N, H, R)``, ``phi_k (B, M, H|1, R)``;
dense bias ``(B|1, H, N, M)``.

Masks are *computed* from positions (iota), never read from memory — the TPU
analogue of the paper's "orthogonal to mask speedup" claim (Sec. 4.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags

DEFAULT_MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)

__all__ = [
    "MaskSpec", "attention", "flashbias_concat_qk",
    "multiplicative_flashbias_attention", "DEFAULT_MASK_VALUE",
]


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """kind: "none" | "causal" | "local" (causal sliding window of ``window``)."""
    kind: str = "none"
    window: int = 0

    def __post_init__(self):
        assert self.kind in ("none", "causal", "local")
        if self.kind == "local":
            assert self.window > 0

    def block_mask(self, q_pos: jax.Array, k_pos: jax.Array) -> Optional[jax.Array]:
        """Boolean allowed-matrix for positions; None means all-allowed.

        q_pos: (..., N) absolute query positions, k_pos: (M,) key positions.
        Returns (..., N, M) bool or None.
        """
        if self.kind == "none":
            return None
        diff = q_pos[..., :, None] - k_pos[..., None, :]  # i - j
        allowed = diff >= 0
        if self.kind == "local":
            allowed &= diff < self.window
        return allowed


def _split_gqa(x: jax.Array, kv_heads: int) -> jax.Array:
    """(B, S, H, E) -> (B, S, K, G, E) grouping q-heads under their kv head."""
    b, s, h, e = x.shape
    if h == kv_heads:
        return x[:, :, :, None, :]
    if h == 1:
        return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv_heads, 1, e))
    assert h % kv_heads == 0, (h, kv_heads)
    return x.reshape(b, s, kv_heads, h // kv_heads, e)


def _normalize_q_offset(q_offset, batch: int):
    q_offset = jnp.asarray(q_offset)
    if q_offset.ndim == 0:
        q_offset = jnp.broadcast_to(q_offset, (batch,))
    return q_offset  # (B,)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mask: MaskSpec = MaskSpec("none"),
    scale: Optional[float] = None,
    bias: Optional[jax.Array] = None,
    phi_q: Optional[jax.Array] = None,
    phi_k: Optional[jax.Array] = None,
    q_offset: Union[int, jax.Array] = 0,
    kv_length: Optional[Union[int, jax.Array]] = None,
    impl: str = "chunked",
    chunk_size: int = 512,
) -> jax.Array:
    """Scaled-dot-product attention with additive bias (dense or factored).

    ``softmax(q k^T * scale + b + mask) v`` with ``b`` either ``bias`` (dense)
    or ``phi_q @ phi_k^T`` (FlashBias factors) or both (low-rank + residual).

    q_offset: absolute position of q[:, 0] (scalar or (B,)) — drives causal/
    local masking for decode steps. kv_length: number of valid cache entries
    (scalar or (B,)); keys at positions >= kv_length are masked out.
    """
    assert impl in ("dense", "chunked")
    b, n, h, d = q.shape
    _, m, kvh, _ = k.shape
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    if phi_q is not None:
        assert phi_k is not None and phi_q.shape[-1] == phi_k.shape[-1]

    if (phi_q is not None
            and phi_k.shape[2] in (1, kvh)
            and jnp.promote_types(jnp.promote_types(phi_q.dtype,
                                                    phi_k.dtype),
                                  q.dtype) == q.dtype):
        # Eq. 3 concat fold: s = [q | phi_q/scale] [k | phi_k]^T * scale —
        # ONE fused matmul of depth D+R replaces the per-block factor
        # matmul + add (measurably faster wherever matmul dispatch or the
        # bias-product temp dominates, e.g. the CPU XLA path). Only taken
        # when it costs no precision: the key factor must live per kv head
        # (GQA identity) and concatenation must not downcast the factors
        # (mixed-precision ALiBi keeps f32 factors against a bf16 q, where
        # folding would quantize positions to bf16 — that path keeps the
        # two-matmul form).
        q, k = flashbias_concat_qk(q, k, phi_q, phi_k, scale)
        phi_q = phi_k = None

    if impl == "dense" or m <= chunk_size:
        return _attention_dense(q, k, v, mask=mask, scale=scale, bias=bias,
                                phi_q=phi_q, phi_k=phi_k, q_offset=q_offset,
                                kv_length=kv_length)
    return _attention_chunked(q, k, v, mask=mask, scale=scale, bias=bias,
                              phi_q=phi_q, phi_k=phi_k, q_offset=q_offset,
                              kv_length=kv_length, chunk_size=chunk_size)


def _logits_block(q5, k_blk, phi_q5, phi_k_blk, scale, mask, q_pos, k_pos,
                  bias_blk, kv_length):
    """Pre-softmax logits for one kv block, fp32.

    q5: (B, N, K, G, D); k_blk: (B, Mc, K, D); phi_q5: (B, N, K, G, R);
    phi_k_blk: (B, Mc, K|1... broadcast to (B, Mc, K, G, R)); returns
    (B, K, G, N, Mc).
    """
    s = jnp.einsum("bnkgd,bmkd->bkgnm", q5, k_blk,
                   preferred_element_type=jnp.float32) * scale
    if phi_q5 is not None:
        s_bias = jnp.einsum("bnkgr,bmkgr->bkgnm", phi_q5, phi_k_blk,
                            preferred_element_type=jnp.float32)
        s = s + s_bias
    if bias_blk is not None:
        # bias_blk: (B|1, H, N, Mc) -> (B|1, K, G, N, Mc)
        bb, hh, nn, mm = bias_blk.shape
        k_, g_ = q5.shape[2], q5.shape[3]
        s = s + bias_blk.reshape(bb, k_, g_, nn, mm).astype(jnp.float32)
    allowed = mask.block_mask(q_pos, k_pos)  # (B, N, Mc) or None
    if kv_length is not None:
        in_range = k_pos[None, :] < jnp.asarray(kv_length).reshape(-1, 1)  # (B, Mc)
        in_range = jnp.broadcast_to(in_range[:, None, :], (s.shape[0], q_pos.shape[-1], k_pos.shape[0]))
        allowed = in_range if allowed is None else (allowed & in_range)
    if allowed is not None:
        s = jnp.where(allowed[:, None, None, :, :], s, DEFAULT_MASK_VALUE)
    return s


def _attention_dense(q, k, v, *, mask, scale, bias, phi_q, phi_k, q_offset,
                     kv_length):
    b, n, h, d = q.shape
    _, m, kvh, _ = k.shape
    q5 = _split_gqa(q, kvh)
    phi_q5 = phi_k5 = None
    if phi_q is not None:
        phi_q5 = _split_gqa(phi_q, kvh)
        phi_k5 = _split_gqa(jnp.broadcast_to(
            phi_k, (b, m, h, phi_k.shape[-1])), kvh)
    q_pos = jnp.arange(n)[None, :] + _normalize_q_offset(q_offset, b)[:, None]
    k_pos = jnp.arange(m)
    bias4 = None
    if bias is not None:
        bias4 = bias if bias.ndim == 4 else bias[None]
    s = _logits_block(q5, k, phi_q5, phi_k5, scale, mask, q_pos, k_pos,
                      bias4, kv_length)                      # (B,K,G,N,M)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgnm,bmkd->bnkgd", p.astype(v.dtype), v)
    return o.reshape(b, n, h, v.shape[-1])


def _attention_chunked(q, k, v, *, mask, scale, bias, phi_q, phi_k, q_offset,
                       kv_length, chunk_size):
    """Online-softmax scan over KV chunks; never materializes (N, M)."""
    b, n, h, d = q.shape
    _, m, kvh, _ = k.shape
    dv = v.shape[-1]
    r = 0 if phi_q is None else phi_q.shape[-1]
    num_chunks = -(-m // chunk_size)
    m_pad = num_chunks * chunk_size
    pad = m_pad - m

    def pad_kv(x):
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else x

    k_p, v_p = pad_kv(k), pad_kv(v)
    # Padded keys must be masked: clamp kv_length to the true m.
    kv_length = m if (kv_length is None and pad) else kv_length

    q5 = _split_gqa(q, kvh)                                  # (B,N,K,G,D)
    g = q5.shape[3]
    phi_q5 = None
    if phi_q is not None:
        phi_q5 = _split_gqa(phi_q, kvh)
        phi_k_b = pad_kv(jnp.broadcast_to(phi_k, (b, m, h, r)))
        phi_k_c = phi_k_b.reshape(b, num_chunks, chunk_size, kvh, g, r)
    k_c = k_p.reshape(b, num_chunks, chunk_size, kvh, k.shape[-1])
    v_c = v_p.reshape(b, num_chunks, chunk_size, kvh, dv)
    bias_c = None
    if bias is not None:
        bias4 = bias if bias.ndim == 4 else bias[None]
        bias4 = jnp.pad(bias4, ((0, 0), (0, 0), (0, 0), (0, pad))) if pad else bias4
        bias_c = bias4.reshape(bias4.shape[0], h, n, num_chunks, chunk_size)

    q_pos = jnp.arange(n)[None, :] + _normalize_q_offset(q_offset, b)[:, None]

    def body(carry, idx):
        m_i, l_i, acc = carry
        k_blk = jax.lax.dynamic_index_in_dim(k_c, idx, 1, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(v_c, idx, 1, keepdims=False)
        phi_k_blk = (jax.lax.dynamic_index_in_dim(phi_k_c, idx, 1, keepdims=False)
                     if phi_q5 is not None else None)
        bias_blk = (jax.lax.dynamic_index_in_dim(bias_c, idx, 3, keepdims=False)
                    if bias_c is not None else None)
        k_pos = idx * chunk_size + jnp.arange(chunk_size)
        s = _logits_block(q5, k_blk, phi_q5, phi_k_blk, scale, mask, q_pos,
                          k_pos, bias_blk, kv_length)        # (B,K,G,N,Mc)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        corr = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgnm,bmkd->bkgnd", p.astype(v.dtype), v_blk)
        acc = acc * corr[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, n), -jnp.inf, dtype=jnp.float32)
    # Start from chunk 0 computed eagerly so the -inf init never meets exp():
    # exp(-inf - m_new) with finite m_new is exactly 0, which is safe, but an
    # all-masked first chunk would yield m_new = MASK_VALUE (finite) and the
    # math stays well-defined.
    l0 = jnp.zeros((b, kvh, g, n), dtype=jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, n, dv), dtype=jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                      jnp.arange(num_chunks),
                                      unroll=flags.scan_unroll(num_chunks))
    l_safe = jnp.where(l_f == 0, 1.0, l_f)
    o = acc / l_safe[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, n, h, dv)      # (B,N,K,G,D)->(B,N,H,D)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Eq. 3 literal form — concat channels (used to verify the paper's identity)
# ---------------------------------------------------------------------------

def flashbias_concat_qk(q, k, phi_q, phi_k, scale: Optional[float] = None):
    """Return (q', k') per Eq. 3: softmax(q'k'^T * scale) == softmax(qk^T*scale + b).

    q' = [q | phi_q / scale], k' = [k | phi_k]. The factors are folded so the
    *single* scale multiplies both terms correctly.

    GQA note: k carries ``Hk <= H`` kv heads. The concat identity requires the
    key-side factor to live per *kv* head, so ``phi_k``'s head dim must be 1 or
    Hk (head-shared biases like ALiBi/sqdist satisfy this trivially; a per-q-
    head key factor cannot ride on grouped keys without expanding them).
    """
    b, n, h, d = q.shape
    hk = k.shape[2]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    assert phi_k.shape[2] in (1, hk), (
        f"phi_k head dim {phi_k.shape[2]} incompatible with {hk} kv heads")
    phi_k = jnp.broadcast_to(phi_k, (b, k.shape[1], hk, phi_k.shape[-1]))
    q_aug = jnp.concatenate([q, (phi_q / scale).astype(q.dtype)], axis=-1)
    k_aug = jnp.concatenate([k, phi_k.astype(k.dtype)], axis=-1)
    return q_aug, k_aug


# ---------------------------------------------------------------------------
# App. I — multiplicative bias via channel expansion (Eq. 17)
# ---------------------------------------------------------------------------

def multiplicative_flashbias_attention(q, k, v, phi_q, phi_k, *,
                                       mask: MaskSpec = MaskSpec("none"),
                                       scale: Optional[float] = None):
    """softmax((q k^T * scale) ⊙ b) v with b = phi_q @ phi_k^T, rank R.

    Eq. 17: q' = [q ⊙ phi_q_1, ..., q ⊙ phi_q_R] (channel expansion to C*R),
    likewise k'; then q' k'^T = (q k^T) ⊙ (phi_q phi_k^T). Worthwhile iff
    R <= sqrt(S/C^2 + 1) (Cor. I.2).
    """
    b, n, h, d = q.shape
    m = k.shape[1]
    scale = (1.0 / float(np.sqrt(d))) if scale is None else scale
    r = phi_q.shape[-1]
    phi_q = jnp.broadcast_to(phi_q, (b, n, h, r))
    phi_k = jnp.broadcast_to(phi_k, (b, m, h, r))
    # (B,S,H,D) ⊙ (B,S,H,R) -> (B,S,H,R*D)
    q_exp = (q[..., None, :] * phi_q[..., :, None]).reshape(b, n, h, r * d)
    k_exp = (k[..., None, :] * phi_k[..., :, None]).reshape(b, m, h, r * d)
    return attention(q_exp, k_exp, v, mask=mask, scale=scale, impl="dense")
