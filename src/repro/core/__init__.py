"""FlashBias core: the paper's contribution as composable JAX modules.

- ``bias``: bias taxonomy + exact low-rank factorizations (ALiBi, spatial
  distance, multiplicative cos) — Table 1 row (a).
- ``decomp``: SVD factors for learnable tables and neural token-wise factor
  MLPs (Eq. 5) — Table 1 rows (b), (c).
- ``attention``: dense / chunked(flash-style) / FlashBias execution paths
  (Eq. 3), masks computed from iota, GQA, multiplicative extension (App. I).
- ``lowrank``: singular-energy tooling + the paper's HBM IO model
  (Thms 3.1/3.2, Cors 3.3/3.7).

NOTE: submodules are imported *as modules* here; the ``attention`` callable
lives at ``repro.core.attention.attention`` (and is re-exported as
``attention_fn``) to avoid shadowing the submodule name.
"""
from repro.core import attention, bias, decomp, lowrank  # noqa: F401 (modules)
from repro.core.attention import (
    MaskSpec,
    flashbias_concat_qk,
    multiplicative_flashbias_attention,
)
from repro.core.attention import attention as attention_fn
from repro.core.bias import BiasSpec, alibi_dense, alibi_factors, alibi_slopes
from repro.core.lowrank import IOModel, energy_profile, rank_for_energy

__all__ = [
    "attention", "bias", "decomp", "lowrank",
    "MaskSpec", "attention_fn", "flashbias_concat_qk",
    "multiplicative_flashbias_attention", "BiasSpec", "alibi_factors",
    "alibi_dense", "alibi_slopes", "IOModel", "energy_profile",
    "rank_for_energy",
]
