"""Attention-bias taxonomy and the paper's exact low-rank factorizations.

FlashBias (Sec. 3.2) replaces a dense bias ``b = f(x_q, x_k) in R^{N x M}``
with two factor tensors ``phi_q in R^{N x R}``, ``phi_k in R^{M x R}`` such
that ``b = phi_q @ phi_k.T``. Attention with bias then becomes standard
attention over ``C + R`` channels (Eq. 3) and the quadratic bias is never
materialized in HBM.

This module implements the paper's *exact* decompositions (Table 1 row (a)):

- ALiBi (Example 3.4): ``f(i, j) = slope_h * (j - i)``, rank 2.
- Squared spatial distance (Example 3.5): ``f(x_i, x_j) = ||x_i - x_j||^2``,
  rank 3d for d-dimensional coordinates (the paper writes the 3D case, R=9).
- Learnable-scaled distance (Sec. 4.4 PDE solver):
  ``f(x_i, x_j) = alpha_i * ||x_i - x_j||^2`` — the per-query scale folds into
  phi_q, so the rank is unchanged.
- Multiplicative ``cos(i - j)`` (App. I Example I.1), rank 2.

Conventions
-----------
Factor tensors are returned with explicit head dims where the bias is
per-head: ``phi_q: (H, N, R)``. Helpers below broadcast them to the
``(B, N, H, R)`` layout the attention paths consume. All factorizations are
closed-form, differentiable, and O((N+M)R) storage (Thm 3.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "BiasSpec",
    "alibi_slopes",
    "alibi_factors",
    "alibi_dense",
    "sqdist_factors",
    "sqdist_dense",
    "scaled_sqdist_factors",
    "scaled_sqdist_dense",
    "cos_relpos_factors",
    "cos_relpos_dense",
    "broadcast_factors",
]


@dataclasses.dataclass(frozen=True)
class BiasSpec:
    """Declarative description of an attention bias, resolved by the model.

    kind:
      - "none":       no bias.
      - "alibi":      exact factorization, R=2 (per-head slopes).
      - "sqdist":     exact factorization of squared spatial distance, R=3d.
      - "svd":        factors produced offline from a learnable table
                      (core.decomp.svd_factors); rank = ``rank``.
      - "neural":     token-wise factor MLPs (core.decomp.NeuralDecomposition).
      - "dense":      materialize the full N x M bias (paper's baseline).
    mode:
      - "flashbias":  consume factors via Eq. 3 (never materialize N x M).
      - "dense":      materialize f(x_q, x_k) and add to logits (baseline).
    """

    kind: str = "none"
    mode: str = "flashbias"
    rank: int = 0
    coord_dim: int = 3      # for sqdist
    negate: bool = True     # biases are usually penalties: b = -f(...)

    def __post_init__(self):
        assert self.kind in ("none", "alibi", "sqdist", "svd", "neural", "dense")
        assert self.mode in ("flashbias", "dense")

    @property
    def effective_rank(self) -> int:
        if self.kind == "alibi":
            return 2
        if self.kind == "sqdist":
            return 3 * self.coord_dim
        return self.rank


# ---------------------------------------------------------------------------
# ALiBi (Example 3.4) — rank 2
# ---------------------------------------------------------------------------

def alibi_slopes(num_heads: int) -> jax.Array:
    """Geometric slope sequence from the ALiBi paper (Press et al., 2022).

    For ``num_heads`` a power of two the slopes are ``2^(-8h/num_heads)``;
    otherwise the published interleaving fallback is used.
    """
    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(math.log2(n) - 3)))
        return [start * (start ** i) for i in range(n)]

    if math.log2(num_heads).is_integer():
        vals = pow2_slopes(num_heads)
    else:
        closest = 2 ** math.floor(math.log2(num_heads))
        vals = pow2_slopes(closest)
        extra = pow2_slopes(2 * closest)[0::2][: num_heads - closest]
        vals = vals + extra
    return jnp.asarray(vals, dtype=jnp.float32)


def alibi_factors(
    n: int, m: int, num_heads: int, *, dtype=jnp.float32,
    q_offset: int = 0, k_offset: int = 0,
):
    """Exact rank-2 factorization of the ALiBi bias.

    b[h, i, j] = -slope_h * (i' - j')  with i' = i + q_offset, j' = j + k_offset
    (the causal-side distance; the causal mask hides j' > i').

    Decomposition (Example 3.4): phi_q[h, i] = slope_h * [-i', 1],
    phi_k[j] = [1, j']  ==>  phi_q @ phi_k.T = slope_h * (j' - i').

    Returns (phi_q: (H, N, 2), phi_k: (M, 2)).
    """
    slopes = alibi_slopes(num_heads).astype(dtype)
    qi = jnp.arange(n, dtype=dtype) + q_offset
    kj = jnp.arange(m, dtype=dtype) + k_offset
    phi_q = jnp.stack([-qi, jnp.ones_like(qi)], axis=-1)  # (N, 2)
    phi_q = slopes[:, None, None] * phi_q[None]           # (H, N, 2)
    phi_k = jnp.stack([jnp.ones_like(kj), kj], axis=-1)   # (M, 2)
    return phi_q, phi_k


def alibi_dense(n: int, m: int, num_heads: int, *, dtype=jnp.float32,
                q_offset: int = 0, k_offset: int = 0) -> jax.Array:
    """Dense ALiBi bias (H, N, M) — the baseline / oracle."""
    slopes = alibi_slopes(num_heads).astype(dtype)
    qi = jnp.arange(n, dtype=dtype)[:, None] + q_offset
    kj = jnp.arange(m, dtype=dtype)[None, :] + k_offset
    return slopes[:, None, None] * (kj - qi)[None]


# ---------------------------------------------------------------------------
# Squared spatial distance (Example 3.5) — rank 3d
# ---------------------------------------------------------------------------

def sqdist_factors(x_q: jax.Array, x_k: jax.Array, *, negate: bool = True):
    """Exact rank-3d factorization of ``+-||x_q_i - x_k_j||^2``.

    x_q: (..., N, d), x_k: (..., M, d) spatial coordinates. Per Eq. (4), each
    coordinate axis contributes the triple
      phi_q = [x^2, 1, -2x],  phi_k = [1, x^2, x]
    so that phi_q . phi_k = x_i^2 + x_j^2 - 2 x_i x_j = (x_i - x_j)^2.

    Returns (phi_q: (..., N, 3d), phi_k: (..., M, 3d)).
    """
    sign = -1.0 if negate else 1.0

    def q_feats(x):
        # (..., N, d) -> (..., N, d, 3) -> (..., N, 3d)
        f = jnp.stack([x * x, jnp.ones_like(x), -2.0 * x], axis=-1)
        return f.reshape(*f.shape[:-2], -1)

    def k_feats(x):
        f = jnp.stack([jnp.ones_like(x), x * x, x], axis=-1)
        return f.reshape(*f.shape[:-2], -1)

    return sign * q_feats(x_q), k_feats(x_k)


def sqdist_dense(x_q: jax.Array, x_k: jax.Array, *, negate: bool = True) -> jax.Array:
    """Dense squared-distance bias (..., N, M) — oracle for the factorization."""
    d2 = jnp.sum((x_q[..., :, None, :] - x_k[..., None, :, :]) ** 2, axis=-1)
    return -d2 if negate else d2


# ---------------------------------------------------------------------------
# Learnable-scaled distance (Sec. 4.4) — the PDE-solver "adaptive mesh" bias
# ---------------------------------------------------------------------------

def scaled_sqdist_factors(x_q: jax.Array, x_k: jax.Array, alpha: jax.Array,
                          *, negate: bool = True):
    """f(x_i, x_j) = alpha_i * ||x_i - x_j||^2 with per-query learnable alpha.

    alpha broadcasts against the query axis: shape (..., N) or (H, N) etc.
    The scale folds into phi_q, so rank stays 3d and the factorization remains
    exact AND differentiable w.r.t. alpha — this is what lets FlashBias train
    the learnable bias without materializing (or storing the gradient of) the
    N x M matrix (Table 5).
    """
    phi_q, phi_k = sqdist_factors(x_q, x_k, negate=negate)
    return alpha[..., None] * phi_q, phi_k


def scaled_sqdist_dense(x_q, x_k, alpha, *, negate: bool = True):
    return alpha[..., None] * sqdist_dense(x_q, x_k, negate=negate)


# ---------------------------------------------------------------------------
# Multiplicative cos(i - j) (App. I Example I.1) — rank 2
# ---------------------------------------------------------------------------

def cos_relpos_factors(n: int, m: int, *, dtype=jnp.float32):
    """b[i, j] = cos(i - j) = cos i cos j + sin i sin j, rank 2."""
    qi = jnp.arange(n, dtype=dtype)
    kj = jnp.arange(m, dtype=dtype)
    phi_q = jnp.stack([jnp.cos(qi), jnp.sin(qi)], axis=-1)
    phi_k = jnp.stack([jnp.cos(kj), jnp.sin(kj)], axis=-1)
    return phi_q, phi_k


def cos_relpos_dense(n: int, m: int, *, dtype=jnp.float32) -> jax.Array:
    qi = jnp.arange(n, dtype=dtype)[:, None]
    kj = jnp.arange(m, dtype=dtype)[None, :]
    return jnp.cos(qi - kj)


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def broadcast_factors(phi: jax.Array, batch: int, seq: int, heads: int) -> jax.Array:
    """Broadcast a factor tensor to the canonical (B, S, H, R) layout.

    Accepts (S, R), (H, S, R), (B, S, H, R); returns (B, S, H, R).

    A 3-D factor is ONLY interpreted as per-head (H, S, R), and only when its
    leading dim equals ``heads`` — a (B, S, R) batch factor would previously be
    transposed into nonsense silently whenever it happened to pass the
    broadcast (e.g. B == S). Batch-varying factors must come in explicit 4-D
    (B, S, H, R) / (B, S, 1, R) form.
    """
    if phi.ndim == 2:            # (S, R) — shared across batch & heads
        phi = phi[None, :, None, :]
    elif phi.ndim == 3:          # (H, S, R) — per-head, leading dim must be H
        if phi.shape[0] != heads:
            raise ValueError(
                f"3-D factor leading dim {phi.shape[0]} != heads {heads}: a "
                f"3-D factor means per-head (H, S, R); pass batch factors as "
                f"explicit 4-D (B, S, 1, R) or (B, S, H, R)")
        phi = phi.transpose(1, 0, 2)[None]
    elif phi.ndim != 4:
        raise ValueError(f"factor rank {phi.ndim} not in (2, 3, 4)")
    return jnp.broadcast_to(phi, (batch, seq, heads, phi.shape[-1]))
