"""Low-rank utilities: energy spectra, rank selection, and the paper's IO model.

Implements the measurement side of Theorems 3.1/3.2 and Corollaries 3.3/3.7:
given a dense bias matrix we compute its singular-value energy profile, the
rank needed to retain a target energy fraction, and the storage/HBM-access
model that justifies FlashBias' speedup.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "energy_profile",
    "rank_for_energy",
    "retained_energy",
    "optimal_storage_bytes",
    "IOModel",
]


def energy_profile(mat: jax.Array) -> jax.Array:
    """Cumulative singular-value energy fraction of a (possibly batched) matrix.

    Energy of rank r = sum_{i<=r} s_i^2 / sum_i s_i^2 (Remark 3.8's "energy").
    Returns an array of shape (..., min(N, M)) with monotone entries in (0, 1].
    """
    s = jnp.linalg.svd(mat.astype(jnp.float32), compute_uv=False)
    e = jnp.cumsum(s**2, axis=-1)
    total = e[..., -1:]
    return e / jnp.where(total == 0, 1.0, total)


def rank_for_energy(mat: jax.Array, energy: float = 0.99) -> int:
    """Smallest rank retaining ``energy`` fraction of squared singular values.

    Never exceeds the spectrum length min(N, M): an all-zero matrix has a
    zero energy profile (every entry < energy), which used to count out to
    min(N, M) + 1 — a rank no factorization can have.
    """
    prof = np.asarray(energy_profile(mat))
    # batched: use the worst (max) rank over the batch so every slice is covered.
    flat = prof.reshape(-1, prof.shape[-1])
    ranks = np.minimum((flat < energy).sum(axis=-1) + 1, flat.shape[-1])
    return int(ranks.max())


def retained_energy(mat: jax.Array, rank: int) -> float:
    """Energy fraction retained by the best rank-``rank`` approximation.

    ``rank <= 0`` retains nothing (the old ``rank - 1`` indexing wrapped to
    the LAST profile entry and reported full energy for rank 0).
    """
    if rank <= 0:
        return 0.0
    prof = np.asarray(energy_profile(mat))
    flat = prof.reshape(-1, prof.shape[-1])
    idx = min(rank, flat.shape[-1]) - 1
    return float(flat[:, idx].min())


def optimal_storage_bytes(n: int, rank: int, itemsize: int = 2) -> int:
    """Theorem 3.2: optimal storage of an N x N rank-R dense matrix, Theta(NR).

    The exact bound is (2NR - R^2) scalars; we return it in bytes.
    """
    return (2 * n * rank - rank * rank) * itemsize


@dataclasses.dataclass(frozen=True)
class IOModel:
    """HBM-access model from the paper (per head, per batch element).

    All quantities are *scalar element* counts, not bytes; multiply by itemsize
    for bytes. ``sram`` is in elements too (paper uses S in storage units).
    """

    n: int  # query length
    m: int  # key length
    c: int  # head channel dim
    rank: int  # bias rank R
    sram: int  # on-chip memory size S, in elements

    def standard_attention(self) -> float:
        """Theta(NC + N^2) — materializes logits in HBM (Eq. 6)."""
        return self.n * self.c + self.n * self.m

    def flashattention(self) -> float:
        """Theta(N M C^2 / S) — FlashAttention without bias (Eq. 6)."""
        return self.n * self.m * self.c**2 / self.sram

    def flashattention_with_bias(self) -> float:
        """Theta(N M C^2 / S + N M) — must stream the dense bias (Ex. 3.9)."""
        return self.flashattention() + self.n * self.m

    def flashbias(self) -> float:
        """Cor 3.7: Theta(N M (C^2 + R^2) / S) — factor tensors ride with q/k."""
        return self.n * self.m * (self.c**2 + self.rank**2) / self.sram

    def flashbias_multiplicative(self) -> float:
        """App. I: Theta(N M C^2 R^2 / S) for the channel-expansion form."""
        return self.n * self.m * self.c**2 * self.rank**2 / self.sram

    def multiplicative_worthwhile(self) -> bool:
        """App. I Cor I.2: worthwhile iff R <= sqrt(S / C^2 + 1)."""
        return self.rank <= math.sqrt(self.sram / self.c**2 + 1)

    def speedup_over_dense_bias(self) -> float:
        """Predicted HBM-access ratio (Example 3.9 ~= 6x at C=R=64, S=100KB)."""
        return self.flashattention_with_bias() / self.flashbias()
