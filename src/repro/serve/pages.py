"""Shared KV page pool for the serve engine (vLLM-style paged cache).

The device cache owns ``n_pages`` pages of ``page_size`` token positions for
every paged cache leaf — K, V, *and the per-page ``phi_k`` factor slab* that
FlashBias Sec. 4.3 makes the KV cache the natural home of (the rank-R key
factors "ride with k", keeping bias storage at Theta(N R), Thm 3.2). This
module is the HOST side: a free-list allocator plus per-request accounting.
The device side (pool arrays + page tables) lives in ``models/lm.py`` and
the paged flash-decode path in ``kernels/``. Page ids are layout-agnostic:
since ISSUE 5 the device pools are stored kv-head-major (``(L, KVH,
n_pages, ps, hd)``, the kernels' native layout — serve/README.md §Cache
layout contract), but a page is still one ``page_size``-token claim on
every paged leaf, so the accounting here is unchanged by the layout.

Allocation is LAZY by default (ISSUE 4): admission reserves only the pages
covering a request's *prompt*, and the engine ``grow``s the request by one
page whenever its length crosses a page boundary — FlashBias's Theta(NR)
factor-in-cache layout makes a page cheap enough that on-demand growth is
pure win over stranding the whole worst-case footprint at admit. When the
pool runs dry mid-flight the engine preempts the lowest-priority in-flight
request instead of deadlocking (see ``ServeEngine``). The PR-3
whole-request reservation mode is still available for A/B
(``page_reservation="whole"``); under it decode never allocates.

Pages are uniform, so "fragmentation" reduces to free-list reuse — freed
pages are handed out lowest-index-first for deterministic page tables. The
pool also keeps a high-water mark (``watermark``) of pages simultaneously
in use plus a count of mid-flight ``grow`` allocations, so benchmarks and
tests can see how much memory lazy growth actually commits.

Since ISSUE 9 pages are REFCOUNTED: prefix caching (``serve/prefix.py``)
maps several requests' page tables — plus the prefix index itself — onto
one physical page, so "free" is a decref and a page returns to the free
list only when its last holder lets go. ``free`` reports which pages
actually drained so callers (the prefix index) can invalidate entries.
Decref of a page that is already free is still rejected loudly — the
double-free tripwire survives sharing.

Since ISSUE 10 every failure is TYPED (``serve/lifecycle.py``) so it
survives ``python -O`` and callers can contain it: exhaustion raises
``PoolExhausted`` (a ``MemoryError`` subclass — pre-lifecycle callers
keep working) and accounting violations (double free, incref of a free
page, double allocation, out-of-range page id) raise ``PoolError``. The
failed operation never applies, so the pool stays consistent after a
caught error.
"""
from __future__ import annotations

import heapq
from typing import Iterable, List

from repro.serve.lifecycle import PoolError, PoolExhausted

__all__ = ["PagePool"]


class PagePool:
    """Host-side allocator over ``n_pages`` pages of ``page_size`` tokens."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"PagePool needs n_pages >= 1 and "
                             f"page_size >= 1, got ({n_pages}, {page_size})")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))   # heap, lowest first
        heapq.heapify(self._free)
        self._refs = [0] * n_pages         # holders per page; 0 = free
        self._watermark = 0                # peak pages simultaneously in use
        self._grown = 0                    # pages allocated via grow()

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def watermark(self) -> int:
        """High-water mark: the most pages ever simultaneously allocated.
        Under lazy growth this is the pool's real memory commitment — the
        number whole-request reservation would have pinned at admit."""
        return self._watermark

    @property
    def n_grown(self) -> int:
        """Pages allocated mid-flight via ``grow`` (vs at admission)."""
        return self._grown

    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering positions ``0 .. n_tokens-1`` (>= 1)."""
        return max(1, -(-int(n_tokens) // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= self.n_free

    def refcount(self, page: int) -> int:
        """Holders of ``page`` (requests + the prefix index). 0 = free."""
        self._check_page(page)
        return self._refs[page]

    def _check_page(self, page: int) -> None:
        if not 0 <= page < self.n_pages:
            raise PoolError(f"page id {page} outside pool "
                            f"[0, {self.n_pages})")

    # ------------------------------------------------------------------
    # Alloc / grow / incref / free
    # ------------------------------------------------------------------

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` pages (lowest free indices). Raises ``PoolExhausted``
        (a MemoryError) when the pool can't cover the request — callers
        gate on ``can_alloc``. All-or-nothing: a failed alloc takes no
        pages, so containment code can retry after freeing."""
        if n > self.n_free:
            raise PoolExhausted(
                f"PagePool: want {n} pages, {self.n_free} free")
        pages = [heapq.heappop(self._free) for _ in range(n)]
        for p in pages:
            if self._refs[p] != 0:
                raise PoolError(f"double allocation of page {p}")
            self._refs[p] = 1
        self._watermark = max(self._watermark, self.n_used)
        return pages

    def grow(self, n: int = 1) -> List[int]:
        """Allocate ``n`` more pages for a request already in flight (its
        length crossed a page boundary). Same free list as ``alloc`` —
        the separate entry point exists so the pool can account lazily
        grown pages apart from admission reservations."""
        pages = self.alloc(n)
        self._grown += n
        return pages

    def incref(self, pages: Iterable[int]) -> None:
        """Add a holder to already-allocated pages (prefix sharing: a new
        request maps its page table onto pages some other holder owns).
        Incref of a free page is an error — sharing never resurrects."""
        pages = list(pages)
        for p in pages:
            self._check_page(p)
            if self._refs[p] <= 0:
                raise PoolError(f"incref of free page {p}")
        for p in pages:
            self._refs[p] += 1

    def free(self, pages: Iterable[int]) -> List[int]:
        """Drop one reference per page; pages whose last holder left return
        to the free list. Decref of a free page (double free) is an error.
        Returns the pages that actually drained, so the prefix index can
        drop entries that no longer point at live content."""
        pages = list(pages)
        for p in pages:
            self._check_page(p)
            if self._refs[p] <= 0:
                raise PoolError(f"double free of page {p}")
        freed: List[int] = []
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] == 0:
                heapq.heappush(self._free, p)
                freed.append(p)
        return freed
