"""Serving substrate: continuous-batching engine over slot cache pytrees.

See README.md in this directory for the slot/cache/scheduler contract,
the request lifecycle, and the failure semantics (ISSUE 10).
"""
from repro.serve.backend import Backend, PairBatchBackend, TokenDecodeBackend
from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultPlan, FaultSpec
from repro.serve.lifecycle import (
    CANCELLED, FAILED, OK, QUEUED, REJECTED, RUNNING, TERMINAL_STATUSES,
    TIMED_OUT, AdmissionRejected, EngineStalled, InjectedFault, PoolError,
    PoolExhausted, RequestNotLive, RequestRecord, ServeError)
from repro.serve.pages import PagePool
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine", "Backend", "TokenDecodeBackend",
           "PairBatchBackend", "PagePool", "PrefixCache", "SamplingParams",
           "sample_tokens", "FIFOScheduler", "Request",
           "FaultPlan", "FaultSpec",
           "QUEUED", "RUNNING", "OK", "FAILED", "TIMED_OUT", "CANCELLED",
           "REJECTED", "TERMINAL_STATUSES", "RequestRecord", "ServeError",
           "AdmissionRejected", "EngineStalled", "InjectedFault",
           "PoolError", "PoolExhausted", "RequestNotLive"]
