"""Serving substrate: continuous-batching engine over slot cache pytrees.

See README.md in this directory for the slot/cache/scheduler contract and
the request lifecycle.
"""
from repro.serve.backend import Backend, PairBatchBackend, TokenDecodeBackend
from repro.serve.engine import ServeEngine
from repro.serve.pages import PagePool
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine", "Backend", "TokenDecodeBackend",
           "PairBatchBackend", "PagePool", "PrefixCache", "SamplingParams",
           "sample_tokens", "FIFOScheduler", "Request"]
