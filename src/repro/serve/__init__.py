"""Serving substrate: batched prefill + decode engine over cache pytrees."""
from repro.serve.engine import ServeEngine

__all__ = ["ServeEngine"]
