"""Serve backends: what a workload owns vs. what the engine core owns.

The engine core (``engine.ServeEngine``) owns the REQUEST machinery:
request ids, the scheduler and admission waves, the slot free-list and
live map, result/done bookkeeping, the preemption victim policy and the
determinism contract. A ``Backend`` owns everything DEVICE-side for one
workload family:

- allocated state (caches, factor slabs, page pools, sampling state),
- the jitted admit/step programs,
- per-slot resources and their accounting (pages),
- retire/freeze and preemption snapshots.

``TokenDecodeBackend`` is the autoregressive LM path (KV caches, paged
pools, sampling) — the pre-ISSUE-6 engine body moved here verbatim, so
LM serve behavior is bit-identical to the monolithic engine.

``PairBatchBackend`` serves batched Pairformer inference (the paper's
Sec. 4.4 workload): a request is one complex, admission runs the trunk
once and caches its per-layer pair-bias FACTORS (or the dense bias, for
the A/B baseline), and every step is one refinement iteration of
single-rep attention over the padded slot batch with per-slot ``n_res``
masking. See serve/README.md §Backend contract.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import Rules, shard_put, use_mesh_rules
from repro.models.api import Model
from repro.serve.lifecycle import AdmissionRejected, PoolError, PoolExhausted
from repro.serve.pages import PagePool
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import sample_tokens, sample_tokens_guarded
from repro.serve.scheduler import ChunkPlan, Request

__all__ = ["Backend", "TokenDecodeBackend", "PairBatchBackend"]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


class Backend:
    """Protocol between the engine core and a workload backend.

    ``admit``/``step`` return ``(emissions, mask)``: ``mask[slot]`` marks
    slots that advanced one budget unit this call; ``emissions`` is a
    per-slot int array of emitted token ids, or None for backends that
    emit nothing incrementally (the engine then collects the result via
    ``fetch_result`` when the budget drains). Slot registration, budget
    accounting and retirement stay in the engine core.
    """

    paged: bool = False        # admission gated on page accounting
    lazy: bool = False         # pages grow mid-flight (may force preemption)
    guards: bool = True        # host-side non-finite guards (ISSUE 10)
    faults = None              # serve.faults.FaultPlan injection hook

    def ensure_state(self) -> None:
        """Allocate device state on first use (idempotent)."""
        raise NotImplementedError

    def validate(self, req: Request) -> None:
        """Submit-time bounds check (raises on an inadmissible request)."""
        raise NotImplementedError

    def admit(self, wave: List[Request],
              slots: List[int]) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """Prefill ``wave`` into ``slots``; returns (emissions, mask)."""
        raise NotImplementedError

    def step(self, live: Dict[int, object]
             ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """Advance every live slot one budget unit."""
        raise NotImplementedError

    # -- chunked prefill (ISSUE 7) --------------------------------------
    def prefill_pending(self) -> bool:
        """True while admitted prompts still have chunks queued — the
        engine then interleaves one ``prefill_step`` with each decode
        step. Backends without chunked admission never have any."""
        return False

    def prefill_step(self) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """Advance every pending prompt one chunk; same (emissions, mask)
        contract as ``step`` — only slots whose FINAL chunk landed this
        call emit (their first sampled token) and advance a budget unit."""
        raise NotImplementedError

    def pending_slots(self):
        """Slots mid-chunked-prefill: live (they own resources and can be
        preempted) but not yet decoding — the engine and the backend's own
        ``step``/``growth_pending`` exclude them from decode accounting."""
        return ()

    def fetch_result(self, slot: int, st) -> Optional[np.ndarray]:
        """Final non-incremental result for a finishing slot (or None)."""
        return None

    def stream_result(self, slot: int, st) -> Optional[np.ndarray]:
        """Per-step streaming payload for non-emitting backends (engines
        pass it to a request's ``on_token`` sink when ``emissions`` is
        None). Token backends stream the emitted id instead."""
        return None

    def release(self, slot: int) -> None:
        """Retire a finished slot: freeze its cache row, free resources."""
        raise NotImplementedError

    def snapshot(self, slot: int, st, emitted) -> Request:
        """Preempt: freeze + free the slot, return the resumable Request."""
        raise NotImplementedError

    def snapshot_request(self, slot: int, st, emitted) -> Request:
        """The resumable Request ``snapshot`` would return, WITHOUT
        freezing or freeing anything — the engine checkpoint (ISSUE 10)
        serializes live slots through this, leaving the running engine
        untouched."""
        raise NotImplementedError

    # -- fault containment (ISSUE 10) -----------------------------------
    def take_guard_faults(self) -> Dict[int, str]:
        """Drain {slot: detail} for slots whose last admit/step tripped a
        non-finite guard. The engine drains after every backend call that
        can emit and quarantines the listed slots; a drained fault is
        forgotten (the quarantined slot re-admits with fresh state)."""
        bad = getattr(self, "_guard_bad", None)
        if not bad:
            return {}
        self._guard_bad = {}
        return bad

    def quarantine(self, slot: int) -> None:
        """Pre-release hook for a FAULTING slot: discard anything other
        requests could observe from it (the token backend invalidates
        prefix-index entries for pages the slot wrote). The engine calls
        this BEFORE the snapshot/release frees the slot's resources."""

    # -- resource accounting (paged backends) ---------------------------
    def admission_units(self, req: Request) -> int:
        """Resource units (pages) reserved when ``req`` is admitted."""
        return 0

    def units_free(self) -> int:
        return 0

    def growth_pending(self, live: Dict[int, object]) -> List[int]:
        """Slots whose next step needs a resource grown first."""
        return []

    def grow_slots(self, growing: List[int]) -> None:
        raise NotImplementedError

    def page_cap(self, live: Dict[int, object]) -> Optional[int]:
        """Static page bound for this step (None for unpaged backends)."""
        return None

    def stats(self) -> dict:
        return {}


class TokenDecodeBackend(Backend):
    """Autoregressive LM decode: KV caches, paged pools, per-slot sampling.

    This is the pre-refactor engine body: prefill waves right-padded and
    batch-padded to ``n_slots``, one jitted decode step over the full slot
    batch, per-request PRNG key chains, paged KV with lazy page growth.
    Every computation and its ordering is preserved from the monolithic
    engine, so behavior is bit-identical.

    ``prefill_chunk`` (ISSUE 7) switches admission from whole-prompt waves
    to CHUNKED prefill: ``admit`` becomes a pure planner (reserve pages,
    arm sampling state, enqueue a ``ChunkPlan``) and the engine drives one
    ``prefill_step`` — a single jitted fixed-shape (n_slots, chunk)
    program appending one chunk per pending slot — per engine step,
    interleaved with decode. A 4k-token arrival then costs each in-flight
    request one chunk's latency per step instead of a whole-prompt stall.
    Mid-prefill slots hold device length 0 (frozen for decode); the final
    chunk flips the length to the prompt length and samples the first
    token, so PRNG chains and decode behavior match the wave path exactly.
    Ring-KV archs clamp the chunk to the attention window (a chunk's
    positions must map to distinct ring slots).

    ``mesh``/``rules`` (ISSUE 7) make the backend mesh-aware: every jitted
    program traces under ``use_mesh_rules`` (so ``dist.constrain`` calls
    in model code bind — TP-sharded heads, DP-sharded slot rows) and
    ``ensure_state`` places persistent device state with explicit
    shardings — KV caches and page pools along ``kv_heads``, slot-batch
    rows along ``batch`` (dropped when ``n_slots`` does not divide DP),
    ``pages_phi`` and page tables replicated. The page ALLOCATOR and slot
    page lists stay host-side: planning is cheap python, only content
    moves through collectives.
    """

    def __init__(self, model: Model, params: dict, max_len: int,
                 n_slots: int, prefill_len: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 pages_per_slot: Optional[int] = None,
                 page_reservation: str = "lazy",
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 mesh=None, rules: Optional[Rules] = None):
        if page_reservation not in ("lazy", "whole"):
            raise ValueError(f"page_reservation must be 'lazy' or "
                             f"'whole', got {page_reservation!r}")
        self.model, self.params = model, params
        self.max_len, self.n_slots = max_len, n_slots
        self.prefill_len = prefill_len
        self.mesh = mesh
        self.rules = (rules or Rules()) if mesh is not None else rules
        self._guard_bad: Dict[int, str] = {}
        self._slot_kept: Dict[int, int] = {}   # shared (unwritten) pages
        cfg = model.cfg
        self._vocab = cfg.vocab
        self._front_dim = (cfg.frontend_len, cfg.d_model)
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, "
                                 f"got {prefill_chunk}")
            if model.prefill_chunk is None:
                raise ValueError(
                    f"{cfg.family} model has no chunked-prefill path")
            if cfg.window and cfg.window < max_len:
                # ring cache: a chunk's positions must land on distinct
                # ring slots, so the chunk can never exceed the window
                prefill_chunk = min(prefill_chunk, cfg.window)
        self.chunk_size = prefill_chunk
        self._pending: Dict[int, ChunkPlan] = {}
        # full-KV families must fit prompt + budget inside the slot segment
        # (contiguous mode) or inside the page pool (paged mode)
        self._bounded_cache = (cfg.family in ("dense", "moe", "hybrid")
                               and not (cfg.window and cfg.window < max_len))
        self.paged = (page_size is not None and self._bounded_cache
                      and model.init_paged_cache is not None)
        self.lazy = self.paged and page_reservation == "lazy"
        if self.paged:
            self.page_size = page_size
            self.n_pages = n_pages or n_slots * _ceil_to(max_len,
                                                         page_size) // page_size
            self.pages_per_slot = min(pages_per_slot or self.n_pages,
                                      self.n_pages)
            self._pool = PagePool(self.n_pages, page_size)
            self._slot_pages: Dict[int, List[int]] = {}
        # prefix caching (ISSUE 9): content-hashed sharing of completed
        # prompt pages. Requires the paged pool (sharing is page-table
        # indirection), the chunked planner (the novel tail lands through a
        # mid-prompt ChunkPlan) and a family whose slot state lives
        # ENTIRELY in pages — hybrid's SSM state is recurrent and cannot
        # be rebuilt from a mid-prompt prefill start.
        self._prefix: Optional[PrefixCache] = None
        if prefix_cache:
            if not (self.paged and self.chunk_size):
                raise ValueError(
                    "prefix_cache needs paged KV (page_size) + chunked "
                    "prefill (prefill_chunk): shared pages map through "
                    "the page table and novel tails land via mid-prompt "
                    "ChunkPlans")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"prefix_cache shares KV pages only — family "
                    f"'{cfg.family}' carries per-slot recurrent state a "
                    f"mid-prompt prefill start cannot rebuild")
            if model.copy_pages is None:
                raise ValueError(
                    "prefix_cache needs the model's copy_pages program")
            self._prefix = PrefixCache(page_size)
            self._n_cow = 0                  # CoW page copies performed
            self._tok_matched = 0            # prefix tokens served from cache
            self._tok_matchable = 0          # full-block tokens seen at admit
        self._cache = None                        # allocated on first step

        def _pf(p, toks, front, lengths, max_len):
            batch = {"tokens": toks}
            if front is not None:
                batch["frontend"] = front
            return model.prefill(p, batch, max_len=max_len, lengths=lengths)

        self._prefill = jax.jit(self._with_mesh(_pf),
                                static_argnames=("max_len",))
        # max_pages is a STATIC cap on the pages a paged decode step may
        # reference: the engine passes a power-of-two rounding of its
        # host-mirrored longest live length, so the paged XLA fallback
        # gathers Θ(longest request) instead of the full page-table width
        # while recompiling at most log2(pages_per_slot) times.
        self._decode = jax.jit(self._with_mesh(model.decode),
                               static_argnames=("max_pages",))
        self._insert = jax.jit(self._with_mesh(model.insert_cache))
        if self.paged:
            self._insert_paged = jax.jit(self._with_mesh(model.insert_paged))
            self._grow_tables = jax.jit(self._with_mesh(
                model.grow_page_table))
        if self._prefix is not None:
            # fixed-shape CoW program: (n_slots,) src/dst page ids per
            # call, out-of-range dst ids dropped — compiles once
            self._copy_pages = jax.jit(self._with_mesh(model.copy_pages))
        if self.chunk_size:
            self._chunk = jax.jit(self._with_mesh(model.prefill_chunk),
                                  static_argnames=("max_pages",))

    def _with_mesh(self, fn):
        """Bind ``use_mesh_rules(mesh, rules)`` around ``fn`` at TRACE
        time, so every ``dist.constrain`` in the model body resolves
        against the backend's mesh inside the jitted program. Identity
        when no mesh is configured — single-device serve pays nothing."""
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with use_mesh_rules(mesh, rules):
                return fn(*args, **kwargs)
        return wrapped

    # -- lifecycle ------------------------------------------------------

    def ensure_state(self) -> None:
        if self._cache is not None:
            return
        ns = self.n_slots
        if self.paged:
            self._cache = self.model.init_paged_cache(
                ns, self.n_pages, self.page_size, self.pages_per_slot)
        else:
            self._cache = self.model.init_cache(ns, self.max_len)
        self._temps = jnp.zeros((ns,), jnp.float32)
        self._topks = jnp.zeros((ns,), jnp.int32)
        self._keys = jnp.zeros((ns, 2), jnp.uint32)
        self._last_tok = jnp.zeros((ns, 1), jnp.int32)
        self._shard_state()

    def _state_axes(self) -> Dict[str, tuple]:
        """Logical axes of every persistent cache leaf (one entry per dim).

        The serve-path sharding contract: KV content shards along
        ``kv_heads`` (TP) and slot rows along ``batch`` (DP); everything
        host-planned — page tables, the phi position slab — replicates, so
        the allocator never needs a collective to rewrite an int32 row.
        SSM state shards on batch only (its head dim is padded for the
        kernel, not for the mesh)."""
        kernel = self.model.cfg.cache_layout == "kernel"
        axes: Dict[str, tuple] = {
            "length": ("batch",),
            "ssm_h": ("layers", "batch", None, None, None),
            "conv_x": ("layers", "batch", None, None, None),
            "conv_bc": ("layers", "batch", None, None),
        }
        if self.paged:
            pool = (("layers", "kv_heads", None, None, None) if kernel
                    else ("layers", None, None, "kv_heads", None))
            axes.update(pages_k=pool, pages_v=pool,
                        page_table=(None, None),
                        pages_phi=(None, None, None))
        else:
            kv = (("layers", "batch", "kv_heads", None, None) if kernel
                  else ("layers", "batch", None, "kv_heads", None))
            axes.update(k=kv, v=kv)
        return axes

    def _shard_state(self) -> None:
        """Place persistent device state with explicit shardings so jit
        input shardings agree with the constraints traced by
        ``_with_mesh`` programs (no resharding on the first step)."""
        if self.mesh is None:
            return
        mesh, rules = self.mesh, self.rules
        axes = self._state_axes()
        for key, a in axes.items():
            if key in self._cache:
                self._cache[key] = shard_put(self._cache[key], mesh, rules,
                                             a)
        self._temps = shard_put(self._temps, mesh, rules, ("batch",))
        self._topks = shard_put(self._topks, mesh, rules, ("batch",))
        self._keys = shard_put(self._keys, mesh, rules, ("batch", None))
        self._last_tok = shard_put(self._last_tok, mesh, rules,
                                   ("batch", None))

    def validate(self, req: Request) -> None:
        if not np.issubdtype(req.tokens.dtype, np.integer):
            raise AdmissionRejected("token backend takes int token prompts")
        if self.chunk_size and req.frontend is not None:
            raise AdmissionRejected(
                "chunked prefill takes token prompts only (frontend "
                "embeddings ride the whole-prompt wave path)")
        if (self.prefill_len is not None
                and req.tokens.size > self.prefill_len):
            raise AdmissionRejected(
                f"prompt of {req.tokens.size} tokens exceeds the pinned "
                f"prefill_len={self.prefill_len}")
        if self._bounded_cache and self.paged:
            # paged: prompt + budget may exceed max_len (the PR-2 segment
            # bound is gone). The real bounds are the request's own
            # page-table row and the pool itself — a footprint the pool
            # can never cover would preempt everything and still deadlock
            needed = self._pages_needed(req)
            cap = min(self.pages_per_slot, self.n_pages)
            if needed > cap:
                # shared-prefix hits don't lift the bound (shared pages
                # still occupy page-table row entries and pool pages), but
                # the message must state what admission actually reserves
                shared = ""
                if self._prefix is not None:
                    hit = len(self._prefix.match(req.tokens)[0])
                    shared = (f", of which {hit} currently shared via the "
                              f"prefix cache — admission would reserve "
                              f"{needed - hit} fresh pages but the table "
                              f"row still references all {needed}")
                raise AdmissionRejected(
                    f"paged mode: request footprint {needed} pages "
                    f"(ceil((prompt {req.prompt_len} + budget "
                    f"{req.max_new_tokens} - 1) / page_size "
                    f"{self.page_size})){shared} exceeds {cap} "
                    f"(page-table row width {self.pages_per_slot}, "
                    f"pool {self.n_pages} pages)")
        elif self._bounded_cache:
            if req.prompt_len + req.max_new_tokens > self.max_len:
                raise AdmissionRejected(
                    f"contiguous mode: prompt {req.prompt_len} + budget "
                    f"{req.max_new_tokens} exceeds the per-slot segment "
                    f"max_len={self.max_len} (paged mode lifts this bound "
                    f"— pass page_size)")
        # ring-KV keeps only the last `window` keys and SSM state is
        # constant-size, so those families accept prompts of any length

    # -- paged accounting -----------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Pages a request can ever touch: its final cache length is
        ``prompt + budget - 1`` (the last sampled token is never fed
        back)."""
        return self._pool.pages_needed(req.prompt_len + req.max_new_tokens
                                       - 1)

    def admission_units(self, req: Request) -> int:
        """Pages reserved at admission: just the prompt's under lazy
        growth, the full worst-case footprint under ``"whole"``. With
        prefix caching, matched pages that already have a LIVE holder
        (refcount >= 2) cost nothing — index-only matches (refcount 1)
        still count, because the same pages appear in ``units_free``'s
        evictable pool and must not be double-counted."""
        units = (self._pool.pages_needed(req.prompt_len) if self.lazy
                 else self._pages_needed(req))
        if self._prefix is not None:
            kept, _, _, _ = self._prefix_plan(req)
            units -= sum(1 for p in kept if self._pool.refcount(p) >= 2)
        return units

    def units_free(self) -> int:
        """Pages admission can draw on: the free list plus pages the
        prefix index retains with no live sharer (evictable on demand)."""
        free = self._pool.n_free
        if self._prefix is not None:
            free += self._prefix.n_evictable(self._pool)
        return free

    def _reclaim(self, n: int) -> None:
        """Make sure ``n`` pages are actually on the free list, evicting
        index-retained pages (LRU, leaf-first) if the free list is short.
        Callers gated on ``units_free`` so the eviction always suffices."""
        if self._prefix is not None and self._pool.n_free < n:
            self._prefix.evict(self._pool, n - self._pool.n_free)

    def _prefix_plan(self, req: Request) -> Tuple[List[int], List[int],
                                                  int, int]:
        """Resolve a request against the prefix index:
        ``(kept, cow_src, done, matched_tokens)``.

        ``kept`` pages are shared as-is (page-table indirection + incref).
        ``done`` — where the mid-prompt ChunkPlan starts — is ``matched``
        floored to a CHUNK multiple and capped below the prompt length:
        chunk boundaries then land exactly where the unshared engine's do
        (admission always chunks from a chunk-multiple offset), which is
        what makes shared outputs BIT-identical, and the final chunk always
        exists to produce the first sampled token. Matched pages covering
        the re-run span ``[done, matched)`` would be written by a sharer —
        they are returned as ``cow_src`` for copy-on-write instead."""
        pages, matched = self._prefix.match(req.tokens)
        if not pages:
            return [], [], 0, 0
        done = (min(matched, req.prompt_len - 1)
                // self.chunk_size) * self.chunk_size
        k = len(pages) if done >= matched else done // self.page_size
        return pages[:k], pages[k:], done, matched

    def page_cap(self, live) -> Optional[int]:
        """Static page bound for this decode step: pow2-rounded pages of
        the longest live length (+1 for the position being written), so
        the jitted step recompiles only when a length crosses a doubling
        boundary. None for unpaged engines."""
        if not self.paged:
            return None
        longest = max((st.length for s, st in live.items()
                       if s not in self._pending), default=0)
        need = max(1, -(-(longest + 1) // self.page_size))
        cap = 1
        while cap < need:
            cap *= 2
        return min(cap, self.pages_per_slot)

    def growth_pending(self, live) -> List[int]:
        # mid-chunked-prefill slots hold their full prompt reservation
        # already and are frozen for decode — they never grow here
        ps = self.page_size
        return [s for s, st in live.items()
                if s not in self._pending
                and st.length // ps >= len(self._slot_pages[s])]

    def grow_slots(self, growing: List[int]) -> None:
        """Allocate the next page for every growing slot and push the new
        table rows to the device in one fixed-shape jitted scatter.

        ATOMIC (ISSUE 10): every check and allocation happens before any
        host table mutates — a ``PoolExhausted`` (real or injected via
        the fault plan) leaves ``_slot_pages`` and the device tables
        exactly as they were, so the engine can contain it by preempting
        the growing slots and retrying."""
        if self.faults is not None and self.faults.alloc_fails():
            raise PoolExhausted("injected page-alloc failure (fault plan)")
        for slot in growing:
            if len(self._slot_pages[slot]) + 1 > self.pages_per_slot:
                raise PoolError(
                    f"slot {slot} page table full "
                    f"({self.pages_per_slot} rows) — admission validation "
                    f"should have rejected this footprint")
        self._reclaim(len(growing))
        grown = self._pool.grow(len(growing))   # all-or-nothing
        slot_ids = np.full((self.n_slots,), self.n_slots, np.int32)
        tables = np.full((self.n_slots, self.pages_per_slot), self.n_pages,
                         np.int32)
        for i, (slot, page) in enumerate(zip(growing, grown)):
            pages = self._slot_pages[slot]
            pages.append(page)
            slot_ids[i] = slot
            tables[i, :len(pages)] = pages
        self._cache = self._grow_tables(self._cache, jnp.asarray(slot_ids),
                                        jnp.asarray(tables))

    # -- admit / step ----------------------------------------------------

    def admit(self, wave: List[Request], slots: List[int]):
        """Prefill the wave into freed slots and sample each admitted
        request's first token from its prefill logits.

        Chunked mode (``prefill_chunk``): admission is a PLANNER — reserve
        each request's prompt pages and write its page-table row now, arm
        its sampling state (so a mid-prefill preemption snapshots a valid
        PRNG chain), and enqueue a ``ChunkPlan``. Nothing runs on device
        beyond the int32 table scatter; the prompt lands chunk by chunk
        through ``prefill_step``, and nothing emits until a final chunk."""
        if self.chunk_size:
            return self._plan_chunked(wave, slots)
        ns, w = self.n_slots, len(wave)

        # right-pad prompts; pad the wave batch to n_slots so exactly one
        # prefill program serves every wave size (padding rows are dropped
        # at insert via an out-of-range slot id). A resumed prompt may
        # exceed a pinned prefill_len — that wave pads to the resumed
        # length, and _take_wave made it a SOLO wave so no co-admitted
        # request sees the changed padding
        padded = max(r.tokens.size for r in wave)
        if self.prefill_len is not None:
            padded = max(self.prefill_len, padded)
        toks = np.zeros((ns, padded), np.int32)
        lengths = np.ones((ns,), np.int32)
        for i, r in enumerate(wave):
            toks[i, :r.tokens.size] = r.tokens
            lengths[i] = r.prompt_len
        front = None
        has_front = [r.frontend is not None for r in wave]
        if any(has_front):
            assert all(has_front), \
                "wave mixes frontend/frontend-less requests"
            front = np.zeros((ns,) + self._front_dim, np.float32)
            for i, r in enumerate(wave):
                front[i] = r.frontend
            front = jnp.asarray(front)

        front_len = self._front_dim[0] if front is not None else 0
        if self.paged:
            # the wave cache only needs to hold the padded prompt, page-
            # aligned — NOT a full max_len segment; pages scatter from it
            pf_len = _ceil_to(padded + front_len, self.page_size)
        else:
            pf_len = self.max_len
        logits, wave_cache = self._prefill(
            self.params, jnp.asarray(toks), front, jnp.asarray(lengths),
            pf_len)
        slot_ids = np.full((ns,), ns, np.int32)    # padding rows -> dropped
        slot_ids[:w] = slots
        if self.paged:
            # lazy: reserve only the prompt's pages — decode grows the
            # table on page-boundary crossings. whole: reserve the full
            # footprint so decode never allocates mid-flight
            tables = np.full((ns, self.pages_per_slot), self.n_pages,
                             np.int32)
            for i, (slot, r) in enumerate(zip(slots, wave)):
                pages = self._pool.alloc(self.admission_units(r))
                self._slot_pages[slot] = pages
                tables[i, :len(pages)] = pages
            self._cache = self._insert_paged(self._cache, wave_cache,
                                             slot_ids, jnp.asarray(tables))
        else:
            self._cache = self._insert(self._cache, wave_cache, slot_ids)

        # per-slot sampling state + per-request PRNG chains; a preempted
        # request resumes from its key snapshot so its sample stream stays
        # aligned with its token count
        sl = jnp.asarray(np.asarray(slots, np.int32))
        self._temps = self._temps.at[sl].set(jnp.asarray(
            [r.sampling.temperature for r in wave], jnp.float32))
        self._topks = self._topks.at[sl].set(jnp.asarray(
            [r.sampling.top_k for r in wave], jnp.int32))
        self._keys = self._keys.at[sl].set(jnp.stack(
            [jax.random.PRNGKey(r.sampling.seed) if r.key_override is None
             else jnp.asarray(r.key_override, jnp.uint32) for r in wave]))

        # first token: scatter wave-row logits into slot rows, sample
        lg = jnp.zeros((ns, logits.shape[-1]), logits.dtype)
        lg = lg.at[jnp.asarray(slot_ids)].set(logits[:, 0], mode="drop")
        mask = np.zeros((ns,), bool)
        mask[slots] = True
        return self._sample(lg, mask), mask

    def _plan_chunked(self, wave: List[Request], slots: List[int]):
        """Chunked admission: reserve resources + arm sampling state, then
        queue the prompts. Page content and phi factor rows are written by
        the chunk program itself (write-then-attend), so only the int32
        page-table rows move here — one fixed-shape jitted scatter."""
        ns = self.n_slots
        starts: Dict[int, int] = {}
        if self.paged:
            slot_ids = np.full((ns,), ns, np.int32)
            tables = np.full((ns, self.pages_per_slot), self.n_pages,
                             np.int32)
            cow_jobs: List[Tuple[int, int]] = []   # (src, dst) page copies
            for i, (slot, r) in enumerate(zip(slots, wave)):
                kept: List[int] = []
                cow_src: List[int] = []
                if self._prefix is not None:
                    kept, cow_src, done, matched = self._prefix_plan(r)
                    starts[slot] = done
                    self._tok_matched += matched
                    self._tok_matchable += (r.prompt_len // self.page_size
                                            ) * self.page_size
                    # pin shared pages (kept AND CoW sources) before any
                    # eviction this wave triggers can reach them
                    self._pool.incref(kept + cow_src)
                total = (self._pool.pages_needed(r.prompt_len) if self.lazy
                         else self._pages_needed(r))
                self._reclaim(total - len(kept))
                fresh = self._pool.alloc(total - len(kept))
                # fresh pages fill the table row after the kept prefix; the
                # first len(cow_src) of them are private copies of shared
                # pages the re-run span [done, matched) will write into
                cow_jobs += list(zip(cow_src, fresh))
                if cow_src:
                    self._n_cow += len(cow_src)
                pages = kept + fresh
                self._slot_pages[slot] = pages
                # pages after the kept prefix are WRITTEN by this slot
                # (novel tail, CoW copies, decode growth) — quarantine
                # invalidates exactly those from the prefix index
                self._slot_kept[slot] = len(kept)
                slot_ids[i] = slot
                tables[i, :len(pages)] = pages
            self._cache = self._grow_tables(self._cache,
                                            jnp.asarray(slot_ids),
                                            jnp.asarray(tables))
            if cow_jobs:
                # copy shared content into the private pages BEFORE any
                # chunk program writes; one fixed-shape program per
                # n_slots-wide batch, in-batch gathers read the pre-copy
                # pool so a same-wave evict/reuse cannot misorder
                for j0 in range(0, len(cow_jobs), ns):
                    batch = cow_jobs[j0:j0 + ns]
                    src = np.full((ns,), self.n_pages, np.int32)
                    dst = np.full((ns,), self.n_pages, np.int32)
                    for j, (s_pg, d_pg) in enumerate(batch):
                        src[j], dst[j] = s_pg, d_pg
                    self._cache = self._copy_pages(self._cache,
                                                   jnp.asarray(src),
                                                   jnp.asarray(dst))
                # drop the planning pin on CoW sources: the sharer now owns
                # a private copy, the cached entry stays valid for others
                self._pool.free([s_pg for s_pg, _ in cow_jobs])
        sl = jnp.asarray(np.asarray(slots, np.int32))
        self._temps = self._temps.at[sl].set(jnp.asarray(
            [r.sampling.temperature for r in wave], jnp.float32))
        self._topks = self._topks.at[sl].set(jnp.asarray(
            [r.sampling.top_k for r in wave], jnp.int32))
        self._keys = self._keys.at[sl].set(jnp.stack(
            [jax.random.PRNGKey(r.sampling.seed) if r.key_override is None
             else jnp.asarray(r.key_override, jnp.uint32) for r in wave]))
        for slot, r in zip(slots, wave):
            # prefix hits start the cursor mid-prompt: the shared pages
            # already hold positions [0, done), only the tail lands
            self._pending[slot] = ChunkPlan(r, done=starts.get(slot, 0))
        return None, np.zeros((ns,), bool)

    def prefill_pending(self) -> bool:
        return bool(self._pending)

    def pending_slots(self):
        return self._pending.keys()

    def _chunk_page_cap(self) -> Optional[int]:
        """Static page cap of one chunk program: pow2-rounded pages of the
        longest pending prefix, same doubling-boundary recompile bound as
        the decode ``page_cap``."""
        if not self.paged:
            return None
        longest = max(p.done for p in self._pending.values())
        need = max(1, -(-longest // self.page_size))
        cap = 1
        while cap < need:
            cap *= 2
        return min(cap, self.pages_per_slot)

    def prefill_step(self):
        """Append one chunk for EVERY pending slot in a single jitted
        fixed-shape (n_slots, chunk) program. Mid-prompt chunks keep the
        device length at 0 (the ``final_lens`` -1 sentinel) so decode
        freezes the lane; a final chunk sets the prompt length and its
        logits sample the request's first token — mask marks exactly those
        finalized slots, keeping each PRNG chain aligned with its token
        count."""
        ns, c = self.n_slots, self.chunk_size
        toks = np.zeros((ns, c), np.int32)
        offs = np.zeros((ns,), np.int32)
        clens = np.zeros((ns,), np.int32)
        flens = np.full((ns,), -1, np.int32)
        finalized: List[int] = []
        for slot, plan in self._pending.items():
            off, chunk_toks, final = plan.next_chunk(c)
            toks[slot, :chunk_toks.size] = chunk_toks
            offs[slot] = off
            clens[slot] = chunk_toks.size
            if final:
                flens[slot] = plan.req.prompt_len
                finalized.append(slot)
        cap = self._chunk_page_cap()
        logits, self._cache = self._chunk(
            self.params, self._cache, jnp.asarray(toks), jnp.asarray(offs),
            jnp.asarray(clens), jnp.asarray(flens), max_pages=cap)
        for slot in finalized:
            plan = self._pending.pop(slot)
            if self._prefix is not None:
                # the prompt has fully landed: its FULL pages are immutable
                # from here (decode writes at positions >= prompt_len, and
                # partial last pages are never registered) — index them
                self._prefix.insert(plan.req.tokens,
                                    self._slot_pages[slot], self._pool)
        mask = np.zeros((ns,), bool)
        mask[finalized] = True
        return self._sample(logits[:, 0], mask), mask

    def step(self, live):
        """One jitted decode step over the full slot batch. Slots still
        mid-chunked-prefill ride the batch frozen (device length 0) and
        are EXCLUDED from the advance mask — committing their sampling
        state here would burn a PRNG split the wave path never spends."""
        logits, self._cache = self._decode(self.params, self._cache,
                                           self._last_tok,
                                           max_pages=self.page_cap(live))
        mask = np.zeros((self.n_slots,), bool)
        for s, st in live.items():
            if s not in self._pending:
                st.length += 1
                mask[s] = True
        return self._sample(logits[:, 0], mask), mask

    def _sample(self, logits2d, mask: np.ndarray) -> np.ndarray:
        """Sample all slots; commit key/token state for ``mask`` slots only
        (keeping every request's key chain aligned with its token
        count).

        Guarded (ISSUE 10): before committing, the emitting slots'
        logits are checked host-side for non-finite values — NaN, +inf,
        or an all(-inf) row. ``max`` is the right reduction: -inf
        entries are LEGITIMATE (vocab-padding mask, top-k truncation),
        but the row maximum is finite for any sane distribution and
        poisoned by any NaN. A guard trip withholds the slot's key/token
        commit entirely (its PRNG chain stays aligned with its COMMITTED
        token count, so the quarantine retry resumes bit-identically)
        and records the slot in ``_guard_bad`` for the engine to
        quarantine. Fault-plan ``nan`` injections overwrite the chosen
        slots' logits on device first, so drills flow through the same
        guard as real poison."""
        if self.faults is not None:
            bad = self.faults.nan_slots()
            if bad:
                rows = (jnp.arange(self.n_slots) if -1 in bad
                        else jnp.asarray(sorted(bad)))
                logits2d = jnp.asarray(logits2d).at[rows].set(jnp.nan)
        commit = mask
        if self.guards:
            # fused sampler variant: the guard's row-max reduction rides
            # the same dispatch as sampling, and the peak vector comes
            # back in the same host transfer as the tokens — the guarded
            # path costs no extra device round-trip over the unguarded
            # one (gated at <= 5% overhead by check_bench).
            toks, new_keys, peak_dev = sample_tokens_guarded(
                logits2d, self._temps, self._topks, self._keys, self._vocab)
            toks_h, peak = np.asarray(toks), np.asarray(peak_dev)
            if mask.any():
                trip = ~np.isfinite(peak) & mask
                if trip.any():
                    commit = mask & ~trip
                    for s in np.nonzero(trip)[0]:
                        self._guard_bad[int(s)] = (
                            f"non-finite logits (row max {peak[s]!r}) at "
                            f"slot {int(s)} — emission withheld")
        else:
            toks, new_keys = sample_tokens(logits2d, self._temps,
                                           self._topks, self._keys,
                                           self._vocab)
            toks_h = np.asarray(toks)
        m = jnp.asarray(commit)
        self._keys = jnp.where(m[:, None], new_keys, self._keys)
        self._last_tok = jnp.where(m[:, None], toks[:, None],
                                   self._last_tok)
        return toks_h

    # -- retire / preempt ------------------------------------------------

    def release(self, slot: int) -> None:
        """Free a finished slot: zero its cache length so the decode
        step's active mask freezes the lane (ISSUE 3: retired slots used
        to keep advancing their length and writing garbage KV every step —
        fatal under paging, where the stale page table points at pages
        that may already belong to another request), and return its
        pages."""
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        self._pending.pop(slot, None)
        self._slot_kept.pop(slot, None)
        if self.paged:
            self._pool.free(self._slot_pages.pop(slot))

    def quarantine(self, slot: int) -> None:
        """Invalidate prefix-index entries for every page this slot WROTE
        (everything after the shared prefix kept at admission): the
        slot's prompt pages were indexed when its prefill finalized, so a
        fault in the slot means other requests could match — and read —
        content it produced. Pages it only SHARED are untouched:
        copy-on-write guarantees a sharer never writes them, so their
        content predates the fault."""
        if self._prefix is None or slot not in self._slot_pages:
            return
        kept = self._slot_kept.get(slot, 0)
        written = self._slot_pages[slot][kept:]
        if written:
            self._prefix.invalidate(written, self._pool)

    def snapshot_request(self, slot: int, st, emitted) -> Request:
        """The resumable request, PURE (no freeze/free — ``snapshot``
        adds that): generated-so-far folds into the prompt (budget
        shrinks by the same amount) and the PRNG key chain is
        snapshotted into ``key_override``. Re-prefill of prompt +
        generated reproduces the exact cache the preempted decode had
        built — prefill/decode parity is the tested invariant.

        A slot caught MID-CHUNKED-PREFILL has emitted nothing: the
        original request re-queues whole (partial chunk writes are dead
        once the lane freezes), so the resumed run is bit-identical by
        construction."""
        req = st.req
        # guard the generated == 0 slice: [-0:] is the WHOLE list, and a
        # mid-chunk preemption is exactly the case that reaches it
        gen = emitted[-st.generated:] if st.generated else []
        return Request(
            req.rid, np.concatenate([req.tokens,
                                     np.asarray(gen, np.int32)]),
            req.max_new_tokens - st.generated, req.sampling, req.frontend,
            key_override=np.asarray(self._keys)[slot],
            priority=req.priority, on_token=req.on_token)

    def snapshot(self, slot: int, st, emitted) -> Request:
        """Preemption: build the resume request (``snapshot_request``),
        then freeze the slot (length 0) and return its pages to the pool
        immediately."""
        resumed = self.snapshot_request(slot, st, emitted)
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        self._pending.pop(slot, None)
        self._slot_kept.pop(slot, None)
        if self.paged:
            self._pool.free(self._slot_pages.pop(slot))
        return resumed

    def stats(self) -> dict:
        if not self.paged:
            return {}
        out = {"n_pages": self.n_pages, "n_free": self._pool.n_free,
               "watermark": self._pool.watermark,
               "grown": self._pool.n_grown}
        if self._prefix is not None:
            matched, matchable = self._tok_matched, self._tok_matchable
            out["prefix"] = {
                "entries": len(self._prefix),
                "cached_pages": self._prefix.n_cached(self._pool),
                "tokens_matched": matched,
                "tokens_matchable": matchable,
                "hit_rate": matched / matchable if matchable else 0.0,
                "cow_copies": self._n_cow,
                "evictions": self._prefix.n_evicted,
                "collisions_rejected": self._prefix.n_rejected,
                "invalidated": self._prefix.n_invalidated,
            }
        return out


class PairBatchBackend(Backend):
    """Batched Pairformer inference (FlashBias Sec. 4.4).

    A request is ONE COMPLEX: its payload is a float (n_res, F) residue
    feature array, its budget ``max_new_tokens`` is the number of
    refinement iterations, and its result is the final single
    representation (n_res, d_model). Admission runs the full trunk once
    (triangle updates, pair transitions) and caches each layer's pair-bias
    state per slot — factor-MLP phi_q/phi_k when ``factors`` is given
    (Eq. 5), truncated-SVD factors of the projected bias when not
    (Sec. 4.3), or the dense (H, N, N) bias itself under
    ``cfg.bias_mode="dense"`` (the A/B baseline). The pair representation
    z is then DISCARDED: steps run attention + transition over the single
    rep only, reusing the frozen factors — the factor cache is the
    Pairformer analogue of the LM path's KV cache.

    Batching pads every wave to ``max_len`` residues (pinned, like the LM
    ``prefill_len``) and masks attention per slot at its own ``n_res`` —
    factor-MLP biases are nonzero at padded residues, so the mask is
    load-bearing, not cosmetic. Preemption restarts a complex from scratch
    (nothing is emitted incrementally, so the re-run is trivially
    deterministic and the snapshot carries no device state).
    """

    def __init__(self, model: Model, params: dict, max_len: int,
                 n_slots: int, factors: Optional[dict] = None):
        self.model, self.params = model, params
        self.max_len, self.n_slots = max_len, n_slots
        self.factors = factors
        self._guard_bad: Dict[int, str] = {}
        self._cache = None

        def _pf(p, feats, lengths, factors, max_len):
            return model.prefill(p, {"feats": feats}, max_len=max_len,
                                 lengths=lengths, factors=factors)

        self._prefill = jax.jit(_pf, static_argnames=("max_len",))
        self._step = jax.jit(model.decode)
        self._insert = jax.jit(model.insert_cache)

    def ensure_state(self) -> None:
        if self._cache is None:
            self._cache = self.model.init_cache(self.n_slots, self.max_len,
                                                factors=self.factors)

    def validate(self, req: Request) -> None:
        if req.tokens.dtype != np.float32 or req.tokens.ndim != 2:
            raise AdmissionRejected(
                "pair request payload must be a float (n_res, F) feature "
                "array")
        if req.tokens.shape[0] > self.max_len:
            raise AdmissionRejected(
                f"complex has {req.tokens.shape[0]} residues; slot batch "
                f"is padded to max_len={self.max_len}")
        if req.frontend is not None:
            raise AdmissionRejected("pair requests carry no frontend")

    def admit(self, wave: List[Request], slots: List[int]):
        """Trunk pass over the padded wave; scatter per-layer bias state
        into the slot factor cache. Emits nothing (mask all-False): the
        budget counts refinement STEPS, and admission is step 0."""
        ns, w = self.n_slots, len(wave)
        f = wave[0].tokens.shape[1]
        feats = np.zeros((ns, self.max_len, f), np.float32)
        lengths = np.zeros((ns,), np.int32)
        for i, r in enumerate(wave):
            feats[i, :r.tokens.shape[0]] = r.tokens
            lengths[i] = r.tokens.shape[0]
        _, wave_cache = self._prefill(self.params, jnp.asarray(feats),
                                      jnp.asarray(lengths), self.factors,
                                      self.max_len)
        if self.guards:
            # admission-time factor guard (ISSUE 10): the trunk ran once
            # and its cached per-layer bias state is frozen for every
            # refinement step — a NaN/Inf here (bad features, unstable
            # factorization) poisons ALL of the request's steps, so catch
            # it now, per wave row, before the engine registers the slot
            flags = [jnp.isfinite(leaf).all(axis=tuple(range(1, leaf.ndim)))
                     for leaf in jax.tree_util.tree_leaves(wave_cache)
                     if jnp.issubdtype(leaf.dtype, jnp.floating)
                     and leaf.ndim >= 1 and leaf.shape[0] == ns]
            if flags:
                ok = np.asarray(functools.reduce(jnp.logical_and, flags))
                for i in range(w):
                    if not ok[i]:
                        self._guard_bad[slots[i]] = (
                            f"non-finite factor cache at admission of "
                            f"slot {slots[i]} (trunk produced NaN/Inf "
                            f"from the complex features)")
        slot_ids = np.full((ns,), ns, np.int32)    # padding rows -> dropped
        slot_ids[:w] = slots
        self._cache = self._insert(self._cache, wave_cache,
                                   jnp.asarray(slot_ids))
        return None, np.zeros((ns,), bool)

    def step(self, live):
        """One refinement iteration over every live slot (single jitted
        call; retired slots are frozen by their zero length)."""
        self._cache = self._step(self.params, self._cache)
        mask = np.zeros((self.n_slots,), bool)
        mask[list(live)] = True
        return None, mask

    def fetch_result(self, slot: int, st) -> np.ndarray:
        n = st.req.tokens.shape[0]
        return np.asarray(self._cache["s"][slot, :n], np.float32)

    def stream_result(self, slot: int, st) -> np.ndarray:
        """Per-iteration single rep for streaming sinks: the pair backend
        emits no tokens, so ``on_token`` subscribers drain the current
        (n_res, d_model) state after every refinement step instead of
        waiting for retirement."""
        return self.fetch_result(slot, st)

    def release(self, slot: int) -> None:
        self._cache["length"] = self._cache["length"].at[slot].set(0)

    def snapshot_request(self, slot: int, st, emitted) -> Request:
        """Preemption = restart: the resume request is the ORIGINAL with
        its full budget (no incremental output was emitted, so the
        re-run is deterministic by construction). Pure — ``snapshot``
        adds the freeze."""
        req = st.req
        return Request(req.rid, req.tokens, req.max_new_tokens,
                       req.sampling, req.frontend, priority=req.priority,
                       on_token=req.on_token)

    def snapshot(self, slot: int, st, emitted) -> Request:
        resumed = self.snapshot_request(slot, st, emitted)
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        return resumed
