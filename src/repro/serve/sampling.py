"""Per-slot token sampling: greedy / temperature / top-k.

All slots are sampled in ONE fused call over the (B_slots, V) logits; each
slot carries its own (temperature, top_k, PRNG key), so a request's sample
stream is a pure function of its own seed — bit-identical whether the
request runs alone or packed into a busy batch. The engine's parity test
relies on this: the sampler consumes one key split per slot per call, and
the engine commits the new key only for slots that actually emitted a
token, keeping every request's key chain aligned with its token count.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "sample_tokens", "sample_tokens_guarded"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 selects greedy; top_k == 0 keeps the full vocab."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        assert self.temperature >= 0.0, self.temperature
        assert self.top_k >= 0, self.top_k


@functools.partial(jax.jit, static_argnums=(4,))
def sample_tokens(logits, temps, top_ks, keys, vocab: int):
    """Sample one token per slot.

    logits (B, V); temps (B,) f32; top_ks (B,) int32; keys (B, 2) uint32;
    ``vocab`` masks TP-padded vocab rows so padding ids can never be
    emitted. Returns (tokens (B,) int32, new_keys (B, 2)).
    """
    b, v = logits.shape
    logits = logits.astype(jnp.float32)
    if vocab < v:
        logits = jnp.where(jnp.arange(v) >= vocab, -jnp.inf, logits)
    greedy = jnp.argmax(logits, axis=-1)

    # per-slot top-k truncation via the k-th largest logit as threshold;
    # the O(V log V) sort only runs when some slot actually asked for it
    def _truncate(lg):
        sorted_desc = jnp.sort(lg, axis=-1)[:, ::-1]
        kth = jnp.take_along_axis(
            sorted_desc, jnp.clip(top_ks - 1, 0, v - 1)[:, None], axis=-1)
        trunc = jnp.where(lg < kth, -jnp.inf, lg)
        return jnp.where((top_ks > 0)[:, None], trunc, lg)

    logits = jax.lax.cond(jnp.any(top_ks > 0), _truncate, lambda lg: lg,
                          logits)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]

    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)    # (B, 2, 2)
    use, carry = split[:, 0], split[:, 1]
    sampled = jax.vmap(jax.random.categorical)(use, scaled)
    tok = jnp.where(temps > 0.0, sampled, greedy)
    return tok.astype(jnp.int32), carry


@functools.partial(jax.jit, static_argnums=(4,))
def sample_tokens_guarded(logits, temps, top_ks, keys, vocab: int):
    """``sample_tokens`` plus the per-slot RAW-logit row maximum, fused
    into one dispatch. The row max is the non-finite guard's reduction
    (-inf entries are legitimate — masking, top-k — but the max is finite
    for any sane row and poisoned by any NaN); fusing it here instead of
    issuing a second ``jnp.max`` call keeps the guarded decode path at
    one device round-trip per step, which is what holds the guard's cost
    under the benchmark gate's 5% budget."""
    peak = jnp.max(logits.astype(jnp.float32), axis=-1)
    tok, carry = sample_tokens(logits, temps, top_ks, keys, vocab)
    return tok, carry, peak
