"""Content-hashed prefix index over completed prompt pages (ISSUE 9).

Maps hash chains over ``page_size`` token-id blocks to physical page ids,
so a request whose prompt starts with an already-served prefix (system
prompt, few-shot template) can point its page table at existing pages and
prefill only the novel tail. FlashBias makes the sharing total: the
per-page ``pages_phi`` factor slab is position-only (Sec. 4.3 / Thm 3.2),
so a cached page already carries its bias factors — nothing is recomputed
per sharer.

This module is HOST-ONLY (statcheck ``host-jnp``): pure-python dict walk
over numpy token blocks, no jax, no device sync. The device-side content
never moves on a hit — sharing is page-table indirection plus a
``PagePool.incref``.

Chain keys: ``key_i = H(key_{i-1} || block_i)``, so a block's key commits
to every token before it and two prefixes share entries exactly as far as
their tokens agree. A hit is only trusted after a FULL token-block compare
against the entry's stored block (hash-collision safety) — the chain makes
the inductive step sound: block ``i`` is compared directly, blocks
``< i`` were compared when their entries matched.

The index holds its own reference on every registered page (cache
retention past request retirement, vLLM-style). Index-only pages
(``refcount == 1``) are *evictable*: when the pool runs short the backend
asks ``evict`` to drop least-recently-used leaf entries until enough pages
drain. Leaf-first eviction keeps the chain invariant — an entry is never
orphaned behind a missing parent.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.pages import PagePool

__all__ = ["PrefixCache"]

_ROOT = b"prefix-cache-root"


def _blake_chain(parent: bytes, block: bytes) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(block)
    return h.digest()


class _Entry:
    __slots__ = ("page", "block", "parent", "children", "last_use")

    def __init__(self, page: int, block: bytes, parent: bytes):
        self.page = page
        self.block = block            # raw int32 bytes: full-compare on hit
        self.parent = parent          # parent chain key (b"" sentinel: root)
        self.children = 0             # live child entries (leaf == 0)
        self.last_use = 0


class PrefixCache:
    """Hash-chain index: completed full prompt pages, keyed by content.

    ``digest`` is injectable so tests can force collisions and prove the
    full token-block compare rejects them.
    """

    def __init__(self, page_size: int,
                 digest: Optional[Callable[[bytes, bytes], bytes]] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._digest = digest or _blake_chain
        self._entries: Dict[bytes, _Entry] = {}
        self._clock = 0               # monotonic touch counter (LRU)
        self.n_evicted = 0
        self.n_rejected = 0           # hash hits rejected by block compare
        self.n_invalidated = 0        # entries dropped by invalidate()

    def __len__(self) -> int:
        return len(self._entries)

    def _blocks(self, tokens: np.ndarray) -> List[bytes]:
        toks = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
        n_full = toks.size // self.page_size
        ps = self.page_size
        return [toks[i * ps:(i + 1) * ps].tobytes() for i in range(n_full)]

    def _touch(self, entry: _Entry) -> None:
        self._clock += 1
        entry.last_use = self._clock

    # ------------------------------------------------------------------
    # Lookup / registration
    # ------------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``tokens`` in whole ``page_size``
        blocks: ``(pages, matched_tokens)``. Matched entries are touched
        so an actively shared prefix never ages to the eviction front."""
        pages: List[int] = []
        key = _ROOT
        for block in self._blocks(tokens):
            key = self._digest(key, block)
            entry = self._entries.get(key)
            if entry is None:
                break
            if entry.block != block:          # hash collision: reject hit
                self.n_rejected += 1
                break
            self._touch(entry)
            pages.append(entry.page)
        return pages, len(pages) * self.page_size

    def insert(self, tokens: np.ndarray, pages: List[int],
               pool: PagePool) -> int:
        """Register the full prompt pages of a landed prompt. Blocks the
        chain already indexes are left pointing at their original page
        (same-wave duplicates keep their private pages — no remap after
        the fact); each NEW entry takes an index reference on its page.
        Returns the number of entries added."""
        added = 0
        key = _ROOT
        for i, block in enumerate(self._blocks(tokens)):
            parent_key, key = key, self._digest(key, block)
            entry = self._entries.get(key)
            if entry is not None:
                if entry.block != block:      # collision: keep old entry
                    self.n_rejected += 1
                    break
                self._touch(entry)
                continue
            entry = _Entry(pages[i], block, parent_key)
            self._touch(entry)
            self._entries[key] = entry
            pool.incref([pages[i]])
            if parent_key != _ROOT:
                self._entries[parent_key].children += 1
            added += 1
        return added

    # ------------------------------------------------------------------
    # Retention / eviction
    # ------------------------------------------------------------------

    def n_cached(self, pool: PagePool) -> int:
        """Pages held ONLY by the index (refcount 1): retained cache."""
        return sum(1 for e in self._entries.values()
                   if pool.refcount(e.page) == 1)

    def n_evictable(self, pool: PagePool) -> int:
        """Pages leaf-first ``evict`` can actually drain under pressure.

        Not every index-only page qualifies: an entry whose DESCENDANT has
        a live sharer (refcount >= 2) is pinned — the descendant is never
        evicted, so the chain above it can never become a leaf. (The state
        arises via copy-on-write: a sharer's table holds private copies of
        some matched pages, referencing only the deepest shared one.) The
        engine's preemption gate keys off this number, so overcounting
        here turns backpressure into a pool-exhaustion crash."""
        pinned = set()
        for entry in self._entries.values():
            if pool.refcount(entry.page) >= 2:
                key = entry.parent
                while key != _ROOT and key not in pinned:
                    pinned.add(key)
                    key = self._entries[key].parent
        return sum(1 for k, e in self._entries.items()
                   if k not in pinned and pool.refcount(e.page) == 1)

    def invalidate(self, pages: List[int], pool: PagePool) -> int:
        """Drop every entry whose page is in ``pages`` — plus ALL its
        descendants — and release the index's reference on each dropped
        page. The quarantine hook (ISSUE 10): when a slot faults
        (non-finite guard trip), every page it WROTE since admission is
        suspect, and so is every chain entry hanging below one — a chain
        key commits to the tokens, not the content, so a poisoned page
        would keep serving sharers forever if the entry survived.

        Pages a live sharer still holds stay allocated (the decref only
        removes the index's claim) — their content is safe for THOSE
        sharers because copy-on-write means a sharer never writes a page
        it shares; invalidation only stops NEW requests from matching
        entries whose content a faulting slot produced. Returns the
        number of entries dropped."""
        suspect = set(pages)
        doomed = {k for k, e in self._entries.items() if e.page in suspect}
        # descendants: an entry is reachable only through its parent, so
        # anything below a doomed entry must go too (and would otherwise
        # leak its index reference forever)
        changed = True
        while changed:
            changed = False
            for k, e in self._entries.items():
                if k not in doomed and e.parent in doomed:
                    doomed.add(k)
                    changed = True
        for k in doomed:
            entry = self._entries.pop(k)
            if entry.parent != _ROOT and entry.parent in self._entries:
                self._entries[entry.parent].children -= 1
            pool.free([entry.page])
        self.n_invalidated += len(doomed)
        return len(doomed)

    def evict(self, pool: PagePool, need: int) -> int:
        """Drop least-recently-used LEAF entries whose page has no holder
        but the index, until ``need`` pages drained or nothing is left to
        evict. Entries with live sharers (refcount > 1) are never touched.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < need:
            victim_key = None
            victim = None
            for k, e in self._entries.items():
                if e.children == 0 and pool.refcount(e.page) == 1:
                    if victim is None or e.last_use < victim.last_use:
                        victim_key, victim = k, e
            if victim is None:
                break
            del self._entries[victim_key]
            if victim.parent != _ROOT:
                self._entries[victim.parent].children -= 1
            freed += len(pool.free([victim.page]))
            self.n_evicted += 1
        return freed
