"""FIFO request scheduler for the continuous-batching engine.

Host-side and deliberately dumb: requests join a FIFO queue; whenever the
engine has freed slots it asks for the next admission wave. Admission never
reorders (no head-of-line bypass, no length bucketing), so a request's
admission step is a pure function of the arrival order — which keeps the
engine's per-request reproducibility contract easy to reason about.
Smarter policies (shortest-prompt-first, prefill/decode interleaving
budgets) can swap in behind the same two-method surface.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serve.sampling import SamplingParams

__all__ = ["Request", "FIFOScheduler"]


@dataclasses.dataclass
class Request:
    """One generation request (host-side descriptor)."""
    rid: int
    tokens: np.ndarray                        # (T,) int32 prompt
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    frontend: Optional[np.ndarray] = None     # (F, D) precomputed embeddings

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        assert self.tokens.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1, self.max_new_tokens

    @property
    def prompt_len(self) -> int:
        """Valid prefix length (frontend embeddings included)."""
        front = 0 if self.frontend is None else self.frontend.shape[0]
        return front + int(self.tokens.size)


class FIFOScheduler:
    """Arrival-order admission into freed slots."""

    def __init__(self):
        self._queue: Deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._queue)

    def add(self, req: Request) -> None:
        self._queue.append(req)

    def peek(self) -> Optional[Request]:
        """Head of the queue without popping (None when empty) — lets the
        engine gate admission on resources (free pages) without reordering."""
        return self._queue[0] if self._queue else None

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in arrival order."""
        wave = []
        while self._queue and len(wave) < n:
            wave.append(self._queue.popleft())
        return wave
