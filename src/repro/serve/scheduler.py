"""Request scheduler for the continuous-batching engine.

Host-side and deliberately simple: requests join a queue; whenever the
engine has freed slots it asks for the next admission wave. The default
``policy="fifo"`` never reorders (no head-of-line bypass, no length
bucketing), so a request's admission step is a pure function of the arrival
order — which keeps the engine's per-request reproducibility contract easy
to reason about. ``policy="spf"`` (shortest-prompt-first) is an opt-in
toggle that admits the queued request with the smallest prompt first
(stable: ties break on arrival order) — it trades the arrival-order
guarantee for lower head-of-line blocking when prompts are wildly mixed.

Preempted requests re-enter through ``add_front`` and always resume BEFORE
any queued arrival, under either policy: a preempted request already spent
pool pages and prefill FLOPs once, so letting arrivals overtake it would
both starve it and re-inflate the very memory pressure that forced the
preemption. Within the front queue, lower request ids (earlier arrivals)
stay ahead — preemption priority is arrival order, so resume priority is
too. Smarter policies (prefill/decode interleaving budgets) can swap in
behind the same surface.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.serve.sampling import SamplingParams

__all__ = ["Request", "FIFOScheduler"]


@dataclasses.dataclass
class Request:
    """One generation request (host-side descriptor).

    ``key_override`` carries a preempted request's PRNG key snapshot: the
    sampler consumes one split per emitted token, so resuming from the
    snapshot (instead of re-seeding from ``sampling.seed``) keeps the
    sample stream bit-identical to the run that was never preempted.
    """
    rid: int
    tokens: np.ndarray                        # (T,) int32 prompt
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    frontend: Optional[np.ndarray] = None     # (F, D) precomputed embeddings
    key_override: Optional[np.ndarray] = None  # (2,) uint32 resume PRNG key

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        assert self.tokens.size >= 1, "empty prompt"
        assert self.max_new_tokens >= 1, self.max_new_tokens

    @property
    def prompt_len(self) -> int:
        """Valid prefix length (frontend embeddings included)."""
        front = 0 if self.frontend is None else self.frontend.shape[0]
        return front + int(self.tokens.size)


class FIFOScheduler:
    """Admission into freed slots: FIFO by default, optional SPF toggle."""

    def __init__(self, policy: str = "fifo"):
        assert policy in ("fifo", "spf"), policy
        self.policy = policy
        self._front: Deque[Request] = deque()   # preempted, resume first
        self._queue: Deque[Request] = deque()   # arrivals

    def __len__(self) -> int:
        return len(self._front) + len(self._queue)

    def add(self, req: Request) -> None:
        self._queue.append(req)

    def add_front(self, req: Request) -> None:
        """Re-queue a preempted request ahead of every arrival. Earlier
        arrivals (lower rid) stay ahead within the front queue, matching
        the engine's preemption priority."""
        i = 0
        while i < len(self._front) and self._front[i].rid < req.rid:
            i += 1
        self._front.insert(i, req)

    def _pick(self) -> int:
        """Index into ``_queue`` of the next request under ``policy``
        (-1 when empty). Callers drain ``_front`` first."""
        if not self._queue:
            return -1
        if self.policy == "spf":
            return min(range(len(self._queue)),
                       key=lambda i: (self._queue[i].prompt_len, i))
        return 0

    def peek(self) -> Optional[Request]:
        """Next request without popping (None when empty) — lets the
        engine gate admission on resources (free pages) without losing
        its place in the queue."""
        if self._front:
            return self._front[0]
        i = self._pick()
        return None if i == -1 else self._queue[i]

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in policy order (front queue first)."""
        wave: List[Request] = []
        while len(wave) < n:
            if self._front:
                wave.append(self._front.popleft())
                continue
            i = self._pick()
            if i == -1:
                break
            self._queue.rotate(-i)
            wave.append(self._queue.popleft())
            self._queue.rotate(i)
        return wave
