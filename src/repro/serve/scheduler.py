"""Request scheduler for the continuous-batching engine.

Host-side and deliberately simple: requests join a queue; whenever the
engine has freed slots it asks for the next admission wave. The default
``policy="fifo"`` never reorders within a priority class (no head-of-line
bypass, no length bucketing), so a request's admission step is a pure
function of the arrival order — which keeps the engine's per-request
reproducibility contract easy to reason about. ``policy="spf"``
(shortest-prompt-first) is an opt-in toggle that admits the queued request
with the smallest prompt first (stable: ties break on arrival order) — it
trades the arrival-order guarantee for lower head-of-line blocking when
prompts are wildly mixed.

Priority classes (ISSUE 6): ``Request.priority`` (higher = more urgent,
default 0) is the OUTER sort key under either policy — the scheduler
drains class by class, FIFO/SPF *within* a class. When every request
carries the default priority the order is bit-identical to the pre-class
scheduler, so the determinism contract's arrival-order reasoning is
unchanged for existing callers. The engine's preemption victim hook is the
mirror image: it evicts the LOWEST class first (latest arrival within the
class), so (priority, arrival) stays a total order and the earliest
request of the highest class always makes progress — no livelock.

Preempted requests re-enter through ``add_front`` and always resume BEFORE
any queued arrival of any class: a preempted request already spent pool
pages and prefill FLOPs once, so letting arrivals overtake it would both
starve it and re-inflate the very memory pressure that forced the
preemption. Within the front queue, higher classes stay ahead and lower
request ids (earlier arrivals) break ties — resume order mirrors
preemption order. Smarter policies (prefill/decode interleaving budgets)
can swap in behind the same surface.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np

from repro.serve.lifecycle import AdmissionRejected
from repro.serve.sampling import SamplingParams

__all__ = ["Request", "ChunkPlan", "FIFOScheduler"]


@dataclasses.dataclass
class Request:
    """One generation request (host-side descriptor).

    ``tokens`` is the request payload: an integer array is a (T,) token
    prompt (LM backends); a FLOAT array is kept float32 as-is — e.g. a
    Pairformer complex's (n_res, F) residue features — and ``prompt_len``
    reads its leading axis.

    ``key_override`` carries a preempted request's PRNG key snapshot: the
    sampler consumes one split per emitted token, so resuming from the
    snapshot (instead of re-seeding from ``sampling.seed``) keeps the
    sample stream bit-identical to the run that was never preempted.

    ``priority``: higher admits first and preempts last; 0 is the default
    class, negative classes are valid (scavenger traffic).

    ``on_token``: optional streaming callback, invoked by the engine once
    per budget unit the request advances — with the emitted token id for
    token backends, or the backend's ``stream_result`` (e.g. the current
    single representation) for non-emitting backends. It rides the request
    descriptor so preemption/resume keeps the stream attached.
    """
    rid: int
    tokens: np.ndarray                        # (T,) int32 prompt | float feats
    max_new_tokens: int
    sampling: SamplingParams = SamplingParams()
    frontend: Optional[np.ndarray] = None     # (F, D) precomputed embeddings
    key_override: Optional[np.ndarray] = None  # (2,) uint32 resume PRNG key
    priority: int = 0
    on_token: Optional[Callable] = None       # streaming sink (per step)

    def __post_init__(self):
        arr = np.asarray(self.tokens)
        if np.issubdtype(arr.dtype, np.floating):
            self.tokens = np.asarray(arr, np.float32)
            if self.tokens.ndim < 1 or self.tokens.shape[0] < 1:
                raise AdmissionRejected("empty feature payload")
        else:
            self.tokens = np.asarray(arr, np.int32).reshape(-1)
            if self.tokens.size < 1:
                raise AdmissionRejected("empty prompt")
        if self.max_new_tokens < 1:
            raise AdmissionRejected(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        """Valid prefix length (frontend embeddings included)."""
        front = 0 if self.frontend is None else self.frontend.shape[0]
        return front + int(self.tokens.shape[0])

    @property
    def _order(self):
        """Queue sort key: higher class first, earlier arrival within it."""
        return (-self.priority, self.rid)


@dataclasses.dataclass
class ChunkPlan:
    """Host-side prefill plan of one admitted-but-not-yet-decoding request.

    Chunked prefill (ISSUE 7) turns admission into planning: the backend
    reserves the slot's resources (pages, sampling state) up front, then
    feeds the prompt ``chunk`` tokens at a time — one chunk per engine
    step, interleaved with the decode batch — so a long arrival never
    stalls in-flight requests. ``done`` is the prompt prefix already in
    the slot cache; the final chunk flips the device-side length from 0
    (frozen lane) to the full prompt length and samples the first token.
    """
    req: Request
    done: int = 0                             # prompt tokens prefilled so far

    @property
    def remaining(self) -> int:
        return int(self.req.tokens.size) - self.done

    def next_chunk(self, chunk: int):
        """Advance the plan one chunk; returns (offset, tokens, final)."""
        n = min(chunk, self.remaining)
        off = self.done
        toks = self.req.tokens[off:off + n]
        self.done += n
        return off, toks, self.remaining == 0


class FIFOScheduler:
    """Admission into freed slots: priority classes, FIFO (or SPF) within."""

    def __init__(self, policy: str = "fifo"):
        if policy not in ("fifo", "spf"):
            raise ValueError(f"scheduler policy must be 'fifo' or 'spf', "
                             f"got {policy!r}")
        self.policy = policy
        self._front: Deque[Request] = deque()   # preempted, resume first
        self._queue: Deque[Request] = deque()   # arrivals

    def __len__(self) -> int:
        return len(self._front) + len(self._queue)

    def add(self, req: Request) -> None:
        self._queue.append(req)

    def add_front(self, req: Request) -> None:
        """Re-queue a preempted request ahead of every arrival. Higher
        classes stay ahead within the front queue; earlier arrivals (lower
        rid) break ties — matching the engine's preemption order."""
        i = 0
        while i < len(self._front) and self._front[i]._order < req._order:
            i += 1
        self._front.insert(i, req)

    def _pick(self) -> int:
        """Index into ``_queue`` of the next request under ``policy``
        (-1 when empty). Callers drain ``_front`` first. The class is the
        outer key; with all-default priorities this reduces exactly to the
        classless pick (index 0 / shortest prompt)."""
        if not self._queue:
            return -1
        if self.policy == "spf":
            return min(range(len(self._queue)),
                       key=lambda i: (-self._queue[i].priority,
                                      self._queue[i].prompt_len, i))
        return min(range(len(self._queue)),
                   key=lambda i: (-self._queue[i].priority, i))

    def peek(self) -> Optional[Request]:
        """Next request without popping (None when empty) — lets the
        engine gate admission on resources (free pages) without losing
        its place in the queue."""
        if self._front:
            return self._front[0]
        i = self._pick()
        return None if i == -1 else self._queue[i]

    def remove(self, rid: int) -> Optional[Request]:
        """Drop the queued request with id ``rid`` (front or arrival
        queue). Returns the removed request, or None when ``rid`` is not
        queued — cancellation and deadline expiry of requests that never
        reached a slot (ISSUE 10)."""
        for q in (self._front, self._queue):
            for i, r in enumerate(q):
                if r.rid == rid:
                    del q[i]
                    return r
        return None

    def queued(self) -> List[Request]:
        """Every queued request, front queue first (inspection only —
        deadline sweeps and engine checkpoints walk this without
        popping)."""
        return list(self._front) + list(self._queue)

    def snapshot(self) -> Tuple[List[Request], List[Request]]:
        """(front, arrivals) in queue order — the engine checkpoint
        serializes these; ``restore`` rebuilds the exact state."""
        return list(self._front), list(self._queue)

    def restore(self, front: List[Request],
                arrivals: List[Request]) -> None:
        """Replace the queue state with a ``snapshot``'s content."""
        self._front = deque(front)
        self._queue = deque(arrivals)

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in policy order (front queue first)."""
        wave: List[Request] = []
        while len(wave) < n:
            if self._front:
                wave.append(self._front.popleft())
                continue
            i = self._pick()
            if i == -1:
                break
            self._queue.rotate(-i)
            wave.append(self._queue.popleft())
            self._queue.rotate(i)
        return wave
