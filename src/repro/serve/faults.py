"""Deterministic fault injection for the serve engine (ISSUE 10).

A ``FaultPlan`` is a seeded, fully host-side schedule of faults the
engine and backends consult at well-defined points of each step:

- ``alloc`` — the next page allocation (admission reservation or lazy
  growth) raises ``PoolExhausted`` before touching the pool, exercising
  the preempt-on-exhaustion containment path.
- ``nan``  — a chosen slot's decode logits are overwritten with NaN on
  device before sampling, exercising the NaN guard + quarantine path
  with a *real* non-finite value flowing through the real guard.
- ``step`` — the jitted decode dispatch is replaced by an
  ``InjectedFault`` raise, exercising step-failure containment (nothing
  advanced, so retrying next iteration is trivially safe).
- ``delay`` — admission is skipped this step (queued requests wait),
  exercising deadline expiry and stall accounting.

Faults are addressed by ENGINE STEP (the ``ServeEngine.step_idx``
counter ticks the plan once per step), optionally by slot, and stay
armed for ``count`` consecutive steps — so a drill is a pure function
of (plan, traffic): re-running the same seed replays the same faults at
the same points, which is what lets the chaos suite assert bit-identical
survivor outputs against a fault-free run.

``FaultPlan.parse`` accepts the CLI grammar used by ``launch/serve.py
--inject-fault``: comma-separated ``kind@step[/slot][xcount]`` specs,
e.g. ``"nan@12/0, alloc@5x3, step@20"``.

Host-only (statcheck ``host-jnp`` / ``host-assert``): the plan never
touches jax — backends apply ``nan`` injections on device themselves.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultPlan"]

FAULT_KINDS = ("alloc", "nan", "step", "delay")

_SPEC_RE = re.compile(
    r"^(?P<kind>[a-z]+)@(?P<step>\d+)(?:/(?P<slot>-?\d+))?"
    r"(?:x(?P<count>\d+))?$")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: ``kind`` fires on engine steps
    ``[step, step + count)``; ``slot`` targets one lane (``nan`` only;
    -1 hits every slot)."""
    kind: str
    step: int
    slot: int = -1
    count: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if self.step < 0 or self.count < 1:
            raise ValueError(f"fault needs step >= 0, count >= 1: {self}")

    def active(self, step_idx: int) -> bool:
        return self.step <= step_idx < self.step + self.count

    def spec_str(self) -> str:
        """Round-trips through ``FaultPlan.parse``."""
        out = f"{self.kind}@{self.step}"
        if self.slot != -1:
            out += f"/{self.slot}"
        if self.count != 1:
            out += f"x{self.count}"
        return out


class FaultPlan:
    """A deterministic schedule of ``FaultSpec``s plus a firing log.

    The engine calls ``tick(step_idx)`` once per step; the query methods
    (``alloc_fails`` / ``nan_slots`` / ``step_fails`` /
    ``admission_delayed``) answer for the current step and append every
    positive answer to ``fired`` — ``(step, kind, slot)`` tuples the
    chaos suite asserts on to prove each injection actually reached its
    containment path.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.step_idx = -1                 # before the first tick
        self.fired: List[Tuple[int, str, int]] = []

    # -- constructors ---------------------------------------------------

    @classmethod
    def parse(cls, text: Optional[str]) -> "FaultPlan":
        """CLI grammar: comma-separated ``kind@step[/slot][xcount]``.
        Empty/None parses to a no-fault plan."""
        specs = []
        for part in (text or "").split(","):
            part = part.strip()
            if not part:
                continue
            m = _SPEC_RE.match(part)
            if m is None:
                raise ValueError(
                    f"bad fault spec {part!r} — want "
                    f"kind@step[/slot][xcount], kind in {FAULT_KINDS}")
            specs.append(FaultSpec(
                m.group("kind"), int(m.group("step")),
                slot=int(m.group("slot") or -1),
                count=int(m.group("count") or 1)))
        return cls(specs)

    @classmethod
    def random(cls, seed: int, n_steps: int, n_slots: int,
               n_faults: int = 4,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """Seeded random plan: ``n_faults`` draws of (kind, step, slot)
        uniform over ``kinds`` x ``[0, n_steps)`` x ``[0, n_slots)`` —
        the chaos suite's generator (same seed => same drill)."""
        rng = np.random.RandomState(seed)
        specs = [FaultSpec(kinds[int(rng.randint(len(kinds)))],
                           int(rng.randint(max(1, n_steps))),
                           slot=int(rng.randint(max(1, n_slots))),
                           count=int(rng.randint(1, 3)))
                 for _ in range(n_faults)]
        return cls(specs)

    # -- engine hooks ---------------------------------------------------

    def tick(self, step_idx: int) -> None:
        self.step_idx = int(step_idx)

    def _fire(self, kind: str, slot: int = -1) -> None:
        self.fired.append((self.step_idx, kind, slot))

    def _active(self, kind: str) -> List[FaultSpec]:
        return [s for s in self.specs
                if s.kind == kind and s.active(self.step_idx)]

    def alloc_fails(self) -> bool:
        """True: the next pool allocation must raise ``PoolExhausted``."""
        hit = bool(self._active("alloc"))
        if hit:
            self._fire("alloc")
        return hit

    def nan_slots(self) -> List[int]:
        """Slots whose logits get NaN-poisoned this step (-1 = all)."""
        slots = sorted({s.slot for s in self._active("nan")})
        for s in slots:
            self._fire("nan", s)
        return slots

    def step_fails(self) -> bool:
        """True: this step's decode dispatch raises ``InjectedFault``."""
        hit = bool(self._active("step"))
        if hit:
            self._fire("step")
        return hit

    def admission_delayed(self) -> bool:
        """True: skip admission this step (queued requests keep waiting)."""
        hit = bool(self._active("delay"))
        if hit:
            self._fire("delay")
        return hit

    def spec_str(self) -> str:
        return ",".join(s.spec_str() for s in self.specs)

    def __repr__(self):
        return f"FaultPlan({self.spec_str()!r}, step={self.step_idx})"
