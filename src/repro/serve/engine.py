"""Continuous-batching serve engine over the ragged flash-decode path.

The engine owns ``n_slots`` decode lanes. Each slot is one batch row of
every cache leaf — a ``max_len`` KV segment (ring window / SSM state for
those families), its own ``length`` entry, sampling state (temperature,
top-k, PRNG key chain) and an output buffer. A FIFO scheduler admits
queued requests into freed slots; each admission wave is prefilled
right-padded (batch padded to ``n_slots`` and prompt padded to the wave
maximum or a pinned ``prefill_len``, so at most a handful of prefill
programs ever compile) and scattered into the slot cache with
``Model.insert_cache``. Decode is ONE jitted step over the full slot batch
every iteration — per-request raggedness rides in the ``lengths`` vector
the flash-decode kernel block-skips on — so arbitrary arrival/finish
patterns never recompile and never stall on the slowest request.

Determinism contract (tested in tests/test_serve_engine.py): every
per-slot computation is batch-row independent and the sampler key chain is
per-request, so a request's output is identical whether it runs alone or
packed with strangers — provided ``prefill_len`` is pinned (the padded
prompt length is the one shape that changes with wave composition).

Cache kinds (all pytrees, all jit-traceable):

- full KV            (dense/moe archs)        — (L, B, S_max, KV, hd),
- paged KV           (full-KV + ``page_size``) — shared (L, n_pages, ps,
  KV, hd) pool + per-page phi_k factor slab + per-slot page tables,
- ring KV            (sliding-window archs)   — (L, B, window, KV, hd),
- SSM state + conv   (ssm/hybrid archs)       — constant size.

Paged mode (pass ``page_size``) replaces the per-slot ``max_len`` segment
with a vLLM-style shared page pool: admission is gated on free pages (the
PR-2 ``prompt + budget <= max_len`` assert is gone), a request's pages are
reserved whole at admit and freed the step it finishes, and retired slots
are frozen via the length-0 active mask so a stale page table can never
scribble on reallocated pages. See serve/README.md §Paged KV.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.pages import PagePool
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine"]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied decode lane."""
    req: Request
    generated: int = 0


class ServeEngine:
    """Slot-based continuous-batching engine (prefill/decode/sample).

    Args:
        model: a decode-capable ``Model`` (prefill/decode/init_cache/
            insert_cache).
        params: parameter pytree.
        max_len: per-slot cache segment length (prompt + decode budget must
            fit for full-KV families).
        eos_id: generation stops when this id is sampled (it is kept in the
            output; remaining columns of ``generate`` pad with it). -1
            never matches, i.e. requests always run out their budget.
        n_slots: fixed decode batch — the number of concurrent requests.
        prefill_len: pinned padded prompt length. None pads each admission
            wave to its own maximum (fewest wasted FLOPs); pinning it makes
            request outputs independent of wave composition and bounds
            prefill compiles to one.
        page_size: enables PAGED KV for full-KV families — the cache
            becomes a shared pool of ``n_pages`` pages of ``page_size``
            tokens (K, V, and the per-page phi_k factor slab), admission is
            gated on free pages instead of the slot-segment bound, and a
            request may exceed ``max_len`` as long as its pages fit. Ring-KV
            and SSM-only families ignore it (their caches are already
            constant-size per slot).
        n_pages: pool size; defaults to ``n_slots * ceil(max_len /
            page_size)`` — the same HBM the contiguous layout would commit.
        pages_per_slot: page-table width = one request's max page count.
            Defaults to ``n_pages`` (a lone request may take the whole
            pool); lower it to bound the per-step logical view.
    """

    def __init__(self, model: Model, params: dict, max_len: int = 1024,
                 eos_id: int = -1, n_slots: int = 4,
                 prefill_len: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 pages_per_slot: Optional[int] = None):
        assert model.prefill is not None and model.decode is not None, \
            "model is not decode-capable"
        self.model, self.params = model, params
        self.max_len, self.eos_id = max_len, eos_id
        self.n_slots, self.prefill_len = n_slots, prefill_len
        cfg = model.cfg
        self._vocab = cfg.vocab
        self._front_dim = (cfg.frontend_len, cfg.d_model)
        # full-KV families must fit prompt + budget inside the slot segment
        # (contiguous mode) or inside the page pool (paged mode)
        self._bounded_cache = (cfg.family in ("dense", "moe", "hybrid")
                               and not (cfg.window and cfg.window < max_len))
        self._paged = (page_size is not None and self._bounded_cache
                       and model.init_paged_cache is not None)
        if self._paged:
            self.page_size = page_size
            self.n_pages = n_pages or n_slots * _ceil_to(max_len,
                                                         page_size) // page_size
            self.pages_per_slot = min(pages_per_slot or self.n_pages,
                                      self.n_pages)
            self._pool = PagePool(self.n_pages, page_size)
            self._slot_pages: Dict[int, List[int]] = {}
        self.scheduler = FIFOScheduler()
        self._next_rid = 0
        self._results: Dict[int, List[int]] = {}
        self._done: Dict[int, bool] = {}
        self._live: Dict[int, _Slot] = {}         # slot -> _Slot
        self._free: List[int] = list(range(n_slots))
        self._cache = None                        # allocated on first step

        def _pf(p, toks, front, lengths, max_len):
            batch = {"tokens": toks}
            if front is not None:
                batch["frontend"] = front
            return model.prefill(p, batch, max_len=max_len, lengths=lengths)

        self._prefill = jax.jit(_pf, static_argnames=("max_len",))
        self._decode = jax.jit(model.decode)
        self._insert = jax.jit(model.insert_cache)
        if self._paged:
            self._insert_paged = jax.jit(model.insert_paged)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               frontend: Optional[np.ndarray] = None) -> int:
        """Queue one request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(tokens), max_new_tokens,
                      sampling or SamplingParams(), frontend)
        if self.prefill_len is not None:
            assert req.tokens.size <= self.prefill_len, \
                (req.tokens.size, self.prefill_len)
        if self._bounded_cache and self._paged:
            # paged: the only hard bound is the request's own page-table
            # row — prompt + budget may exceed max_len (the PR-2 segment
            # bound is gone); admission waits for free pages instead
            needed = self._pages_needed(req)
            assert needed <= self.pages_per_slot, \
                f"request needs {needed} pages " \
                f"(prompt {req.prompt_len} + budget {max_new_tokens}), " \
                f"page table holds {self.pages_per_slot}"
        elif self._bounded_cache:
            assert req.prompt_len + max_new_tokens <= self.max_len, \
                f"prompt {req.prompt_len} + budget {max_new_tokens} " \
                f"exceeds slot segment {self.max_len}"
        # ring-KV keeps only the last `window` keys and SSM state is
        # constant-size, so those families accept prompts of any length
        self._results[rid] = []
        self._done[rid] = False
        self.scheduler.add(req)
        return rid

    def result(self, rid: int) -> np.ndarray:
        """Generated ids so far for ``rid`` (complete iff ``is_done``)."""
        return np.asarray(self._results[rid], np.int32)

    def is_done(self, rid: int) -> bool:
        return self._done[rid]

    @property
    def occupancy(self) -> int:
        return len(self._live)

    # ------------------------------------------------------------------
    # Engine steps
    # ------------------------------------------------------------------

    def step(self) -> List[int]:
        """Admit queued requests into free slots, then advance every live
        slot one token. Returns rids that finished during this step."""
        self._ensure_state()
        finished = []
        if self._free and len(self.scheduler):
            finished += self.admit()
        if self._live:
            finished += self.decode()
        return finished

    def run(self) -> None:
        """Step until the queue and all slots drain."""
        self._ensure_state()
        while self._live or len(self.scheduler):
            self.step()

    def _pages_needed(self, req: Request) -> int:
        """Pages a request can ever touch: its final cache length is
        ``prompt + budget - 1`` (the last sampled token is never fed back)."""
        return self._pool.pages_needed(req.prompt_len + req.max_new_tokens - 1)

    def _take_wave(self) -> List[Request]:
        """Pop the next admission wave. Contiguous mode: one request per
        free slot. Paged mode: additionally gated on free-page accounting —
        admit while the head request's full reservation (prompt pages +
        decode-growth pages) fits; strict FIFO, no head-of-line bypass."""
        if not self._paged:
            return self.scheduler.take(len(self._free))
        wave: List[Request] = []
        reserved = 0
        while len(wave) < len(self._free):
            r = self.scheduler.peek()
            if r is None:
                break
            needed = self._pages_needed(r)
            if needed > self._pool.n_free - reserved:
                break                    # backpressure: wait for retires
            reserved += needed
            wave.append(self.scheduler.take(1)[0])
        return wave

    def admit(self) -> List[int]:
        """Prefill the next admission wave into freed slots and emit each
        admitted request's first token (from its prefill logits)."""
        self._ensure_state()
        wave = self._take_wave()
        if not wave:
            return []
        slots = [self._free.pop(0) for _ in wave]
        ns, w = self.n_slots, len(wave)

        # right-pad prompts; pad the wave batch to n_slots so exactly one
        # prefill program serves every wave size (padding rows are dropped
        # at insert via an out-of-range slot id)
        pl = self.prefill_len or max(r.tokens.size for r in wave)
        toks = np.zeros((ns, pl), np.int32)
        lengths = np.ones((ns,), np.int32)
        for i, r in enumerate(wave):
            toks[i, :r.tokens.size] = r.tokens
            lengths[i] = r.prompt_len
        front = None
        has_front = [r.frontend is not None for r in wave]
        if any(has_front):
            assert all(has_front), "wave mixes frontend/frontend-less requests"
            front = np.zeros((ns,) + self._front_dim, np.float32)
            for i, r in enumerate(wave):
                front[i] = r.frontend
            front = jnp.asarray(front)

        front_len = self._front_dim[0] if front is not None else 0
        if self._paged:
            # the wave cache only needs to hold the padded prompt, page-
            # aligned — NOT a full max_len segment; pages scatter from it
            pf_len = _ceil_to(pl + front_len, self.page_size)
        else:
            pf_len = self.max_len
        logits, wave_cache = self._prefill(
            self.params, jnp.asarray(toks), front, jnp.asarray(lengths),
            pf_len)
        slot_ids = np.full((ns,), ns, np.int32)    # padding rows -> dropped
        slot_ids[:w] = slots
        if self._paged:
            # allocate each request's full reservation now; decode appends
            # through the table without ever allocating mid-flight
            tables = np.full((ns, self.pages_per_slot), self.n_pages,
                             np.int32)
            for i, (slot, r) in enumerate(zip(slots, wave)):
                pages = self._pool.alloc(self._pages_needed(r))
                self._slot_pages[slot] = pages
                tables[i, :len(pages)] = pages
            self._cache = self._insert_paged(self._cache, wave_cache,
                                             slot_ids, jnp.asarray(tables))
        else:
            self._cache = self._insert(self._cache, wave_cache, slot_ids)

        # per-slot sampling state + per-request PRNG chains
        sl = jnp.asarray(np.asarray(slots, np.int32))
        self._temps = self._temps.at[sl].set(jnp.asarray(
            [r.sampling.temperature for r in wave], jnp.float32))
        self._topks = self._topks.at[sl].set(jnp.asarray(
            [r.sampling.top_k for r in wave], jnp.int32))
        self._keys = self._keys.at[sl].set(jnp.stack(
            [jax.random.PRNGKey(r.sampling.seed) for r in wave]))

        # first token: scatter wave-row logits into slot rows, sample
        lg = jnp.zeros((ns, logits.shape[-1]), logits.dtype)
        lg = lg.at[jnp.asarray(slot_ids)].set(logits[:, 0], mode="drop")
        mask = np.zeros((ns,), bool)
        mask[slots] = True
        for slot, r in zip(slots, wave):
            self._live[slot] = _Slot(r)
        return self._sample_and_commit(lg, mask)

    def decode(self) -> List[int]:
        """One jitted decode step over the full slot batch."""
        self._ensure_state()
        logits, self._cache = self._decode(self.params, self._cache,
                                           self._last_tok)
        mask = np.zeros((self.n_slots,), bool)
        mask[list(self._live)] = True
        return self._sample_and_commit(logits[:, 0], mask)

    def generate(self, prompts, max_new_tokens: int, frontend=None,
                 sampling: Optional[SamplingParams] = None) -> np.ndarray:
        """Batch convenience wrapper (the PR-1 era API, now ragged-capable).

        prompts: (B, T) int32 array OR a list of 1-D ragged prompts.
        Returns (B, max_new_tokens) generated ids; rows that stop early at
        ``eos_id`` pad the remaining columns with ``eos_id``.
        """
        rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        rids = [self.submit(row, max_new_tokens, sampling=sampling,
                            frontend=None if frontend is None
                            else np.asarray(frontend[i]))
                for i, row in enumerate(rows)]
        self.run()
        out = np.full((len(rows), max_new_tokens), self.eos_id, np.int32)
        for i, rid in enumerate(rids):
            got = self.result(rid)
            out[i, :got.size] = got
        return out

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------

    def _ensure_state(self) -> None:
        if self._cache is not None:
            return
        ns = self.n_slots
        if self._paged:
            self._cache = self.model.init_paged_cache(
                ns, self.n_pages, self.page_size, self.pages_per_slot)
        else:
            self._cache = self.model.init_cache(ns, self.max_len)
        self._temps = jnp.zeros((ns,), jnp.float32)
        self._topks = jnp.zeros((ns,), jnp.int32)
        self._keys = jnp.zeros((ns, 2), jnp.uint32)
        self._last_tok = jnp.zeros((ns, 1), jnp.int32)

    def _retire_slot(self, slot: int) -> None:
        """Free a finished slot: zero its cache length so ``decode_step``'s
        active mask freezes the lane (ISSUE 3: retired slots used to keep
        advancing their length and writing garbage KV every step — fatal
        under paging, where the stale page table points at pages that may
        already belong to another request), and return its pages."""
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        if self._paged:
            self._pool.free(self._slot_pages.pop(slot))

    def _sample_and_commit(self, logits2d, mask: np.ndarray) -> List[int]:
        """Sample all slots, commit key/token state for ``mask`` slots only
        (keeping every request's key chain aligned with its token count),
        record tokens and retire finished requests."""
        toks, new_keys = sample_tokens(logits2d, self._temps, self._topks,
                                       self._keys, self._vocab)
        m = jnp.asarray(mask)
        self._keys = jnp.where(m[:, None], new_keys, self._keys)
        self._last_tok = jnp.where(m[:, None], toks[:, None], self._last_tok)
        toks_np = np.asarray(toks)

        finished = []
        for slot in [s for s in self._live if mask[s]]:
            st = self._live[slot]
            t = int(toks_np[slot])
            self._results[st.req.rid].append(t)
            st.generated += 1
            if t == self.eos_id or st.generated >= st.req.max_new_tokens:
                self._done[st.req.rid] = True
                finished.append(st.req.rid)
                del self._live[slot]
                bisect.insort(self._free, slot)
                self._retire_slot(slot)
        return finished
