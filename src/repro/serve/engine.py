"""Continuous-batching serve engine core (backend-abstracted since ISSUE 6).

The engine owns ``n_slots`` lanes and everything REQUEST-shaped: request
ids, the scheduler and admission waves, the slot free-list and live map,
result/done bookkeeping, budget accounting, and the preemption victim
policy. Everything DEVICE-shaped — caches, page pools, sampling state, the
jitted admit/step programs — lives in a ``serve.backend.Backend``:

- ``TokenDecodeBackend`` (LM families): KV caches (full / paged / ring /
  SSM), per-request PRNG sampling chains, lazy page growth. This is the
  pre-refactor engine body moved verbatim — LM serve behavior is
  bit-identical to the monolithic engine.
- ``PairBatchBackend`` (``cfg.family == "pairformer"``): batched
  Pairformer inference where a request is one complex, admission runs the
  trunk once and caches the per-layer pair-bias FACTORS per slot
  (FlashBias Sec. 4.4), and each step is one refinement iteration over the
  padded slot batch with per-slot ``n_res`` masking.

The admission/step loop is backend-agnostic: a FIFO scheduler (with
priority classes — higher admits first, preempts last) fills freed slots,
each admission wave is padded to ``n_slots`` and prefilled in one jitted
call, and every engine step advances the full slot batch in ONE jitted
program — per-request raggedness rides in the ``lengths`` vector, so
arbitrary arrival/finish patterns never recompile and never stall on the
slowest request.

Determinism contract (tested in tests/test_serve_engine.py /
test_pair_serve.py): every per-slot computation is batch-row independent
and sampler key chains are per-request, so a request's output is identical
whether it runs alone or packed with strangers — provided the padded
prompt length is pinned (``prefill_len`` for LM; ``max_len`` pins it
structurally for the pair backend).

Paged mode, lazy growth and preemption semantics are unchanged from
ISSUEs 3-5 (see serve/README.md §Paged KV): when the pool runs dry the
engine preempts the lowest-priority live request (lowest priority class,
then latest arrival), whose snapshot re-enters at the head of the queue —
greedy outputs are bit-identical to the never-preempted run.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.models.api import Model
from repro.serve.backend import PairBatchBackend, TokenDecodeBackend
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied lane.

    ``length`` mirrors ``cache["length"][slot]``: it is the position the
    NEXT decode step will write, which is what lazy page growth gates on
    (no device read-back in the decode loop)."""
    req: Request
    generated: int = 0
    length: int = 0


class ServeEngine:
    """Slot-based continuous-batching engine (admit/step/commit core).

    Args:
        model: a serve-capable ``Model`` (prefill/decode/init_cache/
            insert_cache). ``cfg.family`` selects the backend:
            ``"pairformer"`` gets the batched pair-inference backend,
            every decode family gets the token backend.
        params: parameter pytree.
        max_len: per-slot cache segment length. For the pair backend this
            is the pinned residue padding — every wave pads to it, so one
            prefill/step program serves all complexes and outputs are
            independent of wave composition.
        eos_id: generation stops when this id is sampled (kept in the
            output; ``generate`` pads remaining columns with it). -1 never
            matches, i.e. requests always run out their budget. Ignored by
            non-emitting backends.
        n_slots: fixed batch — the number of concurrent requests.
        prefill_len: pinned padded prompt length (token backend only).
            None pads each admission wave to its own maximum (fewest
            wasted FLOPs); pinning it makes request outputs independent of
            wave composition and bounds prefill compiles to one.
        page_size / n_pages / pages_per_slot / page_reservation: paged-KV
            knobs, forwarded to the token backend (see its docstring and
            serve/README.md §Paged KV). Ignored by the pair backend.
        scheduler_policy: ``"fifo"`` (default) admits in arrival order;
            ``"spf"`` admits the shortest queued prompt first. Priority
            classes order above either policy; preempted requests resume
            ahead of same-priority arrivals.
        factors: fitted pair-bias factor MLP params (pair backend only).
            None selects per-complex SVD factors at ``cfg.bias_rank``
            (``cfg.bias_mode="dense"`` caches the dense bias instead —
            the A/B baseline).
        prefill_chunk: > 0 switches the token backend to CHUNKED prefill
            (ISSUE 7): admission becomes planning, prompts land
            ``prefill_chunk`` tokens per engine step interleaved with the
            decode batch — a long arrival can never stall in-flight
            decodes for more than one chunk's latency. None (default)
            keeps whole-prompt admission waves, bit-identical to the
            pre-chunking engine. Ring-KV archs clamp the chunk to the
            attention window.
        prefix_cache: True enables content-hashed prefix caching
            (ISSUE 9): completed prompt pages are indexed by token
            content, a new request whose prompt starts with an indexed
            prefix maps its page table onto the existing pages (refcounted
            sharing + copy-on-write) and prefills only the novel tail.
            Requires ``page_size`` + ``prefill_chunk`` and a full-KV
            family without recurrent state (dense / moe). Outputs stay
            bit-identical to the unshared engine; see serve/README.md
            §Prefix caching contract.
        mesh / rules: device mesh + logical-axis rules for the token
            backend (ISSUE 7). The backend traces every jitted program
            under ``use_mesh_rules`` and places its persistent state with
            explicit shardings — KV/pools along ``kv_heads``, slot rows
            along ``batch`` — while the page allocator and tables stay
            host-side. None serves single-device, unchanged.
    """

    def __init__(self, model: Model, params: dict, max_len: int = 1024,
                 eos_id: int = -1, n_slots: int = 4,
                 prefill_len: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 pages_per_slot: Optional[int] = None,
                 page_reservation: str = "lazy",
                 scheduler_policy: str = "fifo",
                 factors: Optional[dict] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 mesh=None, rules=None):
        assert model.prefill is not None and model.decode is not None, \
            "model is not serve-capable"
        assert page_reservation in ("lazy", "whole"), page_reservation
        self.model, self.params = model, params
        self.max_len, self.eos_id = max_len, eos_id
        self.n_slots, self.prefill_len = n_slots, prefill_len
        if model.cfg.family == "pairformer":
            assert prefill_chunk is None and mesh is None \
                and not prefix_cache, \
                "chunked prefill / prefix cache / mesh sharding are " \
                "token-backend paths"
            self.backend = PairBatchBackend(model, params, max_len=max_len,
                                            n_slots=n_slots, factors=factors)
        else:
            self.backend = TokenDecodeBackend(
                model, params, max_len=max_len, n_slots=n_slots,
                prefill_len=prefill_len, page_size=page_size,
                n_pages=n_pages, pages_per_slot=pages_per_slot,
                page_reservation=page_reservation,
                prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
                mesh=mesh, rules=rules)
        if self.backend.paged:
            self.page_size = self.backend.page_size
            self.n_pages = self.backend.n_pages
            self.pages_per_slot = self.backend.pages_per_slot
        self.n_preemptions = 0
        self.scheduler = FIFOScheduler(policy=scheduler_policy)
        self._next_rid = 0
        self._results: Dict[int, object] = {}   # rid -> [ids] | result array
        self._done: Dict[int, bool] = {}
        self._live: Dict[int, _Slot] = {}         # slot -> _Slot
        self._free: List[int] = list(range(n_slots))

    # -- legacy aliases: device state lives in the backend now, but the
    # -- pre-ISSUE-6 attribute names remain the observable surface used by
    # -- tests and benches
    @property
    def _cache(self):
        return self.backend._cache

    @property
    def _pool(self):
        return self.backend._pool

    @property
    def _slot_pages(self):
        return self.backend._slot_pages

    @property
    def _paged(self) -> bool:
        return self.backend.paged

    @property
    def _lazy(self) -> bool:
        return self.backend.lazy

    def _page_cap(self) -> Optional[int]:
        return self.backend.page_cap(self._live)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               frontend: Optional[np.ndarray] = None,
               priority: int = 0, on_token=None) -> int:
        """Queue one request; returns its request id.

        ``priority`` is the request's class: higher admits before lower
        regardless of arrival order, and preemption victims are drawn from
        the lowest class first. The default 0 for every request reproduces
        the pre-class engine exactly.

        ``on_token`` streams the request's progress: the engine calls it
        once per budget unit the request advances, with the emitted token
        id (token backend) or the backend's per-step ``stream_result``
        (pair backend — the current single rep). The callback rides the
        request descriptor, so it survives preemption and resume.
        """
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(tokens), max_new_tokens,
                      sampling or SamplingParams(), frontend,
                      priority=priority, on_token=on_token)
        self.backend.validate(req)
        self._results[rid] = []
        self._done[rid] = False
        self.scheduler.add(req)
        return rid

    def result(self, rid: int):
        """Result so far for ``rid`` (complete iff ``is_done``): generated
        ids for the token backend, the final (n_res, d_model) single
        representation for the pair backend."""
        res = self._results[rid]
        if isinstance(res, np.ndarray):
            return res
        return np.asarray(res, np.int32)

    def is_done(self, rid: int) -> bool:
        return self._done[rid]

    @property
    def occupancy(self) -> int:
        return len(self._live)

    def page_stats(self) -> dict:
        """Pool accounting snapshot (empty for unpaged backends)."""
        stats = self.backend.stats()
        if stats:
            stats["preemptions"] = self.n_preemptions
        return stats

    # ------------------------------------------------------------------
    # Engine steps
    # ------------------------------------------------------------------

    def step(self) -> List[int]:
        """Admit queued requests into free slots, advance every pending
        prompt one prefill chunk, then advance every decoding slot one
        budget unit. Returns rids that finished this step.

        The chunk/decode INTERLEAVE is the chunked-prefill latency
        contract: each engine step costs the decode batch exactly one
        chunk program, so a long arrival's admission is amortized one
        chunk per step instead of stalling the whole batch behind a
        monolithic prompt prefill."""
        self._ensure_state()
        finished = []
        if self._free and len(self.scheduler):
            finished += self.admit()
        if self.backend.prefill_pending():
            emissions, mask = self.backend.prefill_step()
            finished += self._commit(emissions, mask)
        if self._live:
            finished += self.decode()
        return finished

    def run(self) -> None:
        """Step until the queue and all slots drain."""
        self._ensure_state()
        while self._live or len(self.scheduler):
            self.step()

    def _take_wave(self) -> List[Request]:
        """Pop the next admission wave: one request per free slot, gated in
        paged mode on free-page accounting — admit while the head request's
        admission reservation (prompt pages under lazy growth, the full
        footprint under whole-request reservation) fits; no head-of-line
        bypass within the policy. A resumed request whose prompt outgrew a
        pinned ``prefill_len`` rides a SOLO wave: padding a mixed wave to
        the resumed length would change co-admitted requests' padded
        prompt length, which is exactly the shape the determinism contract
        pins (it feeds MoE expert capacity)."""
        wave: List[Request] = []
        reserved = 0
        while len(wave) < len(self._free):
            r = self.scheduler.peek()
            if r is None:
                break
            over = (self.prefill_len is not None
                    and r.tokens.size > self.prefill_len)
            if over and wave:
                break                    # over-length request: next wave
            if self.backend.paged:
                needed = self.backend.admission_units(r)
                if needed > self.backend.units_free() - reserved:
                    break                # backpressure: wait for frees
                reserved += needed
            wave.append(self.scheduler.take(1)[0])
            if over:
                break                    # solo wave for the resumed prompt
        return wave

    def admit(self) -> List[int]:
        """Prefill the next admission wave into freed slots; the backend
        decides what (if anything) each admission emits — the token
        backend samples each request's first token from its prefill
        logits, the pair backend emits nothing (its budget counts
        refinement steps)."""
        self._ensure_state()
        wave = self._take_wave()
        if not wave:
            return []
        slots = [self._free.pop(0) for _ in wave]
        emissions, mask = self.backend.admit(wave, slots)
        for slot, r in zip(slots, wave):
            self._live[slot] = _Slot(r, length=r.prompt_len)
        return self._commit(emissions, mask)

    def decode(self) -> List[int]:
        """Advance every live slot one budget unit in one jitted backend
        step. Lazy paged mode first grows any slot whose write position
        crossed a page boundary — preempting the lowest-priority request
        while the pool is dry — so the jitted step itself never
        allocates."""
        self._ensure_state()
        pending = self.backend.pending_slots()
        if pending and all(s in pending for s in self._live):
            return []               # nothing decoding yet — chunks only
        if self.backend.lazy:
            # when the pool can't cover the growth, preempt lowest-
            # priority live requests (possibly a growing request itself —
            # freeing it both clears its demand and returns its pages)
            # until it can; (priority, arrival) is a total order, so the
            # highest-priority earliest-arrived request always makes
            # progress and the engine can never preempt itself into a
            # livelock
            growing = self.backend.growth_pending(self._live)
            while growing and self.backend.units_free() < len(growing):
                victim = self._victim_slot()
                self._preempt_slot(victim)
                growing = [s for s in growing if s != victim]
            if growing:
                self.backend.grow_slots(growing)
        if not self._live:
            return []
        emissions, mask = self.backend.step(self._live)
        return self._commit(emissions, mask)

    def generate(self, prompts, max_new_tokens: int, frontend=None,
                 sampling: Optional[SamplingParams] = None) -> np.ndarray:
        """Batch convenience wrapper (the PR-1 era API, now ragged-capable).

        prompts: (B, T) int32 array OR a list of 1-D ragged prompts.
        Returns (B, max_new_tokens) generated ids; rows that stop early at
        ``eos_id`` pad the remaining columns with ``eos_id``.
        """
        assert isinstance(self.backend, TokenDecodeBackend), \
            "generate() is a token-emitting API; submit()/result() serve " \
            "pair requests"
        rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        rids = [self.submit(row, max_new_tokens, sampling=sampling,
                            frontend=None if frontend is None
                            else np.asarray(frontend[i]))
                for i, row in enumerate(rows)]
        self.run()
        out = np.full((len(rows), max_new_tokens), self.eos_id, np.int32)
        for i, rid in enumerate(rids):
            got = self.result(rid)
            out[i, :got.size] = got
        return out

    # ------------------------------------------------------------------
    # Preemption (lazy paged mode; public for any backend)
    # ------------------------------------------------------------------

    def _victim_slot(self) -> int:
        """Lowest priority class first, then latest arrival (highest rid)
        — with all-default priorities this is exactly the pre-class
        victim, so existing preemption behavior is unchanged."""
        return min(self._live,
                   key=lambda s: (self._live[s].req.priority,
                                  -self._live[s].req.rid))

    def preempt(self, rid: Optional[int] = None) -> Optional[int]:
        """Preempt one in-flight request and re-queue it at the head.

        Default victim is the lowest-priority live request (lowest class,
        latest arrival). Returns the preempted rid, or None when nothing
        is live. The engine calls this automatically when lazy page growth
        finds the pool dry; it is public so tests and external policies
        can force it for ANY backend (ring-KV / SSM slots hold no pages
        but preempt the same way; a pair slot restarts its complex).
        """
        self._ensure_state()
        if not self._live:
            return None
        if rid is None:
            slot = self._victim_slot()
        else:
            matches = [s for s, st in self._live.items()
                       if st.req.rid == rid]
            assert matches, f"request {rid} is not in flight"
            slot = matches[0]
        return self._preempt_slot(slot)

    def _preempt_slot(self, slot: int) -> int:
        """Snapshot + free + re-queue one slot.

        The backend builds the resumable request — the token backend folds
        generated-so-far into the prompt and snapshots the PRNG key chain
        (greedy outputs stay bit-identical to the never-preempted run; a
        sampled request continues its key chain unbroken), the pair
        backend restarts the complex from scratch. Either way the slot
        freezes, its resources free immediately, and the snapshot re-
        enters at the head of its priority class.
        """
        st = self._live.pop(slot)
        bisect.insort(self._free, slot)
        resumed = self.backend.snapshot(slot, st, self._results[st.req.rid])
        self.scheduler.add_front(resumed)
        self.n_preemptions += 1
        return st.req.rid

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------

    def _ensure_state(self) -> None:
        self.backend.ensure_state()

    def _commit(self, emissions: Optional[np.ndarray],
                mask: np.ndarray) -> List[int]:
        """Record this step's emissions and retire finished requests.

        ``mask`` marks slots that advanced one budget unit; ``emissions``
        is per-slot token ids (token backend) or None (pair backend —
        nothing emitted incrementally; the result is fetched from the
        backend when the budget drains)."""
        finished = []
        for slot in [s for s in self._live if mask[s]]:
            st = self._live[slot]
            t = None if emissions is None else int(emissions[slot])
            if t is not None:
                self._results[st.req.rid].append(t)
            st.generated += 1
            if st.req.on_token is not None:
                # streaming: emitted id for token backends; non-emitting
                # backends drain their per-step output instead
                st.req.on_token(t if t is not None
                                else self.backend.stream_result(slot, st))
            if ((t is not None and t == self.eos_id)
                    or st.generated >= st.req.max_new_tokens):
                res = self.backend.fetch_result(slot, st)
                if res is not None:
                    self._results[st.req.rid] = res
                self._done[st.req.rid] = True
                finished.append(st.req.rid)
                del self._live[slot]
                bisect.insort(self._free, slot)
                self.backend.release(slot)
        return finished
