"""Continuous-batching serve engine core (backend-abstracted since ISSUE 6).

The engine owns ``n_slots`` lanes and everything REQUEST-shaped: request
ids, the scheduler and admission waves, the slot free-list and live map,
result/done bookkeeping, budget accounting, and the preemption victim
policy. Everything DEVICE-shaped — caches, page pools, sampling state, the
jitted admit/step programs — lives in a ``serve.backend.Backend``:

- ``TokenDecodeBackend`` (LM families): KV caches (full / paged / ring /
  SSM), per-request PRNG sampling chains, lazy page growth. This is the
  pre-refactor engine body moved verbatim — LM serve behavior is
  bit-identical to the monolithic engine.
- ``PairBatchBackend`` (``cfg.family == "pairformer"``): batched
  Pairformer inference where a request is one complex, admission runs the
  trunk once and caches the per-layer pair-bias FACTORS per slot
  (FlashBias Sec. 4.4), and each step is one refinement iteration over the
  padded slot batch with per-slot ``n_res`` masking.

The admission/step loop is backend-agnostic: a FIFO scheduler (with
priority classes — higher admits first, preempts last) fills freed slots,
each admission wave is padded to ``n_slots`` and prefilled in one jitted
call, and every engine step advances the full slot batch in ONE jitted
program — per-request raggedness rides in the ``lengths`` vector, so
arbitrary arrival/finish patterns never recompile and never stall on the
slowest request.

Determinism contract (tested in tests/test_serve_engine.py /
test_pair_serve.py): every per-slot computation is batch-row independent
and sampler key chains are per-request, so a request's output is identical
whether it runs alone or packed with strangers — provided the padded
prompt length is pinned (``prefill_len`` for LM; ``max_len`` pins it
structurally for the pair backend).

Paged mode, lazy growth and preemption semantics are unchanged from
ISSUEs 3-5 (see serve/README.md §Paged KV): when the pool runs dry the
engine preempts the lowest-priority live request (lowest priority class,
then latest arrival), whose snapshot re-enters at the head of the queue —
greedy outputs are bit-identical to the never-preempted run.

FAULT TOLERANCE (ISSUE 10, serve/README.md §Failure semantics): every
request carries a lifecycle record (``QUEUED -> RUNNING -> OK / FAILED /
TIMED_OUT / CANCELLED / REJECTED``); ``result`` returns a
``RequestRecord`` — the result array plus its status and structured
error. Host-side non-finite guards on emissions (and on the pair
backend's admission-time factor caches) QUARANTINE a faulting slot
through the preemption-snapshot machinery: its emission is withheld, its
written prefix pages are invalidated from the index, and its request
retries bit-identically up to ``max_retries`` before terminating FAILED.
Pool exhaustion and injected step faults are contained the same way
(preempt + retry, never crash). ``snapshot_engine`` serializes the whole
host state for crash-safe restore on a fresh engine, and a
``serve.faults.FaultPlan`` drives all of it deterministically in the
chaos suite (tests/test_faults.py).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.models.api import Model
from repro.serve.backend import PairBatchBackend, TokenDecodeBackend
from repro.serve.faults import FaultPlan
from repro.serve.lifecycle import (
    CANCELLED, FAILED, OK, QUEUED, REJECTED, RUNNING, TERMINAL_STATUSES,
    TIMED_OUT, AdmissionRejected, EngineStalled, InjectedFault,
    PoolExhausted, RequestNotLive, RequestRecord)
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine"]

_SNAPSHOT_VERSION = 1


@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied lane.

    ``length`` mirrors ``cache["length"][slot]``: it is the position the
    NEXT decode step will write, which is what lazy page growth gates on
    (no device read-back in the decode loop)."""
    req: Request
    generated: int = 0
    length: int = 0


@dataclasses.dataclass
class _ReqMeta:
    """Lifecycle record of one request (host bookkeeping, ISSUE 10)."""
    status: str = QUEUED
    error: Optional[dict] = None
    retries: int = 0                  # quarantine retries consumed
    max_retries: int = 1
    deadline: Optional[int] = None    # absolute engine step, None = never


def _ser_arr(x) -> dict:
    """JSON-serializable encoding of a result payload: a token-id list
    (mid-flight token results) or an ndarray (prompts, keys, pair
    results)."""
    if isinstance(x, np.ndarray):
        return {"kind": "array", "dtype": str(x.dtype),
                "shape": list(x.shape), "data": x.ravel().tolist()}
    return {"kind": "ids", "data": [int(t) for t in x]}


def _de_arr(d: dict):
    if d["kind"] == "array":
        return np.asarray(d["data"], dtype=np.dtype(d["dtype"])).reshape(
            d["shape"])
    return list(d["data"])


class ServeEngine:
    """Slot-based continuous-batching engine (admit/step/commit core).

    Args:
        model: a serve-capable ``Model`` (prefill/decode/init_cache/
            insert_cache). ``cfg.family`` selects the backend:
            ``"pairformer"`` gets the batched pair-inference backend,
            every decode family gets the token backend.
        params: parameter pytree.
        max_len: per-slot cache segment length. For the pair backend this
            is the pinned residue padding — every wave pads to it, so one
            prefill/step program serves all complexes and outputs are
            independent of wave composition.
        eos_id: generation stops when this id is sampled (kept in the
            output; ``generate`` pads remaining columns with it). -1 never
            matches, i.e. requests always run out their budget. Ignored by
            non-emitting backends.
        n_slots: fixed batch — the number of concurrent requests.
        prefill_len: pinned padded prompt length (token backend only).
            None pads each admission wave to its own maximum (fewest
            wasted FLOPs); pinning it makes request outputs independent of
            wave composition and bounds prefill compiles to one.
        page_size / n_pages / pages_per_slot / page_reservation: paged-KV
            knobs, forwarded to the token backend (see its docstring and
            serve/README.md §Paged KV). Ignored by the pair backend.
        scheduler_policy: ``"fifo"`` (default) admits in arrival order;
            ``"spf"`` admits the shortest queued prompt first. Priority
            classes order above either policy; preempted requests resume
            ahead of same-priority arrivals.
        factors: fitted pair-bias factor MLP params (pair backend only).
            None selects per-complex SVD factors at ``cfg.bias_rank``
            (``cfg.bias_mode="dense"`` caches the dense bias instead —
            the A/B baseline).
        prefill_chunk: > 0 switches the token backend to CHUNKED prefill
            (ISSUE 7): admission becomes planning, prompts land
            ``prefill_chunk`` tokens per engine step interleaved with the
            decode batch — a long arrival can never stall in-flight
            decodes for more than one chunk's latency. None (default)
            keeps whole-prompt admission waves, bit-identical to the
            pre-chunking engine. Ring-KV archs clamp the chunk to the
            attention window.
        prefix_cache: True enables content-hashed prefix caching
            (ISSUE 9): completed prompt pages are indexed by token
            content, a new request whose prompt starts with an indexed
            prefix maps its page table onto the existing pages (refcounted
            sharing + copy-on-write) and prefills only the novel tail.
            Requires ``page_size`` + ``prefill_chunk`` and a full-KV
            family without recurrent state (dense / moe). Outputs stay
            bit-identical to the unshared engine; see serve/README.md
            §Prefix caching contract.
        mesh / rules: device mesh + logical-axis rules for the token
            backend (ISSUE 7). The backend traces every jitted program
            under ``use_mesh_rules`` and places its persistent state with
            explicit shardings — KV/pools along ``kv_heads``, slot rows
            along ``batch`` — while the page allocator and tables stay
            host-side. None serves single-device, unchanged.
        guards: host-side non-finite emission/admission guards
            (ISSUE 10, default on — the ``guard_overhead`` bench gates
            their cost at <= 5%). Off restores the pre-lifecycle
            behavior: poison flows into results undetected.
        faults: a ``serve.faults.FaultPlan`` for deterministic fault
            injection (chaos drills). None (default) injects nothing.
        stall_limit: ``run()`` raises ``EngineStalled`` after this many
            consecutive steps with queued/live work but zero progress
            (no admission, no chunk landed, no token committed, no
            preemption) — a diagnostic instead of an infinite spin.
    """

    def __init__(self, model: Model, params: dict, max_len: int = 1024,
                 eos_id: int = -1, n_slots: int = 4,
                 prefill_len: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 pages_per_slot: Optional[int] = None,
                 page_reservation: str = "lazy",
                 scheduler_policy: str = "fifo",
                 factors: Optional[dict] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 mesh=None, rules=None,
                 guards: bool = True,
                 faults: Optional[FaultPlan] = None,
                 stall_limit: int = 64):
        if model.prefill is None or model.decode is None:
            raise ValueError("model is not serve-capable (needs "
                             "prefill/decode programs)")
        if stall_limit < 1:
            raise ValueError(f"stall_limit must be >= 1, got {stall_limit}")
        self.model, self.params = model, params
        self.max_len, self.eos_id = max_len, eos_id
        self.n_slots, self.prefill_len = n_slots, prefill_len
        if model.cfg.family == "pairformer":
            if prefill_chunk is not None or mesh is not None or prefix_cache:
                raise ValueError(
                    "chunked prefill / prefix cache / mesh sharding are "
                    "token-backend paths")
            self.backend = PairBatchBackend(model, params, max_len=max_len,
                                            n_slots=n_slots, factors=factors)
        else:
            self.backend = TokenDecodeBackend(
                model, params, max_len=max_len, n_slots=n_slots,
                prefill_len=prefill_len, page_size=page_size,
                n_pages=n_pages, pages_per_slot=pages_per_slot,
                page_reservation=page_reservation,
                prefill_chunk=prefill_chunk, prefix_cache=prefix_cache,
                mesh=mesh, rules=rules)
        if self.backend.paged:
            self.page_size = self.backend.page_size
            self.n_pages = self.backend.n_pages
            self.pages_per_slot = self.backend.pages_per_slot
        self.guards = guards
        self.backend.guards = guards
        self.faults = faults
        self.backend.faults = faults
        self.stall_limit = stall_limit
        self.step_idx = 0               # engine steps taken (fault clock)
        self.n_preemptions = 0
        self.n_quarantines = 0          # guard trips contained
        self.n_faults_contained = 0     # step/alloc faults contained
        self.scheduler = FIFOScheduler(policy=scheduler_policy)
        self._next_rid = 0
        self._results: Dict[int, object] = {}   # rid -> [ids] | result array
        self._done: Dict[int, bool] = {}
        self._meta: Dict[int, _ReqMeta] = {}    # rid -> lifecycle record
        self._live: Dict[int, _Slot] = {}         # slot -> _Slot
        self._free: List[int] = list(range(n_slots))
        self._advanced = 0              # committed budget units (stall sig)

    # -- legacy aliases: device state lives in the backend now, but the
    # -- pre-ISSUE-6 attribute names remain the observable surface used by
    # -- tests and benches
    @property
    def _cache(self):
        return self.backend._cache

    @property
    def _pool(self):
        return self.backend._pool

    @property
    def _slot_pages(self):
        return self.backend._slot_pages

    @property
    def _paged(self) -> bool:
        return self.backend.paged

    @property
    def _lazy(self) -> bool:
        return self.backend.lazy

    def _page_cap(self) -> Optional[int]:
        return self.backend.page_cap(self._live)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               frontend: Optional[np.ndarray] = None,
               priority: int = 0, on_token=None,
               deadline_steps: Optional[int] = None,
               max_retries: int = 1, strict: bool = True) -> int:
        """Queue one request; returns its request id.

        ``priority`` is the request's class: higher admits before lower
        regardless of arrival order, and preemption victims are drawn from
        the lowest class first. The default 0 for every request reproduces
        the pre-class engine exactly.

        ``on_token`` streams the request's progress: the engine calls it
        once per budget unit the request advances, with the emitted token
        id (token backend) or the backend's per-step ``stream_result``
        (pair backend — the current single rep). The callback rides the
        request descriptor, so it survives preemption and resume.

        ``deadline_steps`` (ISSUE 10): the request terminates
        ``TIMED_OUT`` (keeping its partial result) if still incomplete
        after this many further engine steps. None = no deadline.

        ``max_retries``: quarantine retries before a guard-tripping
        request terminates ``FAILED`` (each retry resumes bit-identically
        from its preemption snapshot).

        ``strict``: when False, a request that fails admission validation
        returns a rid whose record is terminal ``REJECTED`` (with the
        validation message as its error) instead of raising
        ``AdmissionRejected``.
        """
        if deadline_steps is not None and deadline_steps < 1:
            raise ValueError(
                f"deadline_steps must be >= 1, got {deadline_steps}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        rid = self._next_rid
        self._next_rid += 1
        deadline = (None if deadline_steps is None
                    else self.step_idx + deadline_steps)
        self._results[rid] = []
        self._done[rid] = False
        self._meta[rid] = _ReqMeta(max_retries=max_retries,
                                   deadline=deadline)
        try:
            req = Request(rid, np.asarray(tokens), max_new_tokens,
                          sampling or SamplingParams(), frontend,
                          priority=priority, on_token=on_token)
            self.backend.validate(req)
        except AdmissionRejected as e:
            if strict:
                del self._results[rid], self._done[rid], self._meta[rid]
                raise
            self._finish(rid, REJECTED,
                         error={"kind": "admission", "detail": str(e)})
            return rid
        self.scheduler.add(req)
        return rid

    def result(self, rid: int) -> RequestRecord:
        """The ``(status, tokens, error)`` record for ``rid`` — a
        ``RequestRecord``: the result array so far (complete iff
        ``is_done``) carrying ``status`` and ``error`` attributes.
        Generated ids for the token backend, the final (n_res, d_model)
        single representation for the pair backend."""
        if rid not in self._results:
            raise RequestNotLive(f"unknown request id {rid}")
        res = self._results[rid]
        meta = self._meta[rid]
        if not isinstance(res, np.ndarray):
            res = np.asarray(res, np.int32)
        return RequestRecord(res, status=meta.status, error=meta.error)

    def status(self, rid: int) -> str:
        """Lifecycle status of ``rid`` (see serve.lifecycle)."""
        if rid not in self._meta:
            raise RequestNotLive(f"unknown request id {rid}")
        return self._meta[rid].status

    def status_counts(self) -> Dict[str, int]:
        """{status: count} over every submitted request (the launcher's
        final stats line)."""
        counts: Dict[str, int] = {}
        for meta in self._meta.values():
            counts[meta.status] = counts.get(meta.status, 0) + 1
        return counts

    def cancel(self, rid: int) -> bool:
        """Terminate ``rid`` as ``CANCELLED``, releasing its slot/pages.

        Returns True if the cancel landed, False if the request already
        reached a terminal status (too late to cancel). Unknown rids
        raise ``RequestNotLive``. The partial result (tokens emitted
        before the cancel) stays readable via ``result``."""
        meta = self._meta.get(rid)
        if meta is None:
            raise RequestNotLive(f"unknown request id {rid}")
        if meta.status in TERMINAL_STATUSES:
            return False
        if self.scheduler.remove(rid) is None:
            slots = [s for s, st in self._live.items()
                     if st.req.rid == rid]
            if not slots:
                raise RequestNotLive(
                    f"request {rid} is neither queued nor in flight")
            self._retire_slot(slots[0])
        self._finish(rid, CANCELLED)
        return True

    def is_done(self, rid: int) -> bool:
        if rid not in self._done:
            raise RequestNotLive(f"unknown request id {rid}")
        return self._done[rid]

    def _finish(self, rid: int, status: str,
                error: Optional[dict] = None) -> None:
        meta = self._meta[rid]
        meta.status = status
        if error is not None:
            meta.error = error
        self._done[rid] = True

    def _retire_slot(self, slot: int) -> None:
        """Pop a live slot and free its backend resources (no requeue)."""
        del self._live[slot]
        bisect.insort(self._free, slot)
        self.backend.release(slot)

    @property
    def occupancy(self) -> int:
        return len(self._live)

    def page_stats(self) -> dict:
        """Pool accounting snapshot (empty for unpaged backends)."""
        stats = self.backend.stats()
        if stats:
            stats["preemptions"] = self.n_preemptions
            stats["quarantines"] = self.n_quarantines
            stats["faults_contained"] = self.n_faults_contained
        return stats

    # ------------------------------------------------------------------
    # Engine steps
    # ------------------------------------------------------------------

    def step(self) -> List[int]:
        """Admit queued requests into free slots, advance every pending
        prompt one prefill chunk, then advance every decoding slot one
        budget unit. Returns rids that reached a terminal status this
        step (OK and TIMED_OUT alike).

        The chunk/decode INTERLEAVE is the chunked-prefill latency
        contract: each engine step costs the decode batch exactly one
        chunk program, so a long arrival's admission is amortized one
        chunk per step instead of stalling the whole batch behind a
        monolithic prompt prefill."""
        self._ensure_state()
        if self.faults is not None:
            self.faults.tick(self.step_idx)
        finished = self._expire_deadlines()
        delayed = (self.faults is not None
                   and self.faults.admission_delayed())
        if self._free and len(self.scheduler) and not delayed:
            finished += self.admit()
        if self.backend.prefill_pending():
            emissions, mask = self.backend.prefill_step()
            finished += self._commit_guarded(emissions, mask)
        if self._live:
            try:
                finished += self.decode()
            except InjectedFault:
                # a failed jitted step dispatched nothing and advanced no
                # state — containment is retrying next step
                self.n_faults_contained += 1
        self.step_idx += 1
        return finished

    def run(self) -> None:
        """Step until the queue and all slots drain.

        Stall guard (ISSUE 10): ``stall_limit`` consecutive steps with
        work outstanding but NO progress — no admission, no chunk
        landed, no budget unit committed, no preemption/quarantine, no
        terminal transition — raise ``EngineStalled`` with queue/pool/
        slot diagnostics instead of spinning forever."""
        self._ensure_state()
        idle = 0
        while self._live or len(self.scheduler):
            before = self._progress_sig()
            self.step()
            if self._progress_sig() == before:
                idle += 1
                if idle >= self.stall_limit:
                    raise EngineStalled(
                        f"no progress for {idle} consecutive steps: "
                        f"{len(self.scheduler)} queued, "
                        f"{len(self._live)} live "
                        f"(slots {sorted(self._live)}), "
                        f"free slots {self._free}, "
                        f"page stats {self.page_stats() or None}, "
                        f"statuses {self.status_counts()}")
            else:
                idle = 0

    def _progress_sig(self) -> tuple:
        """Everything that changes when the engine makes progress —
        compared across steps by the ``run`` stall guard."""
        pending = getattr(self.backend, "_pending", None) or {}
        return (len(self.scheduler), len(self._live), self._advanced,
                self.n_preemptions, self.n_quarantines,
                sum(self._done.values()),
                tuple(sorted((s, p.done) for s, p in pending.items())))

    def _expire_deadlines(self) -> List[int]:
        """Terminate every queued/live request whose deadline elapsed
        (TIMED_OUT, partial result retained)."""
        expired: List[int] = []
        for req in self.scheduler.queued():
            meta = self._meta[req.rid]
            if meta.deadline is not None and self.step_idx >= meta.deadline:
                self.scheduler.remove(req.rid)
                expired.append(req.rid)
        for slot in sorted(self._live):
            meta = self._meta[self._live[slot].req.rid]
            if meta.deadline is not None and self.step_idx >= meta.deadline:
                expired.append(self._live[slot].req.rid)
                self._retire_slot(slot)
        for rid in expired:
            self._finish(rid, TIMED_OUT, error={
                "kind": "deadline", "step": self.step_idx,
                "detail": f"deadline (step {self._meta[rid].deadline}) "
                          f"elapsed before completion"})
        return expired

    def _take_wave(self) -> List[Request]:
        """Pop the next admission wave: one request per free slot, gated in
        paged mode on free-page accounting — admit while the head request's
        admission reservation (prompt pages under lazy growth, the full
        footprint under whole-request reservation) fits; no head-of-line
        bypass within the policy. A resumed request whose prompt outgrew a
        pinned ``prefill_len`` rides a SOLO wave: padding a mixed wave to
        the resumed length would change co-admitted requests' padded
        prompt length, which is exactly the shape the determinism contract
        pins (it feeds MoE expert capacity)."""
        wave: List[Request] = []
        reserved = 0
        while len(wave) < len(self._free):
            r = self.scheduler.peek()
            if r is None:
                break
            over = (self.prefill_len is not None
                    and r.tokens.size > self.prefill_len)
            if over and wave:
                break                    # over-length request: next wave
            if self.backend.paged:
                needed = self.backend.admission_units(r)
                if needed > self.backend.units_free() - reserved:
                    break                # backpressure: wait for frees
                reserved += needed
            wave.append(self.scheduler.take(1)[0])
            if over:
                break                    # solo wave for the resumed prompt
        return wave

    def admit(self) -> List[int]:
        """Prefill the next admission wave into freed slots; the backend
        decides what (if anything) each admission emits — the token
        backend samples each request's first token from its prefill
        logits, the pair backend emits nothing (its budget counts
        refinement steps)."""
        self._ensure_state()
        wave = self._take_wave()
        if not wave:
            return []
        slots = [self._free.pop(0) for _ in wave]
        emissions, mask = self.backend.admit(wave, slots)
        for slot, r in zip(slots, wave):
            self._live[slot] = _Slot(r, length=r.prompt_len)
            self._meta[r.rid].status = RUNNING
        return self._commit_guarded(emissions, mask)

    def decode(self) -> List[int]:
        """Advance every live slot one budget unit in one jitted backend
        step. Lazy paged mode first grows any slot whose write position
        crossed a page boundary — preempting the lowest-priority request
        while the pool is dry — so the jitted step itself never
        allocates.

        Fault containment (ISSUE 10): an injected step fault skips the
        dispatch (nothing advanced — the retry next iteration is free);
        a ``PoolExhausted`` escaping growth (injected, or an accounting
        bug) preempts the growing slots — their snapshots resume
        bit-identically — instead of crashing the engine."""
        self._ensure_state()
        pending = self.backend.pending_slots()
        if pending and all(s in pending for s in self._live):
            return []               # nothing decoding yet — chunks only
        if self.faults is not None and self.faults.step_fails():
            raise InjectedFault("injected decode-step failure (fault plan)")
        if self.backend.lazy:
            # when the pool can't cover the growth, preempt lowest-
            # priority live requests (possibly a growing request itself —
            # freeing it both clears its demand and returns its pages)
            # until it can; (priority, arrival) is a total order, so the
            # highest-priority earliest-arrived request always makes
            # progress and the engine can never preempt itself into a
            # livelock
            growing = self.backend.growth_pending(self._live)
            while growing and self.backend.units_free() < len(growing):
                victim = self._victim_slot()
                self._preempt_slot(victim)
                growing = [s for s in growing if s != victim]
            if growing:
                try:
                    self.backend.grow_slots(growing)
                except PoolExhausted:
                    # growth is atomic (no partial table state): preempt
                    # the growing slots — snapshots resume bit-identically
                    # — and let the rest of the batch proceed
                    self.n_faults_contained += 1
                    for slot in growing:
                        if slot in self._live:
                            self._preempt_slot(slot)
        if not self._live:
            return []
        emissions, mask = self.backend.step(self._live)
        return self._commit_guarded(emissions, mask)

    def generate(self, prompts, max_new_tokens: int, frontend=None,
                 sampling: Optional[SamplingParams] = None) -> np.ndarray:
        """Batch convenience wrapper (the PR-1 era API, now ragged-capable).

        prompts: (B, T) int32 array OR a list of 1-D ragged prompts.
        Returns (B, max_new_tokens) generated ids; rows that stop early at
        ``eos_id`` pad the remaining columns with ``eos_id``.
        """
        if not isinstance(self.backend, TokenDecodeBackend):
            raise TypeError(
                "generate() is a token-emitting API; submit()/result() "
                "serve pair requests")
        rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        rids = [self.submit(row, max_new_tokens, sampling=sampling,
                            frontend=None if frontend is None
                            else np.asarray(frontend[i]))
                for i, row in enumerate(rows)]
        self.run()
        out = np.full((len(rows), max_new_tokens), self.eos_id, np.int32)
        for i, rid in enumerate(rids):
            got = self.result(rid)
            out[i, :got.size] = got
        return out

    # ------------------------------------------------------------------
    # Preemption (lazy paged mode; public for any backend)
    # ------------------------------------------------------------------

    def _victim_slot(self) -> int:
        """Lowest priority class first, then latest arrival (highest rid)
        — with all-default priorities this is exactly the pre-class
        victim, so existing preemption behavior is unchanged."""
        return min(self._live,
                   key=lambda s: (self._live[s].req.priority,
                                  -self._live[s].req.rid))

    def preempt(self, rid: Optional[int] = None) -> Optional[int]:
        """Preempt one in-flight request and re-queue it at the head.

        Default victim is the lowest-priority live request (lowest class,
        latest arrival). Returns the preempted rid, or None when nothing
        is live. The engine calls this automatically when lazy page growth
        finds the pool dry; it is public so tests and external policies
        can force it for ANY backend (ring-KV / SSM slots hold no pages
        but preempt the same way; a pair slot restarts its complex).
        """
        self._ensure_state()
        if not self._live:
            return None
        if rid is None:
            slot = self._victim_slot()
        else:
            matches = [s for s, st in self._live.items()
                       if st.req.rid == rid]
            if not matches:
                raise RequestNotLive(f"request {rid} is not in flight")
            slot = matches[0]
        return self._preempt_slot(slot)

    def _preempt_slot(self, slot: int) -> int:
        """Snapshot + free + re-queue one slot.

        The backend builds the resumable request — the token backend folds
        generated-so-far into the prompt and snapshots the PRNG key chain
        (greedy outputs stay bit-identical to the never-preempted run; a
        sampled request continues its key chain unbroken), the pair
        backend restarts the complex from scratch. Either way the slot
        freezes, its resources free immediately, and the snapshot re-
        enters at the head of its priority class.
        """
        st = self._live.pop(slot)
        bisect.insort(self._free, slot)
        resumed = self.backend.snapshot(slot, st, self._results[st.req.rid])
        self.scheduler.add_front(resumed)
        self._meta[st.req.rid].status = QUEUED
        self.n_preemptions += 1
        return st.req.rid

    # ------------------------------------------------------------------
    # Fault containment (ISSUE 10)
    # ------------------------------------------------------------------

    def _quarantine(self, slot: int, detail: str) -> List[int]:
        """Contain a guard trip on ``slot``: invalidate anything other
        requests could observe from it (written prefix pages), then
        either RETRY — the preemption snapshot resumes the request
        bit-identically, its budget and emitted-so-far intact — or, when
        ``max_retries`` is spent, terminate it FAILED with a structured
        error. Pool state stays intact either way (freed pages return to
        the free list; nothing leaks)."""
        st = self._live[slot]
        rid = st.req.rid
        meta = self._meta[rid]
        self.backend.quarantine(slot)
        self.n_quarantines += 1
        if meta.retries < meta.max_retries:
            meta.retries += 1
            self._preempt_slot(slot)
            return []
        error = {"kind": "guard", "slot": slot, "step": self.step_idx,
                 "retries": meta.retries, "detail": detail}
        self._retire_slot(slot)
        self._finish(rid, FAILED, error)
        return [rid]

    def _commit_guarded(self, emissions: Optional[np.ndarray],
                        mask: np.ndarray) -> List[int]:
        """Drain the backend's guard verdicts BEFORE committing: a slot
        that tripped the non-finite guard this call has its emission
        withheld (the backend already withheld its PRNG/token commit) and
        is quarantined; everything else commits normally."""
        finished: List[int] = []
        if self.guards:
            for slot, detail in sorted(
                    self.backend.take_guard_faults().items()):
                if slot in self._live:
                    mask[slot] = False
                    finished += self._quarantine(slot, detail)
        return finished + self._commit(emissions, mask)

    # ------------------------------------------------------------------
    # Crash-safe checkpoint / restore (ISSUE 10)
    # ------------------------------------------------------------------

    def _ser_req(self, req: Request) -> dict:
        """Serialize one request descriptor. ``on_token`` callbacks are
        host closures and cannot survive a process boundary — they are
        dropped (documented in serve/README.md §Failure semantics)."""
        return {
            "rid": req.rid,
            "tokens": _ser_arr(np.asarray(req.tokens)),
            "max_new_tokens": req.max_new_tokens,
            "sampling": {"temperature": req.sampling.temperature,
                         "top_k": req.sampling.top_k,
                         "seed": req.sampling.seed},
            "frontend": (None if req.frontend is None
                         else _ser_arr(np.asarray(req.frontend))),
            "key_override": (None if req.key_override is None
                             else _ser_arr(np.asarray(req.key_override))),
            "priority": req.priority,
        }

    def _de_req(self, d: dict) -> Request:
        return Request(
            d["rid"], _de_arr(d["tokens"]), d["max_new_tokens"],
            SamplingParams(d["sampling"]["temperature"],
                           d["sampling"]["top_k"], d["sampling"]["seed"]),
            None if d["frontend"] is None else _de_arr(d["frontend"]),
            key_override=(None if d["key_override"] is None
                          else np.asarray(_de_arr(d["key_override"]),
                                          np.uint32)),
            priority=d["priority"])

    def _config_sig(self) -> dict:
        """The config facts a restore target must agree on."""
        backend = self.backend
        return {"family": self.model.cfg.family, "arch": self.model.cfg.name,
                "n_slots": self.n_slots, "max_len": self.max_len,
                "prefill_len": self.prefill_len,
                "paged": backend.paged, "lazy": backend.lazy,
                "page_size": getattr(backend, "page_size", None),
                "n_pages": getattr(backend, "n_pages", None),
                "chunk_size": getattr(backend, "chunk_size", None),
                "prefix_cache": getattr(backend, "_prefix", None)
                is not None}

    def snapshot_engine(self) -> dict:
        """Serialize ALL host state to a JSON-compatible dict, without
        perturbing the running engine: lifecycle records, results so
        far, scheduler queues, and every live slot as its preemption-
        snapshot resume request (generated folded into the prompt, PRNG
        chain in ``key_override`` — the exact state the bit-identical
        preemption/resume contract is built on; a mid-ChunkPlan slot
        serializes as its whole original request, nothing emitted yet).

        Device state is deliberately NOT captured: pages, caches and the
        prefix index are rebuilt by re-prefill on the restored engine,
        which the parity contracts make bit-identical. Prefix-index
        chain digests ride along for audit only.

        ``restore_engine`` on a freshly constructed engine of the same
        config resumes the workload bit-identically (greedy and
        sampled), for every cache family."""
        front, arrivals = self.scheduler.snapshot()
        live = [self._ser_req(self.backend.snapshot_request(
                    slot, st, self._results[st.req.rid]))
                for slot, st in sorted(self._live.items())]
        prefix = getattr(self.backend, "_prefix", None)
        return {
            "version": _SNAPSHOT_VERSION,
            "config": self._config_sig(),
            "step_idx": self.step_idx,
            "next_rid": self._next_rid,
            "counters": {"preemptions": self.n_preemptions,
                         "quarantines": self.n_quarantines,
                         "faults_contained": self.n_faults_contained,
                         "advanced": self._advanced},
            "meta": {str(rid): {"status": m.status, "error": m.error,
                                "retries": m.retries,
                                "max_retries": m.max_retries,
                                "deadline": m.deadline}
                     for rid, m in self._meta.items()},
            "results": {str(rid): _ser_arr(res)
                        for rid, res in self._results.items()},
            "done": {str(rid): bool(v) for rid, v in self._done.items()},
            "queue": {"front": [self._ser_req(r) for r in front],
                      "arrivals": [self._ser_req(r) for r in arrivals]},
            "live": live,
            "prefix_digests": ([k.hex() for k in prefix._entries]
                               if prefix is not None else []),
        }

    def restore_engine(self, state: dict) -> None:
        """Rebuild the checkpointed host state on THIS engine (freshly
        constructed, same config, nothing submitted). Live requests
        re-enter at the head of the queue exactly as preempted requests
        do; the next ``run()``/``step()`` re-admits and resumes them
        bit-identically."""
        if state.get("version") != _SNAPSHOT_VERSION:
            raise ValueError(
                f"engine snapshot version {state.get('version')!r} != "
                f"{_SNAPSHOT_VERSION} — refusing to restore")
        if self._live or len(self.scheduler) or self._results:
            raise ValueError("restore_engine needs a fresh engine: this "
                             "one already has requests")
        mine, theirs = self._config_sig(), state["config"]
        if mine != theirs:
            diff = {k: (theirs.get(k), mine.get(k))
                    for k in set(mine) | set(theirs)
                    if theirs.get(k) != mine.get(k)}
            raise ValueError(f"engine config mismatch (snapshot vs this "
                             f"engine): {diff}")
        self.step_idx = state["step_idx"]
        self._next_rid = state["next_rid"]
        counters = state["counters"]
        self.n_preemptions = counters["preemptions"]
        self.n_quarantines = counters["quarantines"]
        self.n_faults_contained = counters["faults_contained"]
        self._advanced = counters["advanced"]
        self._meta = {int(rid): _ReqMeta(status=m["status"],
                                         error=m["error"],
                                         retries=m["retries"],
                                         max_retries=m["max_retries"],
                                         deadline=m["deadline"])
                      for rid, m in state["meta"].items()}
        self._results = {int(rid): _de_arr(res)
                         for rid, res in state["results"].items()}
        self._done = {int(rid): v for rid, v in state["done"].items()}
        live = [self._de_req(d) for d in state["live"]]
        front = [self._de_req(d) for d in state["queue"]["front"]]
        arrivals = [self._de_req(d) for d in state["queue"]["arrivals"]]
        for req in live:
            # a live slot resumes the way a preempted request does: at
            # the queue head, QUEUED until re-admission
            self._meta[req.rid].status = QUEUED
        self.scheduler.restore(
            sorted(live + front, key=lambda r: r._order), arrivals)

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------

    def _ensure_state(self) -> None:
        self.backend.ensure_state()

    def _commit(self, emissions: Optional[np.ndarray],
                mask: np.ndarray) -> List[int]:
        """Record this step's emissions and retire finished requests.

        ``mask`` marks slots that advanced one budget unit; ``emissions``
        is per-slot token ids (token backend) or None (pair backend —
        nothing emitted incrementally; the result is fetched from the
        backend when the budget drains)."""
        finished = []
        for slot in [s for s in self._live if mask[s]]:
            st = self._live[slot]
            t = None if emissions is None else int(emissions[slot])
            if t is not None:
                self._results[st.req.rid].append(t)
            st.generated += 1
            self._advanced += 1
            if st.req.on_token is not None:
                # streaming: emitted id for token backends; non-emitting
                # backends drain their per-step output instead
                st.req.on_token(t if t is not None
                                else self.backend.stream_result(slot, st))
            if ((t is not None and t == self.eos_id)
                    or st.generated >= st.req.max_new_tokens):
                res = self.backend.fetch_result(slot, st)
                if res is not None:
                    self._results[st.req.rid] = res
                self._finish(st.req.rid, OK)
                finished.append(st.req.rid)
                del self._live[slot]
                bisect.insort(self._free, slot)
                self.backend.release(slot)
        return finished
