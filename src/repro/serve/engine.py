"""Continuous-batching serve engine over the ragged flash-decode path.

The engine owns ``n_slots`` decode lanes. Each slot is one batch row of
every cache leaf — a ``max_len`` KV segment (ring window / SSM state for
those families), its own ``length`` entry, sampling state (temperature,
top-k, PRNG key chain) and an output buffer. A FIFO scheduler admits
queued requests into freed slots; each admission wave is prefilled
right-padded (batch padded to ``n_slots`` and prompt padded to the wave
maximum or a pinned ``prefill_len``, so at most a handful of prefill
programs ever compile) and scattered into the slot cache with
``Model.insert_cache``. Decode is ONE jitted step over the full slot batch
every iteration — per-request raggedness rides in the ``lengths`` vector
the flash-decode kernel block-skips on — so arbitrary arrival/finish
patterns never recompile and never stall on the slowest request.

Determinism contract (tested in tests/test_serve_engine.py): every
per-slot computation is batch-row independent and the sampler key chain is
per-request, so a request's output is identical whether it runs alone or
packed with strangers — provided ``prefill_len`` is pinned (the padded
prompt length is the one shape that changes with wave composition).

Cache kinds (all pytrees, all jit-traceable; stored in the flash-decode
kernels' kv-head-major layout since ISSUE 5 — the decode step hands them
to the kernels zero-copy, see serve/README.md §Cache layout contract):

- full KV            (dense/moe archs)        — (L, B, KV, S_max, hd),
- paged KV           (full-KV + ``page_size``) — shared (L, KV, n_pages,
  ps, hd) pool + per-page phi_k factor slab + per-slot page tables,
- ring KV            (sliding-window archs)   — (L, B, KV, window, hd),
- SSM state + conv   (ssm/hybrid archs)       — constant size.

Paged mode (pass ``page_size``) replaces the per-slot ``max_len`` segment
with a vLLM-style shared page pool. Since ISSUE 4 page reservation is LAZY
by default: admission reserves only the pages covering a request's prompt,
and ``decode`` grows a slot by one page when its length crosses a page
boundary. When the pool runs dry mid-flight the engine PREEMPTS the
lowest-priority in-flight request (latest arrival): its generated tokens
are snapshotted into its prompt, its PRNG key chain is snapshotted, its
pages free immediately, and it re-enters at the head of the queue for
re-prefill — greedy outputs are bit-identical to the never-preempted run.
``page_reservation="whole"`` restores the PR-3 whole-request reservation
(decode never allocates, nothing is ever preempted for pages). Retired
slots are frozen via the length-0 active mask so a stale page table can
never scribble on reallocated pages. See serve/README.md §Paged KV.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.serve.pages import PagePool
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import FIFOScheduler, Request

__all__ = ["ServeEngine"]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied decode lane.

    ``length`` mirrors ``cache["length"][slot]``: it is the position the
    NEXT decode step will write, which is what lazy page growth gates on
    (no device read-back in the decode loop)."""
    req: Request
    generated: int = 0
    length: int = 0


class ServeEngine:
    """Slot-based continuous-batching engine (prefill/decode/sample).

    Args:
        model: a decode-capable ``Model`` (prefill/decode/init_cache/
            insert_cache).
        params: parameter pytree.
        max_len: per-slot cache segment length (prompt + decode budget must
            fit for full-KV families in contiguous mode).
        eos_id: generation stops when this id is sampled (it is kept in the
            output; remaining columns of ``generate`` pad with it). -1
            never matches, i.e. requests always run out their budget.
        n_slots: fixed decode batch — the number of concurrent requests.
        prefill_len: pinned padded prompt length. None pads each admission
            wave to its own maximum (fewest wasted FLOPs); pinning it makes
            request outputs independent of wave composition and bounds
            prefill compiles to one. A preempted request's resumed prompt
            (original prompt + generated-so-far) may exceed it; such waves
            pad to the resumed length instead.
        page_size: enables PAGED KV for full-KV families — the cache
            becomes a shared pool of ``n_pages`` pages of ``page_size``
            tokens (K, V, and the per-page phi_k factor slab), admission is
            gated on free pages instead of the slot-segment bound, and a
            request may exceed ``max_len`` as long as its pages fit. Ring-KV
            and SSM-only families ignore it (their caches are already
            constant-size per slot).
        n_pages: pool size; defaults to ``n_slots * ceil(max_len /
            page_size)`` — the same HBM the contiguous layout would commit.
        pages_per_slot: page-table width = one request's max page count.
            Defaults to ``n_pages`` (a lone request may take the whole
            pool); lower it to bound the per-step logical view.
        page_reservation: ``"lazy"`` (default) reserves only the prompt's
            pages at admit and grows on demand, preempting when the pool
            runs dry; ``"whole"`` reserves a request's full worst-case
            footprint at admit (PR-3 behaviour — decode never allocates).
        scheduler_policy: ``"fifo"`` (default) admits in arrival order;
            ``"spf"`` admits the shortest queued prompt first. Preempted
            requests resume ahead of arrivals under either policy.
    """

    def __init__(self, model: Model, params: dict, max_len: int = 1024,
                 eos_id: int = -1, n_slots: int = 4,
                 prefill_len: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 pages_per_slot: Optional[int] = None,
                 page_reservation: str = "lazy",
                 scheduler_policy: str = "fifo"):
        assert model.prefill is not None and model.decode is not None, \
            "model is not decode-capable"
        assert page_reservation in ("lazy", "whole"), page_reservation
        self.model, self.params = model, params
        self.max_len, self.eos_id = max_len, eos_id
        self.n_slots, self.prefill_len = n_slots, prefill_len
        cfg = model.cfg
        self._vocab = cfg.vocab
        self._front_dim = (cfg.frontend_len, cfg.d_model)
        # full-KV families must fit prompt + budget inside the slot segment
        # (contiguous mode) or inside the page pool (paged mode)
        self._bounded_cache = (cfg.family in ("dense", "moe", "hybrid")
                               and not (cfg.window and cfg.window < max_len))
        self._paged = (page_size is not None and self._bounded_cache
                       and model.init_paged_cache is not None)
        self._lazy = self._paged and page_reservation == "lazy"
        self.n_preemptions = 0
        if self._paged:
            self.page_size = page_size
            self.n_pages = n_pages or n_slots * _ceil_to(max_len,
                                                         page_size) // page_size
            self.pages_per_slot = min(pages_per_slot or self.n_pages,
                                      self.n_pages)
            self._pool = PagePool(self.n_pages, page_size)
            self._slot_pages: Dict[int, List[int]] = {}
        self.scheduler = FIFOScheduler(policy=scheduler_policy)
        self._next_rid = 0
        self._results: Dict[int, List[int]] = {}
        self._done: Dict[int, bool] = {}
        self._live: Dict[int, _Slot] = {}         # slot -> _Slot
        self._free: List[int] = list(range(n_slots))
        self._cache = None                        # allocated on first step

        def _pf(p, toks, front, lengths, max_len):
            batch = {"tokens": toks}
            if front is not None:
                batch["frontend"] = front
            return model.prefill(p, batch, max_len=max_len, lengths=lengths)

        self._prefill = jax.jit(_pf, static_argnames=("max_len",))
        # max_pages is a STATIC cap on the pages a paged decode step may
        # reference: the engine passes a power-of-two rounding of its
        # host-mirrored longest live length, so the paged XLA fallback
        # gathers Θ(longest request) instead of the full page-table width
        # while recompiling at most log2(pages_per_slot) times.
        self._decode = jax.jit(model.decode, static_argnames=("max_pages",))
        self._insert = jax.jit(model.insert_cache)
        if self._paged:
            self._insert_paged = jax.jit(model.insert_paged)
            self._grow_tables = jax.jit(model.grow_page_table)

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               frontend: Optional[np.ndarray] = None) -> int:
        """Queue one request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, np.asarray(tokens), max_new_tokens,
                      sampling or SamplingParams(), frontend)
        if self.prefill_len is not None:
            assert req.tokens.size <= self.prefill_len, \
                (req.tokens.size, self.prefill_len)
        if self._bounded_cache and self._paged:
            # paged: prompt + budget may exceed max_len (the PR-2 segment
            # bound is gone). The real bounds are the request's own
            # page-table row and the pool itself — a footprint the pool
            # can never cover would preempt everything and still deadlock
            needed = self._pages_needed(req)
            cap = min(self.pages_per_slot, self.n_pages)
            assert needed <= cap, \
                f"paged mode: request footprint {needed} pages " \
                f"(ceil((prompt {req.prompt_len} + budget {max_new_tokens} " \
                f"- 1) / page_size {self.page_size})) exceeds {cap} " \
                f"(page-table row width {self.pages_per_slot}, " \
                f"pool {self.n_pages} pages)"
        elif self._bounded_cache:
            assert req.prompt_len + max_new_tokens <= self.max_len, \
                f"contiguous mode: prompt {req.prompt_len} + budget " \
                f"{max_new_tokens} exceeds the per-slot segment " \
                f"max_len={self.max_len} (paged mode lifts this bound — " \
                f"pass page_size)"
        # ring-KV keeps only the last `window` keys and SSM state is
        # constant-size, so those families accept prompts of any length
        self._results[rid] = []
        self._done[rid] = False
        self.scheduler.add(req)
        return rid

    def result(self, rid: int) -> np.ndarray:
        """Generated ids so far for ``rid`` (complete iff ``is_done``)."""
        return np.asarray(self._results[rid], np.int32)

    def is_done(self, rid: int) -> bool:
        return self._done[rid]

    @property
    def occupancy(self) -> int:
        return len(self._live)

    def page_stats(self) -> dict:
        """Pool accounting snapshot (empty for unpaged engines)."""
        if not self._paged:
            return {}
        return {"n_pages": self.n_pages, "n_free": self._pool.n_free,
                "watermark": self._pool.watermark,
                "grown": self._pool.n_grown,
                "preemptions": self.n_preemptions}

    # ------------------------------------------------------------------
    # Engine steps
    # ------------------------------------------------------------------

    def step(self) -> List[int]:
        """Admit queued requests into free slots, then advance every live
        slot one token. Returns rids that finished during this step."""
        self._ensure_state()
        finished = []
        if self._free and len(self.scheduler):
            finished += self.admit()
        if self._live:
            finished += self.decode()
        return finished

    def run(self) -> None:
        """Step until the queue and all slots drain."""
        self._ensure_state()
        while self._live or len(self.scheduler):
            self.step()

    def _page_cap(self) -> Optional[int]:
        """Static page bound for this decode step: pow2-rounded pages of
        the longest live length (+1 for the position being written), so
        the jitted step recompiles only when a length crosses a doubling
        boundary. None for unpaged engines."""
        if not self._paged:
            return None
        longest = max((st.length for st in self._live.values()), default=0)
        need = max(1, -(-(longest + 1) // self.page_size))
        cap = 1
        while cap < need:
            cap *= 2
        return min(cap, self.pages_per_slot)

    def _pages_needed(self, req: Request) -> int:
        """Pages a request can ever touch: its final cache length is
        ``prompt + budget - 1`` (the last sampled token is never fed back)."""
        return self._pool.pages_needed(req.prompt_len + req.max_new_tokens - 1)

    def _pages_at_admit(self, req: Request) -> int:
        """Pages reserved at admission: just the prompt's under lazy
        growth, the full worst-case footprint under ``"whole"``."""
        if self._lazy:
            return self._pool.pages_needed(req.prompt_len)
        return self._pages_needed(req)

    def _take_wave(self) -> List[Request]:
        """Pop the next admission wave: one request per free slot, gated in
        paged mode on free-page accounting — admit while the head request's
        admission reservation (prompt pages under lazy growth, the full
        footprint under whole-request reservation) fits; no head-of-line
        bypass within the policy. A resumed request whose prompt outgrew a
        pinned ``prefill_len`` rides a SOLO wave: padding a mixed wave to
        the resumed length would change co-admitted requests' padded
        prompt length, which is exactly the shape the determinism contract
        pins (it feeds MoE expert capacity)."""
        wave: List[Request] = []
        reserved = 0
        while len(wave) < len(self._free):
            r = self.scheduler.peek()
            if r is None:
                break
            over = (self.prefill_len is not None
                    and r.tokens.size > self.prefill_len)
            if over and wave:
                break                    # over-length request: next wave
            if self._paged:
                needed = self._pages_at_admit(r)
                if needed > self._pool.n_free - reserved:
                    break                # backpressure: wait for frees
                reserved += needed
            wave.append(self.scheduler.take(1)[0])
            if over:
                break                    # solo wave for the resumed prompt
        return wave

    def admit(self) -> List[int]:
        """Prefill the next admission wave into freed slots and emit each
        admitted request's first token (from its prefill logits)."""
        self._ensure_state()
        wave = self._take_wave()
        if not wave:
            return []
        slots = [self._free.pop(0) for _ in wave]
        ns, w = self.n_slots, len(wave)

        # right-pad prompts; pad the wave batch to n_slots so exactly one
        # prefill program serves every wave size (padding rows are dropped
        # at insert via an out-of-range slot id). A resumed prompt may
        # exceed a pinned prefill_len — that wave pads to the resumed
        # length, and _take_wave made it a SOLO wave so no co-admitted
        # request sees the changed padding
        pl = max(r.tokens.size for r in wave)
        if self.prefill_len is not None:
            pl = max(self.prefill_len, pl)
        toks = np.zeros((ns, pl), np.int32)
        lengths = np.ones((ns,), np.int32)
        for i, r in enumerate(wave):
            toks[i, :r.tokens.size] = r.tokens
            lengths[i] = r.prompt_len
        front = None
        has_front = [r.frontend is not None for r in wave]
        if any(has_front):
            assert all(has_front), "wave mixes frontend/frontend-less requests"
            front = np.zeros((ns,) + self._front_dim, np.float32)
            for i, r in enumerate(wave):
                front[i] = r.frontend
            front = jnp.asarray(front)

        front_len = self._front_dim[0] if front is not None else 0
        if self._paged:
            # the wave cache only needs to hold the padded prompt, page-
            # aligned — NOT a full max_len segment; pages scatter from it
            pf_len = _ceil_to(pl + front_len, self.page_size)
        else:
            pf_len = self.max_len
        logits, wave_cache = self._prefill(
            self.params, jnp.asarray(toks), front, jnp.asarray(lengths),
            pf_len)
        slot_ids = np.full((ns,), ns, np.int32)    # padding rows -> dropped
        slot_ids[:w] = slots
        if self._paged:
            # lazy: reserve only the prompt's pages — decode grows the
            # table on page-boundary crossings. whole: reserve the full
            # footprint so decode never allocates mid-flight
            tables = np.full((ns, self.pages_per_slot), self.n_pages,
                             np.int32)
            for i, (slot, r) in enumerate(zip(slots, wave)):
                pages = self._pool.alloc(self._pages_at_admit(r))
                self._slot_pages[slot] = pages
                tables[i, :len(pages)] = pages
            self._cache = self._insert_paged(self._cache, wave_cache,
                                             slot_ids, jnp.asarray(tables))
        else:
            self._cache = self._insert(self._cache, wave_cache, slot_ids)

        # per-slot sampling state + per-request PRNG chains; a preempted
        # request resumes from its key snapshot so its sample stream stays
        # aligned with its token count
        sl = jnp.asarray(np.asarray(slots, np.int32))
        self._temps = self._temps.at[sl].set(jnp.asarray(
            [r.sampling.temperature for r in wave], jnp.float32))
        self._topks = self._topks.at[sl].set(jnp.asarray(
            [r.sampling.top_k for r in wave], jnp.int32))
        self._keys = self._keys.at[sl].set(jnp.stack(
            [jax.random.PRNGKey(r.sampling.seed) if r.key_override is None
             else jnp.asarray(r.key_override, jnp.uint32) for r in wave]))

        # first token: scatter wave-row logits into slot rows, sample
        lg = jnp.zeros((ns, logits.shape[-1]), logits.dtype)
        lg = lg.at[jnp.asarray(slot_ids)].set(logits[:, 0], mode="drop")
        mask = np.zeros((ns,), bool)
        mask[slots] = True
        for slot, r in zip(slots, wave):
            self._live[slot] = _Slot(r, length=r.prompt_len)
        return self._sample_and_commit(lg, mask)

    def decode(self) -> List[int]:
        """One jitted decode step over the full slot batch. Lazy paged
        mode first grows any slot whose write position crossed a page
        boundary — preempting the lowest-priority request if the pool is
        dry — so the jitted step itself never allocates."""
        self._ensure_state()
        if self._lazy:
            self._grow_pages()
        if not self._live:
            return []
        logits, self._cache = self._decode(self.params, self._cache,
                                           self._last_tok,
                                           max_pages=self._page_cap())
        for st in self._live.values():
            st.length += 1
        mask = np.zeros((self.n_slots,), bool)
        mask[list(self._live)] = True
        return self._sample_and_commit(logits[:, 0], mask)

    def generate(self, prompts, max_new_tokens: int, frontend=None,
                 sampling: Optional[SamplingParams] = None) -> np.ndarray:
        """Batch convenience wrapper (the PR-1 era API, now ragged-capable).

        prompts: (B, T) int32 array OR a list of 1-D ragged prompts.
        Returns (B, max_new_tokens) generated ids; rows that stop early at
        ``eos_id`` pad the remaining columns with ``eos_id``.
        """
        rows = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        rids = [self.submit(row, max_new_tokens, sampling=sampling,
                            frontend=None if frontend is None
                            else np.asarray(frontend[i]))
                for i, row in enumerate(rows)]
        self.run()
        out = np.full((len(rows), max_new_tokens), self.eos_id, np.int32)
        for i, rid in enumerate(rids):
            got = self.result(rid)
            out[i, :got.size] = got
        return out

    # ------------------------------------------------------------------
    # Preemption (lazy paged mode; public for any cache family)
    # ------------------------------------------------------------------

    def preempt(self, rid: Optional[int] = None) -> Optional[int]:
        """Preempt one in-flight request and re-queue it at the head.

        Default victim is the lowest-priority live request (priority is
        arrival order, so: the highest rid). Returns the preempted rid, or
        None when nothing is live. The engine calls this automatically
        when lazy page growth finds the pool dry; it is public so tests
        and external policies can force it for ANY cache family (ring-KV /
        SSM slots hold no pages but preempt the same way).
        """
        self._ensure_state()
        if not self._live:
            return None
        if rid is None:
            slot = max(self._live, key=lambda s: self._live[s].req.rid)
        else:
            matches = [s for s, st in self._live.items()
                       if st.req.rid == rid]
            assert matches, f"request {rid} is not in flight"
            slot = matches[0]
        return self._preempt_slot(slot)

    def _preempt_slot(self, slot: int) -> int:
        """Snapshot + free + re-queue one slot.

        The victim's generated-so-far tokens are appended to its prompt
        (budget shrinks by the same amount), its PRNG key chain is
        snapshotted into ``key_override``, its slot is frozen (length 0)
        and its pages return to the pool immediately. Re-prefill of
        prompt + generated reproduces the exact cache the preempted decode
        had built — prefill/decode parity is the tested invariant — so a
        greedy request's output is bit-identical to the run that was never
        preempted, and a sampled request continues its key chain unbroken.
        """
        st = self._live.pop(slot)
        bisect.insort(self._free, slot)
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        if self._paged:
            self._pool.free(self._slot_pages.pop(slot))
        req = st.req
        gen = self._results[req.rid][-st.generated:]
        resumed = Request(
            req.rid, np.concatenate([req.tokens,
                                     np.asarray(gen, np.int32)]),
            req.max_new_tokens - st.generated, req.sampling, req.frontend,
            key_override=np.asarray(self._keys)[slot])
        self.scheduler.add_front(resumed)
        self.n_preemptions += 1
        return req.rid

    def _grow_pages(self) -> None:
        """Lazy growth pre-pass: allocate the next page for every live
        slot whose write position (== its host-mirrored length) crossed
        its page-table frontier, then push the new table rows to the
        device in one fixed-shape jitted scatter. When the pool can't
        cover the growth, preempt lowest-priority live requests (possibly
        a growing request itself — freeing it both clears its demand and
        returns its pages) until it can; priority is a total order on
        arrival, so the earliest-arrived request always makes progress and
        the engine can never preempt itself into a livelock."""
        ps = self.page_size
        growing = [s for s, st in self._live.items()
                   if st.length // ps >= len(self._slot_pages[s])]
        while growing and self._pool.n_free < len(growing):
            victim = max(self._live, key=lambda s: self._live[s].req.rid)
            self._preempt_slot(victim)
            growing = [s for s in growing if s != victim]
        if not growing:
            return
        slot_ids = np.full((self.n_slots,), self.n_slots, np.int32)
        tables = np.full((self.n_slots, self.pages_per_slot), self.n_pages,
                         np.int32)
        for i, slot in enumerate(growing):
            pages = self._slot_pages[slot]
            pages += self._pool.grow(1)
            assert len(pages) <= self.pages_per_slot, (slot, len(pages))
            slot_ids[i] = slot
            tables[i, :len(pages)] = pages
        self._cache = self._grow_tables(self._cache, jnp.asarray(slot_ids),
                                        jnp.asarray(tables))

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------

    def _ensure_state(self) -> None:
        if self._cache is not None:
            return
        ns = self.n_slots
        if self._paged:
            self._cache = self.model.init_paged_cache(
                ns, self.n_pages, self.page_size, self.pages_per_slot)
        else:
            self._cache = self.model.init_cache(ns, self.max_len)
        self._temps = jnp.zeros((ns,), jnp.float32)
        self._topks = jnp.zeros((ns,), jnp.int32)
        self._keys = jnp.zeros((ns, 2), jnp.uint32)
        self._last_tok = jnp.zeros((ns, 1), jnp.int32)

    def _retire_slot(self, slot: int) -> None:
        """Free a finished slot: zero its cache length so ``decode_step``'s
        active mask freezes the lane (ISSUE 3: retired slots used to keep
        advancing their length and writing garbage KV every step — fatal
        under paging, where the stale page table points at pages that may
        already belong to another request), and return its pages."""
        self._cache["length"] = self._cache["length"].at[slot].set(0)
        if self._paged:
            self._pool.free(self._slot_pages.pop(slot))

    def _sample_and_commit(self, logits2d, mask: np.ndarray) -> List[int]:
        """Sample all slots, commit key/token state for ``mask`` slots only
        (keeping every request's key chain aligned with its token count),
        record tokens and retire finished requests."""
        toks, new_keys = sample_tokens(logits2d, self._temps, self._topks,
                                       self._keys, self._vocab)
        m = jnp.asarray(mask)
        self._keys = jnp.where(m[:, None], new_keys, self._keys)
        self._last_tok = jnp.where(m[:, None], toks[:, None], self._last_tok)
        toks_np = np.asarray(toks)

        finished = []
        for slot in [s for s in self._live if mask[s]]:
            st = self._live[slot]
            t = int(toks_np[slot])
            self._results[st.req.rid].append(t)
            st.generated += 1
            if t == self.eos_id or st.generated >= st.req.max_new_tokens:
                self._done[st.req.rid] = True
                finished.append(st.req.rid)
                del self._live[slot]
                bisect.insort(self._free, slot)
                self._retire_slot(slot)
        return finished
