"""Batched serving engine: prefill once, decode greedily against the cache.

Cache kinds (all pytrees, all jit-traceable):

- full KV            (dense/moe archs)        — (L, B, S_max, KV, hd),
- ring KV            (sliding-window archs)   — (L, B, window, KV, hd),
- SSM state + conv   (ssm/hybrid archs)       — constant size.

``serve_step`` (= one decode step) is what the decode-shaped dry-run cells
lower; the engine is the runnable wrapper around it (examples/serve_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: dict
    max_len: int = 1024
    eos_id: int = -1          # -1: never stop early

    def __post_init__(self):
        self._decode = jax.jit(self.model.decode)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 frontend: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, T) int32 (same-length; pad upstream). Greedy decode.

        Returns (B, max_new_tokens) generated ids.
        """
        batch = {"tokens": jnp.asarray(prompts)}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        logits, cache = self.model.prefill(self.params, batch,
                                           max_len=self.max_len)
        b = prompts.shape[0]
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(max_new_tokens):
            out[:, i] = np.where(done, self.eos_id, np.asarray(tok[:, 0]))
            done |= np.asarray(tok[:, 0]) == self.eos_id
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return out
