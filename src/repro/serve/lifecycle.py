"""Request lifecycle: terminal statuses, result records, typed errors.

Before ISSUE 10 the serve stack had exactly one request outcome —
success — and every failure path was a hard crash: pool exhaustion
surfaced as a bare ``MemoryError``, in-flight invariants were plain
``assert``s (dead under ``python -O``), and a NaN escaping a factored-
bias step could silently poison shared prefix pages. This module is the
vocabulary of the fault-tolerance layer:

- **Statuses** — a request moves ``QUEUED -> RUNNING -> {OK, FAILED,
  TIMED_OUT, CANCELLED}``; ``REJECTED`` is the terminal state of a
  request that never passed admission validation (``submit(...,
  strict=False)``). Terminal states are final: no transition leaves
  ``TERMINAL_STATUSES``.
- **RequestRecord** — what ``ServeEngine.result`` returns. It IS the
  result array (an ``np.ndarray`` subclass, so every pre-existing caller
  that treated results as arrays still works verbatim) carrying
  ``status`` and ``error`` alongside: ``(status, tokens, error)`` as one
  value.
- **Typed exceptions** — ``PoolExhausted`` subclasses ``MemoryError``
  (existing ``pytest.raises(MemoryError)`` pins and callers survive);
  ``PoolError`` / ``RequestNotLive`` / ``AdmissionRejected`` replace the
  load-bearing asserts in ``pages.py`` / ``engine.py`` / the backends;
  ``EngineStalled`` is the run-loop's no-progress diagnostic;
  ``InjectedFault`` marks a ``serve.faults`` injection so containment
  code can tell a drill from a real fault.

Host-only (statcheck ``host-jnp`` / ``host-assert``): pure
Python/NumPy, no jax, no bare asserts.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = [
    "QUEUED", "RUNNING", "OK", "FAILED", "TIMED_OUT", "CANCELLED",
    "REJECTED", "TERMINAL_STATUSES", "RequestRecord", "ServeError",
    "PoolExhausted", "PoolError", "RequestNotLive", "AdmissionRejected",
    "EngineStalled", "InjectedFault",
]

# -- request statuses -------------------------------------------------------
QUEUED = "QUEUED"          # submitted, waiting for a slot
RUNNING = "RUNNING"        # admitted into a slot (or mid-chunked-prefill)
OK = "OK"                  # ran out its budget / hit eos — result complete
FAILED = "FAILED"          # quarantined and retried past max_retries
TIMED_OUT = "TIMED_OUT"    # deadline_steps elapsed before completion
CANCELLED = "CANCELLED"    # cancel(rid) before completion
REJECTED = "REJECTED"      # failed admission validation (strict=False)

TERMINAL_STATUSES = frozenset(
    {OK, FAILED, TIMED_OUT, CANCELLED, REJECTED})


class RequestRecord(np.ndarray):
    """A result array that knows how its request ended.

    ``ServeEngine.result(rid)`` returns one of these: the generated ids
    (token backends) or the final single representation (pair backend),
    as a plain-looking ndarray, plus:

    - ``status`` — one of the lifecycle statuses above. Non-terminal
      statuses mean the record is a partial result-so-far.
    - ``error`` — ``None`` unless ``status == FAILED`` / ``REJECTED``
      / ``TIMED_OUT``-with-diagnosis; then a dict with at least
      ``kind`` and ``detail`` keys (``slot`` / ``step`` / ``retries``
      when the failure happened in flight).
    - ``tokens`` — the payload as a plain ``np.ndarray`` view (for
      callers that want to shed the subclass).

    Array semantics are untouched: equality asserts, ``.size``,
    concatenation and serialization all behave exactly as before the
    lifecycle existed — which is what keeps every pre-ISSUE-10 caller
    working unchanged.
    """

    def __new__(cls, tokens, status: str = OK,
                error: Optional[dict] = None):
        obj = np.asarray(tokens).view(cls)
        obj.status = status
        obj.error = error
        return obj

    def __array_finalize__(self, obj):
        if obj is None:
            return
        self.status = getattr(obj, "status", OK)
        self.error = getattr(obj, "error", None)

    @property
    def tokens(self) -> np.ndarray:
        """The result payload as a plain ndarray (no lifecycle fields)."""
        return np.asarray(self)

    def __repr__(self):
        return (f"RequestRecord(status={self.status!r}, "
                f"tokens={np.asarray(self)!r}, error={self.error!r})")


# -- typed exceptions -------------------------------------------------------

class ServeError(RuntimeError):
    """Base of every typed serve-stack error (survives ``python -O``)."""


class PoolExhausted(MemoryError):
    """The page pool cannot cover an allocation.

    Subclasses ``MemoryError`` so pre-lifecycle callers (and tests) that
    catch ``MemoryError`` keep working; new code catches the typed name.
    The engine contains it: admission backpressure holds the request in
    the queue, and a mid-flight growth failure preempts the growing
    slots (their snapshots resume bit-identically) instead of crashing.
    """


class PoolError(ServeError):
    """Page-accounting invariant broken: double free, incref of a free
    page, double allocation, or a page id outside the pool. Always a
    caller bug — the pool state is still consistent (the offending
    operation did not apply)."""


class RequestNotLive(ServeError):
    """The rid does not name a live (queued or in-flight) request —
    preempt/cancel of an unknown, finished, or never-submitted id."""


class AdmissionRejected(ValueError):
    """Submit-time validation failed: the request can never be admitted
    (footprint exceeds the page table/pool, prompt exceeds a pinned
    ``prefill_len``, wrong payload type for the backend...). Subclasses
    ``ValueError``: rejection is an input error, not an engine fault.
    ``submit(..., strict=False)`` converts it into a ``REJECTED``
    terminal record instead of raising."""


class EngineStalled(ServeError):
    """``run()`` made no progress for ``stall_limit`` consecutive steps
    while work was still queued — a scheduling/accounting deadlock that
    would otherwise spin forever. The message carries queue/pool/slot
    stats for diagnosis."""


class InjectedFault(ServeError):
    """A ``serve.faults.FaultPlan`` injection (never raised outside a
    drill). Containment paths treat it exactly like the real fault it
    simulates; tests assert on the type to prove the recovery path ran."""
