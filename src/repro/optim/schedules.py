"""LR schedules as pure ``step -> lr`` callables (traceable).

``wsd`` is the warmup-stable-decay schedule MiniCPM trains with
(arXiv:2404.06395): linear warmup, long flat stage, short exponential-ish
decay tail — selected by the minicpm-2b config.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine", "wsd"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio * lr + (1 - min_ratio) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos).astype(jnp.float32)
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int,
        min_ratio: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM). Decay tail: exponential to min_ratio."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * (min_ratio ** t)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out.astype(jnp.float32)
    return f
