"""AdamW with fp32 master state, global-norm clipping, and optional
bf16 gradient compression with error feedback.

Sharding: optimizer states mirror the parameter shardings (ZeRO-1/2
equivalent under GSPMD — each device keeps only its shard of mu/nu because
``train_step``'s out_shardings pin them to the param specs).

Gradient compression (``compress_grads=True``): the gradient crossing the
data-parallel reduction boundary is cast to bf16; the fp32 residual is kept
in an error-feedback buffer and added back next step, so the *long-run*
update is unbiased while the all-reduce moves half the bytes. On TPU the
cast fuses into the reduce-scatter producer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OptState:
    step: jax.Array
    mu: dict
    nu: dict
    err: Optional[dict] = None      # error-feedback residuals

    def tree_flatten(self):
        return (self.step, self.mu, self.nu, self.err), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False

    def init(self, params) -> OptState:
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        err = zeros() if self.compress_grads else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(),
                        nu=zeros(), err=err)

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        new_err = state.err
        if self.compress_grads:
            # error feedback: compress (grad + residual), keep the remainder
            summed = jax.tree.map(lambda g, e: g + e, grads, state.err)
            compressed = jax.tree.map(
                lambda s: s.astype(jnp.bfloat16).astype(jnp.float32), summed)
            new_err = jax.tree.map(lambda s, c: s - c, summed, compressed)
            grads = compressed

        # NOTE: jnp.vdot(g, g) flattens first — a reshape that merges sharded
        # dims is unshardable, so GSPMD all-gathers the ENTIRE gradient to
        # compute the norm (measured: 106 GB f32 gathers on command-r;
        # EXPERIMENTS.md §Perf iteration 1). Elementwise square + reduce
        # shards cleanly.
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        t = state.step + 1
        tf = t.astype(jnp.float32)
        lr = self.lr_fn(state.step)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: self.b2 * n + (1 - self.b2) * g * g,
                          state.nu, grads)

        def upd(p, m, n):
            mh = m / (1 - self.b1 ** tf)
            nh = n / (1 - self.b2 ** tf)
            step = mh / (jnp.sqrt(nh) + self.eps)
            if p.ndim >= 2:   # decay matrices only (standard practice)
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, OptState(t, mu, nu, new_err), metrics
