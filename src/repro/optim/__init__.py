"""Optimizer substrate: AdamW (sharded states), schedules, grad utilities."""
from repro.optim.adamw import AdamW, OptState
from repro.optim.schedules import constant, cosine, wsd

__all__ = ["AdamW", "OptState", "constant", "cosine", "wsd"]
