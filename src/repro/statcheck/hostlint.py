"""AST lint for the host/device split — stdlib-only, no jax import.

The serve engine's throughput story depends on a discipline no type
checker sees: the REQUEST-shaped side (page allocator, scheduler, engine
core) is pure host Python/NumPy, and the DEVICE-shaped side (backend
programs) is jit-compiled jax. A stray ``jnp`` in the allocator turns an
O(1) bookkeeping step into a device dispatch (and a sync, if anything
reads it back); a ``block_until_ready`` in the engine loop serializes the
pipelined decode steps the engine exists to overlap. These are one-line
mistakes that survive every unit test.

Four rules, suppressible per line with ``# statcheck: allow(<rule>)``:

- ``host-jnp`` — ``jax``/``jax.numpy`` usage in host-side modules
  (``serve/pages.py``, ``serve/scheduler.py``, ``serve/engine.py``).
  Sharding moves cache bytes, never allocator arithmetic.
- ``host-assert`` — bare ``assert`` statements in host-side serve
  modules (ISSUE 10): a load-bearing assert vanishes under
  ``python -O``, turning an accounting violation into silent state
  corruption. Failures must be TYPED (``serve/lifecycle.py``) so the
  engine can contain them.
- ``host-sync`` — ``.block_until_ready()`` anywhere in ``serve/``
  (the engine must stay dispatch-only; benchmarks time, engines don't),
  and ``np.asarray``/``jax.device_get`` applied to device state
  (``self._cache``-rooted expressions or names like ``logits``) inside a
  ``for``/``while`` loop body — a hidden per-iteration device sync.
- ``blockspec-bounds`` — a Pallas ``BlockSpec`` index map that reads a
  scalar-prefetch ref (``*_ref`` parameter subscript) must clamp the
  result (``jnp.minimum``/``maximum``/``clip``) before returning block
  indices: an unclamped page-table lookup faults on stale tables instead
  of aliasing the previous block (see ``kernels/flash_decode.py``).

This module intentionally imports nothing beyond the stdlib so the CI
lint job (which installs only ruff, not jax) can run it.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, List, Set, Tuple

__all__ = ["LintFinding", "lint_file", "lint_tree", "HOST_MODULES",
           "SERVE_MODULES", "KERNEL_MODULES"]

# modules that must never touch jax: request/page/schedule bookkeeping
HOST_MODULES = (
    os.path.join("src", "repro", "serve", "pages.py"),
    os.path.join("src", "repro", "serve", "prefix.py"),
    os.path.join("src", "repro", "serve", "scheduler.py"),
    os.path.join("src", "repro", "serve", "engine.py"),
    os.path.join("src", "repro", "serve", "lifecycle.py"),
    os.path.join("src", "repro", "serve", "faults.py"),
)
# modules where the host-sync rules apply (device code allowed)
SERVE_MODULES = (
    os.path.join("src", "repro", "serve", "backend.py"),
    os.path.join("src", "repro", "serve", "sampling.py"),
) + HOST_MODULES
# modules where BlockSpec index maps are audited
KERNEL_MODULES = (
    os.path.join("src", "repro", "kernels", "flash_decode.py"),
    os.path.join("src", "repro", "kernels", "flashbias_attn.py"),
    os.path.join("src", "repro", "kernels", "ssd_scan.py"),
)

_ALLOW_RE = re.compile(r"#\s*statcheck:\s*allow\(([\w-]+)\)")

# names whose np.asarray()/device_get() inside a loop is a per-iteration
# device->host sync (heuristic: device-state roots used by the backends)
_DEVICE_ROOTS = ("_cache", "logits", "emissions")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.path}:{self.line}: {self.message}"


def _suppressed(source_lines: List[str], line: int, rule: str) -> bool:
    if 1 <= line <= len(source_lines):
        m = _ALLOW_RE.search(source_lines[line - 1])
        if m and m.group(1) == rule:
            return True
    return False


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for nested Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _jax_aliases(tree: ast.Module) -> Set[str]:
    """Local names bound to jax or jax.numpy by imports."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "jax" or alias.name.startswith("jax."):
                    names.add(alias.asname or alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.module and (node.module == "jax"
                                or node.module.startswith("jax.")):
                for alias in node.names:
                    names.add(alias.asname or alias.name)
    return names


def _check_host_jnp(tree: ast.Module, path: str,
                    lines: List[str]) -> List[LintFinding]:
    findings = []
    aliases = _jax_aliases(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            mod = (node.names[0].name if isinstance(node, ast.Import)
                   else node.module or "")
            if mod == "jax" or mod.startswith("jax."):
                if not _suppressed(lines, node.lineno, "host-jnp"):
                    findings.append(LintFinding(
                        "host-jnp", path, node.lineno,
                        f"host-side module imports '{mod}' — allocator/"
                        "scheduler arithmetic must stay Python/NumPy"))
        elif isinstance(node, ast.Name) and node.id in aliases:
            if isinstance(node.ctx, ast.Load) \
                    and not _suppressed(lines, node.lineno, "host-jnp"):
                findings.append(LintFinding(
                    "host-jnp", path, node.lineno,
                    f"host-side module uses jax-bound name "
                    f"'{node.id}' — a device dispatch in bookkeeping "
                    "code"))
    return findings


def _check_host_assert(tree: ast.Module, path: str,
                       lines: List[str]) -> List[LintFinding]:
    """Bare ``assert`` in host serve modules: gone under ``python -O``,
    so an invariant breach (double free, dead request, bad config) would
    corrupt state silently instead of raising a typed, containable
    error."""
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert) \
                and not _suppressed(lines, node.lineno, "host-assert"):
            findings.append(LintFinding(
                "host-assert", path, node.lineno,
                "bare assert in host serve code — raise a typed "
                "serve.lifecycle error instead (asserts vanish under "
                "python -O)"))
    return findings


def _loop_spans(tree: ast.Module) -> List[Tuple[int, int]]:
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.While)):
            end = getattr(node, "end_lineno", node.lineno)
            spans.append((node.lineno, end))
    return spans


def _in_spans(line: int, spans: List[Tuple[int, int]]) -> bool:
    return any(lo <= line <= hi for lo, hi in spans)


def _check_host_sync(tree: ast.Module, path: str,
                     lines: List[str]) -> List[LintFinding]:
    findings = []
    spans = _loop_spans(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _dotted(node.func)
        if callee.endswith(".block_until_ready"):
            if not _suppressed(lines, node.lineno, "host-sync"):
                findings.append(LintFinding(
                    "host-sync", path, node.lineno,
                    "block_until_ready in serve code serializes the "
                    "dispatch pipeline (benchmarks time; engines "
                    "don't)"))
            continue
        if callee in ("np.asarray", "numpy.asarray", "jax.device_get"):
            arg_src = "".join(_dotted(a) or ast.dump(a)
                              for a in node.args[:1])
            device_ish = any(root in arg_src for root in _DEVICE_ROOTS)
            if device_ish and _in_spans(node.lineno, spans) \
                    and not _suppressed(lines, node.lineno, "host-sync"):
                findings.append(LintFinding(
                    "host-sync", path, node.lineno,
                    f"{callee} on device state inside a loop — a "
                    "device->host sync per iteration"))
    return findings


def _returns_tuple(fn: ast.AST) -> bool:
    if isinstance(fn, ast.Lambda):
        return isinstance(fn.body, ast.Tuple)
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value,
                                                       ast.Tuple):
            return True
    return False


def _ref_params(fn: ast.AST) -> Set[str]:
    args = fn.args
    names = [a.arg for a in args.args + args.posonlyargs
             + args.kwonlyargs]
    return {n for n in names if n.endswith("_ref")}


def _subscripted_names(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Subscript) and isinstance(node.value,
                                                          ast.Name):
            out.add(node.value.id)
    return out


def _has_clamp(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            if callee.split(".")[-1] in ("minimum", "maximum", "clip"):
                return True
    return False


def _check_blockspec_bounds(tree: ast.Module, path: str,
                            lines: List[str]) -> List[LintFinding]:
    """Index-map-shaped functions (return a tuple of block indices) that
    subscript a ``*_ref`` parameter must clamp — kernel BODIES also take
    refs but never return tuples, so they are naturally exempt."""
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.Lambda)):
            continue
        if not _returns_tuple(node):
            continue
        refs = _ref_params(node)
        if not refs or not (_subscripted_names(node) & refs):
            continue
        if _has_clamp(node):
            continue
        if _suppressed(lines, node.lineno, "blockspec-bounds"):
            continue
        name = getattr(node, "name", "<lambda>")
        findings.append(LintFinding(
            "blockspec-bounds", path, node.lineno,
            f"index map '{name}' reads a scalar-prefetch ref without "
            "clamping (jnp.minimum/clip): a stale page table would "
            "index out of the pool instead of aliasing the previous "
            "block"))
    return findings


def lint_file(path: str, *, host: bool = False, serve: bool = False,
              kernel: bool = False) -> List[LintFinding]:
    with open(path) as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    lines = source.splitlines()
    findings: List[LintFinding] = []
    if host:
        findings += _check_host_jnp(tree, path, lines)
        findings += _check_host_assert(tree, path, lines)
    if serve:
        findings += _check_host_sync(tree, path, lines)
    if kernel:
        findings += _check_blockspec_bounds(tree, path, lines)
    return findings


def lint_tree(root: str,
              host_modules: Iterable[str] = HOST_MODULES,
              serve_modules: Iterable[str] = SERVE_MODULES,
              kernel_modules: Iterable[str] = KERNEL_MODULES,
              ) -> List[LintFinding]:
    """Run every AST rule over its module set, rooted at ``root`` (the
    repo checkout). Missing files are skipped: the lint must not couple
    CI to the exact module list of older/newer trees."""
    host = {os.path.join(root, m) for m in host_modules}
    serve = {os.path.join(root, m) for m in serve_modules}
    kernel = {os.path.join(root, m) for m in kernel_modules}
    findings: List[LintFinding] = []
    for path in sorted(host | serve | kernel):
        if not os.path.exists(path):
            continue
        findings += lint_file(path, host=path in host,
                              serve=path in serve,
                              kernel=path in kernel)
    return findings
