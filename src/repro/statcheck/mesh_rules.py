"""Sharding + compiled-HLO checks for mesh-aware serve programs.

``examples/serve_sharded.py`` proves its mesh is real (not cosmetic) by
lowering the decode step against the live sharded state and asserting the
compiled HLO contains cross-device collectives. These helpers generalize
that ad-hoc assert into reusable checkers that any program / any mesh can
run, plus two static audits of the sharding metadata itself:

- ``check_collectives``: compile a program under ``use_mesh_rules`` and
  assert the expected all-reduce / all-gather family actually appears in
  the HLO text — the difference between "the constrain annotations bound"
  and "XLA silently replicated everything".
- ``check_state_axes``: every logical axis a module annotates its state
  with must exist in the active ``Rules`` vocabulary — an unknown name
  silently resolves to replicated, which is exactly the failure mode a
  static check can catch and a benchmark cannot.
- ``check_shard_divisibility``: ``shard_put`` degrades non-divisible dims
  to replicated BY DESIGN (serve state must always place); this audit
  reports which (leaf, dim) pairs would degrade on a given mesh so the
  degradation is a decision, never a surprise.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.dist.sharding import Rules, spec_for, use_mesh_rules
from repro.statcheck.jaxpr_rules import Finding

__all__ = [
    "COLLECTIVE_OPS",
    "check_collectives",
    "check_shard_divisibility",
    "check_state_axes",
    "compiled_collectives",
    "hlo_text",
]

# the cross-device ops a TP/DP-sharded program must contain at least one of
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "collective-permute", "all-to-all")


def hlo_text(jitted, *args, mesh=None, rules: Optional[Rules] = None,
             **kwargs) -> str:
    """Compiled HLO of ``jitted(*args, **kwargs)``, traced under
    ``use_mesh_rules(mesh, rules)`` when a mesh is given (so ``constrain``
    annotations in model code bind exactly as the serve backend's
    ``_with_mesh`` programs do)."""
    if mesh is not None:
        with use_mesh_rules(mesh, rules or Rules()):
            lowered = jitted.lower(*args, **kwargs)
    else:
        lowered = jitted.lower(*args, **kwargs)
    return lowered.compile().as_text()


def compiled_collectives(txt: str,
                         ops: Sequence[str] = COLLECTIVE_OPS) -> List[str]:
    """Which collective op names appear in compiled HLO text."""
    return sorted(op for op in ops if op in txt)


def check_collectives(txt: str, *, program: str,
                      expect_any: Sequence[str] = COLLECTIVE_OPS,
                      expect_all: Sequence[str] = (),
                      forbid: Sequence[str] = ()) -> List[Finding]:
    """Assert collective presence/absence in compiled HLO text.

    ``expect_any`` (default: any cross-device collective) guards against
    cosmetic sharding; ``expect_all`` pins specific ops a program is known
    to need (e.g. the TP head contraction's all-reduce); ``forbid`` bans
    ops a program must never emit (e.g. no collective in a host-planned
    page-table scatter).
    """
    found = compiled_collectives(txt)
    findings = []
    if expect_any and not any(op in found for op in expect_any):
        findings.append(Finding(
            rule="mesh-collectives", program=program,
            message=(f"compiled HLO contains none of {tuple(expect_any)} — "
                     "the mesh sharding is cosmetic (constrain annotations "
                     "did not bind, or XLA replicated the program)")))
    for op in expect_all:
        if op not in found:
            findings.append(Finding(
                rule="mesh-collectives", program=program,
                message=f"expected collective '{op}' missing from "
                        f"compiled HLO (found: {found or 'none'})"))
    for op in forbid:
        if op in found:
            findings.append(Finding(
                rule="mesh-collectives", program=program,
                message=f"forbidden collective '{op}' present in "
                        "compiled HLO"))
    return findings


def check_state_axes(axes_map: Dict[str, Tuple[Optional[str], ...]],
                     rules: Rules, *, program: str,
                     extra_vocab: Iterable[str] = ()) -> List[Finding]:
    """Every logical axis name in ``axes_map`` (leaf -> per-dim logical
    axes, e.g. ``TokenDecodeBackend._state_axes()``) must be part of the
    ``Rules`` vocabulary. An unknown name is not an error at runtime —
    ``Rules.mesh_axes`` resolves it to replicated — which is why a typo
    ('kv_head' for 'kv_heads') silently un-shards a pool and only a
    static check catches it."""
    vocab = set(rules.table) | set(extra_vocab)
    findings = []
    for leaf, axes in axes_map.items():
        for d, logical in enumerate(axes):
            if logical is not None and logical not in vocab:
                findings.append(Finding(
                    rule="state-axes-vocab", program=program,
                    message=(f"cache leaf '{leaf}' dim {d} names unknown "
                             f"logical axis '{logical}' (vocabulary: "
                             f"{sorted(vocab)}) — it would silently "
                             "replicate")))
    return findings


def shard_degradations(shapes: Dict[str, Tuple[int, ...]],
                       axes_map: Dict[str, Tuple[Optional[str], ...]],
                       mesh, rules: Rules) -> List[Tuple[str, int, str]]:
    """(leaf, dim, logical-axis) triples where ``shard_put`` would degrade
    the dim to replicated on ``mesh`` because the dim size does not divide
    the mesh-axis product (mirrors ``shard_put``'s guard exactly)."""
    out = []
    for leaf, shape in shapes.items():
        axes = axes_map.get(leaf)
        if axes is None:
            continue
        spec = spec_for(axes, mesh, rules)
        for d, (logical, entry) in enumerate(zip(axes, spec)):
            if entry is None:
                continue
            ax = entry if isinstance(entry, tuple) else (entry,)
            n = math.prod(int(mesh.shape[a]) for a in ax)
            if n > 1 and int(shape[d]) % n != 0:
                out.append((leaf, d, str(logical)))
    return out


def check_shard_divisibility(shapes: Dict[str, Tuple[int, ...]],
                             axes_map: Dict[str, Tuple[Optional[str], ...]],
                             mesh, rules: Rules, *, program: str,
                             allow: Iterable[str] = ("length",),
                             ) -> List[Finding]:
    """Fail when a leaf OUTSIDE ``allow`` would lose its sharding to the
    ``shard_put`` divisibility guard. Slot-batch rows (``length``,
    sampling state) may legitimately degrade — an odd ``n_slots`` is
    supported — but a KV pool degrading to replicated multiplies serve
    HBM by the TP degree and must be a deliberate choice."""
    allowed = set(allow)
    findings = []
    for leaf, dim, logical in shard_degradations(shapes, axes_map, mesh,
                                                 rules):
        if leaf in allowed:
            continue
        findings.append(Finding(
            rule="shard-divisibility", program=program,
            message=(f"cache leaf '{leaf}' dim {dim} (logical "
                     f"'{logical}') does not divide its mesh axes on "
                     f"{dict(mesh.shape)} — shard_put would silently "
                     "replicate a pool-sized leaf")))
    return findings


def check_backend_mesh(backend, *, program: str = "decode",
                       expect_any: Sequence[str] = COLLECTIVE_OPS,
                       ) -> List[Finding]:
    """The serve_sharded assert, generalized: compile the live backend's
    decode step under its own mesh and run all three mesh rules — real
    collectives in the HLO, state axes within the Rules vocabulary, and
    no silent pool degradation. The backend must be mesh-configured and
    ``ensure_state``-ed."""
    assert backend.mesh is not None, "backend has no mesh configured"
    backend.ensure_state()
    axes_map = {k: v for k, v in backend._state_axes().items()
                if k in backend._cache}
    findings = check_state_axes(axes_map, backend.rules, program=program)
    shapes = {k: tuple(backend._cache[k].shape) for k in axes_map}
    findings += check_shard_divisibility(
        shapes, axes_map, backend.mesh, backend.rules, program=program,
        allow=("length", "ssm_h", "conv_x", "conv_bc"))
    txt = hlo_text(backend._decode, backend.params, backend._cache,
                   backend._last_tok,
                   max_pages=backend.page_cap({}))
    findings += check_collectives(txt, program=program,
                                  expect_any=expect_any)
    return findings
