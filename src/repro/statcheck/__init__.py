"""repro.statcheck — static contracts for the FlashBias serve stack.

Three layers (see README.md for the rule catalog):

1. :mod:`repro.statcheck.jaxpr_rules` + :mod:`repro.statcheck.contracts`
   — trace every jitted serve program per cache family and walk the
   closed jaxprs: no Θ(pool) relayout in the decode step (ISSUE 5), no
   host callback inside jit, the Eq. 3 single-matmul fold on the
   precision-free factored-bias path, bounded recompile keys.
2. :mod:`repro.statcheck.mesh_rules` — compile programs under a mesh and
   assert real collectives in the HLO, logical axes within the ``Rules``
   vocabulary, and no silent ``shard_put`` degradation of pool leaves.
3. :mod:`repro.statcheck.hostlint` — stdlib-only AST lint of the
   host/device split (no ``jnp`` in allocator/scheduler code, no hidden
   per-step syncs, clamped Pallas BlockSpec index maps).

Driven by ``scripts/run_statcheck.py`` (CI: the ``static-contracts``
job). Heavy jax imports are deferred to the submodules so the AST lint
stays importable in environments without jax.
"""
from repro.statcheck.jaxpr_rules import (
    Finding,
    count_primitive,
    eq3_fold_present,
    no_host_callback,
    no_pool_relayout,
    pool_threshold_for,
    walk_eqns,
)

__all__ = ["Finding", "count_primitive", "eq3_fold_present",
           "no_host_callback", "no_pool_relayout", "pool_threshold_for",
           "walk_eqns"]
