"""Trace-time contract checker for the jitted serve programs.

``check_family`` builds the real serve backend for one cache family at
smoke scale, traces every jitted program it owns — prefill, chunked
prefill, decode step, cache insert, paged insert, page-table growth (LM
families); trunk prefill, refinement step, factor-cache insert
(pairformer) — and runs the :mod:`repro.statcheck.jaxpr_rules` walkers
over each closed jaxpr:

- ``no-pool-relayout`` on the decode programs (the ISSUE-5 tripwire:
  zero Θ(pool) transpose/convert/broadcast per decoded token) and on the
  ISSUE-9 prefix-cache ``copy_pages`` copy-on-write program (a page copy
  must stay a Θ(W·page) gather/scatter),
- ``no-host-callback`` on every program,
- ``eq3-fold`` on the pairformer refinement step when the factored-bias
  path is precision-free (FlashBias Eq. 3: ONE matmul of depth D + R),
- ``recompile-bound`` — an arithmetic audit of the engine's static-arg
  space: the pow2 ``max_pages`` rounding must produce at most
  ``log2(pages_per_slot) + 1`` distinct decode/chunk compile keys.

Tracing is abstract (``jax.jit(...).trace`` over ``ShapeDtypeStruct``
params), so the whole sweep runs in seconds on CPU with no kernels
executed. The default ``attn_impl="pallas_interpret"`` matters: the
legacy layout's pool transpose lives in the Pallas layout adapters
(``kernels/ops.py``), so interpret mode is what makes the tripwire able
to *see* it on CPU CI — and ``verify_tripwire`` proves per run that the
discrimination still works by checking that ``cache_layout="legacy"``
fails (a tripwire that cannot fire is not a tripwire).
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.statcheck.jaxpr_rules import (
    Finding,
    eq3_fold_present,
    no_host_callback,
    no_pool_relayout,
    pool_threshold_for,
)

__all__ = ["FAMILIES", "check_family", "run_contracts", "verify_tripwire"]

# smoke-scale serve dimensions shared by every family check
MAX_LEN = 32
N_SLOTS = 4
PAGE_SIZE = 4
CHUNK = 4
RING_WINDOW = 8        # 0 < window < MAX_LEN -> ring KV
PAIR_MAX_LEN = 16
PAIR_FEATS = 64        # pairformer stub residue-feature width

# family -> smoke ArchConfig. "ring" is the dense arch with a sliding
# window (the ring cache is a cache mode, not a config family).
FAMILIES: Dict[str, Callable] = {
    "dense": lambda: smoke_config("stablelm_12b"),
    "moe": lambda: smoke_config("granite_moe_3b_a800m"),
    "ring": lambda: smoke_config("stablelm_12b").replace(window=RING_WINDOW),
    "ssm": lambda: smoke_config("mamba2_130m"),
    "pairformer": lambda: smoke_config("pairformer_lite"),
}


def _abstract_params(model):
    from repro.models.common import abstract_params
    return abstract_params(model.template())


def _token_backend(cfg):
    from repro.models import get_model
    from repro.serve.backend import TokenDecodeBackend
    model = get_model(cfg)
    params = _abstract_params(model)
    paged = (cfg.family in ("dense", "moe", "hybrid")
             and not (cfg.window and cfg.window < MAX_LEN)
             and model.init_paged_cache is not None)
    kwargs = {"page_size": PAGE_SIZE} if paged else {}
    if model.prefill_chunk is not None:
        kwargs["prefill_chunk"] = CHUNK
    if paged and "prefill_chunk" in kwargs and cfg.family in ("dense", "moe"):
        kwargs["prefix_cache"] = True    # ISSUE 9: trace the CoW program too
    be = TokenDecodeBackend(model, params, max_len=MAX_LEN,
                            n_slots=N_SLOTS, **kwargs)
    be.ensure_state()
    return be


def _decode_caps(be) -> List[Optional[int]]:
    """The static ``max_pages`` values worth tracing: the smallest and the
    largest the engine can ever pass (rules are monotone in between)."""
    if not be.paged:
        return [None]
    lo = be.page_cap({0: SimpleNamespace(length=0)})
    hi = be.page_cap({0: SimpleNamespace(
        length=be.pages_per_slot * be.page_size - 1)})
    return sorted({lo, hi})


def _audit_recompile_bound(be, family: str) -> List[Finding]:
    """Enumerate the REAL engine's static-arg space and assert the
    documented compile bound: the pow2 rounding in ``page_cap`` /
    ``_chunk_page_cap`` may produce at most ``log2(pages_per_slot) + 1``
    distinct keys each (serve/README.md §Cache layout contract)."""
    if not be.paged:
        return []
    bound = be.pages_per_slot.bit_length()
    findings = []
    decode_keys = {be.page_cap({0: SimpleNamespace(length=ln)})
                   for ln in range(be.pages_per_slot * be.page_size)}
    if len(decode_keys) > bound:
        findings.append(Finding(
            rule="recompile-bound", program=f"{family}/decode",
            message=(f"decode max_pages takes {len(decode_keys)} distinct "
                     f"values {sorted(decode_keys)} > documented bound "
                     f"{bound} (log2(pages_per_slot) + 1) — the pow2 "
                     "rounding discipline broke")))
    if be.chunk_size:
        saved = be._pending
        chunk_keys = set()
        try:
            for done in range(1, be.pages_per_slot * be.page_size + 1):
                be._pending = {0: SimpleNamespace(done=done)}
                chunk_keys.add(be._chunk_page_cap())
        finally:
            be._pending = saved
        if len(chunk_keys) > bound:
            findings.append(Finding(
                rule="recompile-bound", program=f"{family}/prefill_chunk",
                message=(f"chunk max_pages takes {len(chunk_keys)} "
                         f"distinct values > documented bound {bound}")))
    return findings


def _check_token_family(family: str, cfg) -> List[Finding]:
    be = _token_backend(cfg)
    cache, params = be._cache, be.params
    ns = be.n_slots
    thresh = pool_threshold_for(cache, cfg.n_layers)
    findings: List[Finding] = []

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    # prefill (wave path): padded prompt of one page / a few tokens
    pf_len = PAGE_SIZE * 2
    toks = sds((ns, pf_len), jnp.int32)
    lens = sds((ns,), jnp.int32)
    traced = {"prefill": be._prefill.trace(params, toks, None, lens,
                                           pf_len)}

    last = sds((ns, 1), jnp.int32)
    for cap in _decode_caps(be):
        traced[f"decode[max_pages={cap}]"] = be._decode.trace(
            params, cache, last, max_pages=cap)

    wave_cache = jax.eval_shape(lambda: be.model.init_cache(ns, MAX_LEN))
    slot_ids = sds((ns,), jnp.int32)
    if be.paged:
        wave_cache = jax.eval_shape(lambda: be.model.init_cache(ns, pf_len))
        tables = sds((ns, be.pages_per_slot), jnp.int32)
        traced["insert_paged"] = be._insert_paged.trace(
            cache, wave_cache, slot_ids, tables)
        traced["grow_tables"] = be._grow_tables.trace(cache, slot_ids,
                                                      tables)
    else:
        traced["insert"] = be._insert.trace(cache, wave_cache, slot_ids)

    if be.chunk_size:
        ctoks = sds((ns, be.chunk_size), jnp.int32)
        offs = sds((ns,), jnp.int32)
        cap = _decode_caps(be)[-1]
        traced["prefill_chunk"] = be._chunk.trace(
            params, cache, ctoks, offs, offs, offs, max_pages=cap)

    if getattr(be, "_prefix", None) is not None:
        ids = sds((ns,), jnp.int32)
        traced["copy_pages"] = be._copy_pages.trace(cache, ids, ids)

    for name, tr in traced.items():
        program = f"{family}/{name}"
        findings += no_host_callback(tr.jaxpr, program=program)
        # the relayout tripwire is a DECODE-step contract: per-token work
        # must be Θ(token), so zero pool-sized transposes. Prefill/chunk
        # programs legitimately transpose Θ(chunk) attention intermediates
        # and amortize them over the whole chunk. copy_pages (the ISSUE-9
        # copy-on-write primitive) is held to the decode standard: it runs
        # at admission inside the serve loop and must stay a Θ(W·page)
        # gather/scatter, never a pool relayout.
        if thresh and (name.startswith("decode") or name == "copy_pages"):
            findings += no_pool_relayout(tr.jaxpr, thresh, program=program)
    findings += _audit_recompile_bound(be, family)
    return findings


def _check_pair_family(family: str, cfg) -> List[Finding]:
    from repro.models import get_model
    from repro.serve.backend import PairBatchBackend
    model = get_model(cfg)
    params = _abstract_params(model)
    be = PairBatchBackend(model, params, max_len=PAIR_MAX_LEN,
                          n_slots=2)
    be.ensure_state()
    ns = be.n_slots

    feats = jax.ShapeDtypeStruct((ns, PAIR_MAX_LEN, PAIR_FEATS),
                                 jnp.float32)
    lens = jax.ShapeDtypeStruct((ns,), jnp.int32)
    slot_ids = jax.ShapeDtypeStruct((ns,), jnp.int32)
    traced = {
        "prefill": be._prefill.trace(params, feats, lens, None,
                                     PAIR_MAX_LEN),
        "step": be._step.trace(params, be._cache),
    }
    wave_cache = jax.eval_shape(
        lambda: model.init_cache(ns, PAIR_MAX_LEN, factors=None))
    traced["insert"] = be._insert.trace(be._cache, wave_cache, slot_ids)

    findings: List[Finding] = []
    for name, tr in traced.items():
        findings += no_host_callback(tr.jaxpr, program=f"{family}/{name}")
    # Eq. 3 fold: only asserted on the precision-free factored path — the
    # refinement step reads the frozen phi_q/phi_k factor cache, so its
    # attention must concat factors onto q/k and run ONE depth-(D+R)
    # matmul (core.attention.flashbias_concat_qk). The fold is an XLA-path
    # construct — the Pallas kernel folds in-kernel instead — so the step
    # is re-traced under attn_impl="xla" specifically for this rule.
    if cfg.bias_mode == "flashbias" and cfg.dtype == "float32":
        if cfg.attn_impl == "xla":
            step_xla = traced["step"]
        else:
            xla_cfg = cfg.replace(attn_impl="xla")
            xla_model = get_model(xla_cfg)
            xla_be = PairBatchBackend(xla_model, _abstract_params(xla_model),
                                      max_len=PAIR_MAX_LEN, n_slots=2)
            xla_be.ensure_state()
            step_xla = xla_be._step.trace(xla_be.params, xla_be._cache)
        head_dim = cfg.d_model // cfg.n_heads
        findings += eq3_fold_present(step_xla.jaxpr, head_dim,
                                     cfg.bias_rank,
                                     program=f"{family}/step[xla]")
    return findings


def check_family(family: str, *, cache_layout: str = "kernel",
                 impl: str = "pallas_interpret") -> List[Finding]:
    """Trace every jitted serve program of ``family`` and return all rule
    violations (empty list = contracts hold)."""
    cfg = FAMILIES[family]().replace(cache_layout=cache_layout,
                                     attn_impl=impl)
    if cfg.family == "pairformer":
        return _check_pair_family(family, cfg)
    return _check_token_family(family, cfg)


def verify_tripwire(impl: str = "pallas_interpret") -> List[Finding]:
    """Built-in negative test: ``cache_layout="legacy"`` MUST trip the
    decode-step pool-relayout rule (the per-layer ``to_pool`` transpose).
    Returns a finding when it does not — a tripwire that cannot fire
    would pass every future regression too."""
    legacy = check_family("dense", cache_layout="legacy", impl=impl)
    hits = [f for f in legacy
            if f.rule == "no-pool-relayout" and "decode" in f.program
            and "transpose" in f.eqn]
    if hits:
        return []
    return [Finding(
        rule="tripwire-self-test", program="dense/decode[legacy]",
        message=("cache_layout='legacy' no longer trips the decode-step "
                 "transpose rule — the tripwire lost its teeth (did the "
                 "pool threshold calibration or the legacy adapter "
                 "change?)"))]


def run_contracts(families, *, cache_layout: str = "kernel",
                  impl: str = "pallas_interpret",
                  self_test: bool = True) -> List[Finding]:
    """Check ``families`` under one layout/impl; with ``self_test`` also
    prove the legacy tripwire still fires."""
    findings: List[Finding] = []
    for family in families:
        findings += check_family(family, cache_layout=cache_layout,
                                 impl=impl)
    if self_test:
        findings += verify_tripwire(impl=impl)
    return findings
