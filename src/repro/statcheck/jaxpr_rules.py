"""Jaxpr-walking rules: what ops may appear inside a jitted serve program.

FlashBias's serve-path wins are *absence* properties — no Θ(pool) relayout
in the decode step (ISSUE 5), no host round-trip inside jit, the Eq. 3
single-matmul fold instead of two matmuls + add — and absence is exactly
what a benchmark can only catch after the regression ships. These rules
assert the properties on the CLOSED JAXPR of each traced program, so a
violating commit fails CI before anyone times anything.

Each rule takes a ``ClosedJaxpr`` (plus calibration arguments) and returns
a list of :class:`Finding`. ``walk_eqns`` descends into every sub-jaxpr
(scan/while/cond bodies, pjit calls, custom-vjp wrappers, pallas_call
bodies), so a violation cannot hide inside the layer scan — which is where
the legacy layout's per-layer pool transpose actually lives.

Calibration notes (empirically pinned by ``tests/test_statcheck.py``):

- Pool-sized means "at least one full per-layer KV slab": cache leaves
  enter the layer scan sliced along L, so the threshold is
  ``min(leaf.size // n_layers)`` over the K/V leaves, not the whole-leaf
  size. Token-batch operands (Θ(B·H·D)) sit orders of magnitude below it.
- The GOOD kernel-native layout emits zero banned pool-sized eqns under
  both the XLA and interpret-mode Pallas decode paths for every family;
  ``cache_layout="legacy"`` emits the per-layer ``to_pool`` transpose
  (paged families, Pallas path) and the GQA ``jnp.repeat`` broadcast
  (ring families, both paths). ``contracts.verify_tripwire`` keeps this
  discrimination honest as a built-in negative test.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional

__all__ = [
    "BANNED_RELAYOUT_PRIMITIVES",
    "CALLBACK_PRIMITIVES",
    "Finding",
    "count_primitive",
    "eq3_fold_present",
    "no_host_callback",
    "no_pool_relayout",
    "pool_threshold_for",
    "walk_eqns",
]

# the PR-5 regression tripwire: a transpose / dtype convert / broadcast of
# a pool-sized operand in the decode step is Θ(pool) HBM traffic per token
BANNED_RELAYOUT_PRIMITIVES = ("transpose", "convert_element_type",
                              "broadcast_in_dim")

# host round-trips inside jit: a callback forces a device sync per call
# and disables XLA fusion across it — never legal on the serve hot path
CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback",
                       "callback")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: which rule, where, and the offending eqn."""

    rule: str            # rule id, e.g. "no-pool-relayout"
    program: str         # traced program, e.g. "dense/decode"
    message: str         # human-readable diagnosis
    eqn: str = ""        # offending equation (primitive + avals), if any

    def __str__(self) -> str:
        loc = f" [{self.eqn}]" if self.eqn else ""
        return f"[{self.rule}] {self.program}: {self.message}{loc}"


def _sub_jaxprs(value) -> Iterator:
    """Yield every jaxpr reachable from one eqn param value.

    Params hold sub-jaxprs in three shapes: a ``ClosedJaxpr`` (scan/pjit),
    a raw ``Jaxpr`` (pallas_call), or a tuple of either (cond branches).
    """
    values = value if isinstance(value, (list, tuple)) else [value]
    for v in values:
        if hasattr(v, "jaxpr"):        # ClosedJaxpr -> unwrap
            v = v.jaxpr
        if hasattr(v, "eqns"):         # Jaxpr
            yield v


def walk_eqns(jaxpr) -> Iterator:
    """Every eqn of ``jaxpr`` and all nested sub-jaxprs, depth-first.

    Accepts a ``ClosedJaxpr`` or a raw ``Jaxpr``.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from walk_eqns(sub)


def _shape_of(var) -> tuple:
    return tuple(getattr(var.aval, "shape", ()))


def _size_of(var) -> int:
    return int(getattr(var.aval, "size", 0))


def _eqn_str(eqn) -> str:
    ins = ",".join(str(_shape_of(v)) for v in eqn.invars)
    outs = ",".join(str(_shape_of(v)) for v in eqn.outvars)
    return f"{eqn.primitive.name} {ins} -> {outs}"


def no_pool_relayout(jaxpr, pool_threshold: int, *,
                     program: str = "decode") -> List[Finding]:
    """ISSUE-5 tripwire: no relayout primitive may consume a pool-sized
    operand inside the decode step.

    ``pool_threshold`` is the size (element count) of the smallest
    per-layer KV slab of the live cache — anything at or above it is pool
    traffic, not token traffic. The kernel-native layout feeds the kernels
    zero-copy, so the GOOD decode jaxpr has no such eqn; the legacy layout
    pays a per-layer ``transpose`` (paged ``to_pool`` adapter) or a GQA
    ``broadcast_in_dim`` (ring ``jnp.repeat``) every decoded token.
    """
    assert pool_threshold > 0, pool_threshold
    findings = []
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name not in BANNED_RELAYOUT_PRIMITIVES:
            continue
        worst = max((_size_of(v) for v in eqn.invars), default=0)
        if worst >= pool_threshold:
            findings.append(Finding(
                rule="no-pool-relayout",
                program=program,
                message=(f"{eqn.primitive.name} consumes a pool-sized "
                         f"operand ({worst} elems >= per-layer KV slab "
                         f"{pool_threshold}) — Θ(pool) relayout per "
                         "decoded token (ISSUE 5 regression)"),
                eqn=_eqn_str(eqn)))
    return findings


def no_host_callback(jaxpr, *, program: str) -> List[Finding]:
    """No ``pure_callback``/``io_callback``/host sync inside a jitted
    serve program: a callback stalls the device once per call and splits
    the program into unfusable halves."""
    findings = []
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name in CALLBACK_PRIMITIVES:
            findings.append(Finding(
                rule="no-host-callback",
                program=program,
                message=(f"{eqn.primitive.name} inside a jitted serve "
                         "program forces a host round-trip per step"),
                eqn=_eqn_str(eqn)))
    return findings


def eq3_fold_present(jaxpr, head_dim: int, rank: int, *,
                     program: str) -> List[Finding]:
    """FlashBias Eq. 3: the precision-free factored-bias path must fold
    ``qk^T + phi_q phi_k^T`` into ONE matmul of depth ``D + R`` by
    concatenating the factors onto q/k (``core.attention
    .flashbias_concat_qk``). The jaxpr signature of the fold is a
    ``concatenate`` whose output feature dim is exactly ``D + R`` — its
    absence means the path regressed to two matmuls + add (or worse, to a
    materialized dense bias)."""
    want = head_dim + rank
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name != "concatenate":
            continue
        shape = _shape_of(eqn.outvars[0])
        if shape and shape[-1] == want:
            return []
    return [Finding(
        rule="eq3-fold",
        program=program,
        message=(f"no concatenate producing feature dim {want} "
                 f"(= head_dim {head_dim} + rank {rank}): the Eq. 3 "
                 "single-matmul QK fold is missing from the precision-"
                 "free factored-bias path"))]


def count_primitive(jaxpr, name: str,
                    min_operand_size: int = 0) -> int:
    """How many eqns of ``name`` (optionally: with an operand at least
    ``min_operand_size`` elements) the program contains — the building
    block for ad-hoc assertions in tests."""
    n = 0
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name != name:
            continue
        if max((_size_of(v) for v in eqn.invars),
               default=0) >= min_operand_size:
            n += 1
    return n


def pool_threshold_for(cache: dict, n_layers: int,
                       kv_keys: Iterable[str] = ("k", "v", "pages_k",
                                                 "pages_v"),
                       fallback_keys: Iterable[str] = ("ssm_h", "conv_x",
                                                       "conv_bc"),
                       ) -> Optional[int]:
    """Pool-size threshold for ``no_pool_relayout``, from a live cache.

    KV leaves carry a leading layer axis and enter the decode layer scan
    as per-layer slices, so the threshold is the smallest per-layer K/V
    slab. Families without attention KV (pure SSM) fall back to their
    recurrent-state leaves; returns None when the cache has neither
    (nothing pool-shaped to protect).
    """
    sizes = [int(v.size) // n_layers
             for k, v in cache.items() if k in tuple(kv_keys)]
    if not sizes:
        sizes = [int(v.size) // n_layers
                 for k, v in cache.items() if k in tuple(fallback_keys)]
    return min(sizes) if sizes else None
