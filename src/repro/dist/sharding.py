"""Logical-axis sharding: one rule set drives every mesh shape.

Parameters and activations name their dims with *logical* axes (``fsdp``,
``heads``, ``batch``, ...); ``Rules`` maps each logical axis to the mesh
axes it shards over. ``spec_for`` resolves a tuple of logical axes to a
``PartitionSpec`` against a concrete mesh, silently dropping mesh axes the
mesh does not have — so the same rules drive a 2D ``(data, model)`` single
pod and a 3D ``(pod, data, model)`` multi-pod mesh (``pod`` just vanishes
on the former).

Default vocabulary (see ``repro.dist`` package docstring for the full
story):

==========  =====================  =========================================
logical     default mesh axes      sharded dim of
==========  =====================  =========================================
batch       ("pod", "data")        activation batch (DP)
seq         replicated             activation sequence (SP/CP via overrides)
fsdp        ("data",)              weight d_model dim (ZeRO-3 gather axis)
heads       ("model",)             q-head dim (TP)
kv_heads    ("model",)             kv-head dim (TP)
mlp         ("model",)             FFN hidden dim (TP)
vocab       ("model",)             embedding / logits vocab dim (TP)
expert      ("model",)             MoE expert dim (EP)
kv_seq      replicated             decode KV-cache sequence dim
layers      replicated             scanned-layers stack dim
==========  =====================  =========================================

``use_mesh_rules(mesh, rules)`` establishes the ambient (mesh, rules) pair
that ``constrain(x, *axes)`` reads; outside any such context ``constrain``
is the identity, so model code is unconditionally instrumented and costs
nothing single-device.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["Rules", "spec_for", "batch_axes_for", "use_mesh_rules",
           "get_active_mesh", "constrain", "shard_put", "DEFAULT_RULES"]

# One logical axis maps to: None (replicate) or a tuple of mesh axis names.
MeshAxes = Optional[Tuple[str, ...]]

DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "fsdp": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "kv_seq": None,
    "layers": None,
}


def _norm(v) -> MeshAxes:
    if v is None:
        return None
    if isinstance(v, str):
        return (v,)
    return tuple(v)


class Rules:
    """Immutable logical-axis -> mesh-axes table.

    ``Rules()`` is the production default (FSDP x TP with the pod axis
    folded into DP). ``Rules.make({...})`` overlays overrides — values may
    be a mesh-axis name, a tuple of names, or ``None`` (replicate); logical
    axes absent from the table resolve to replicated, so overrides can also
    introduce new vocabulary (e.g. ``kv_seq`` cache sharding).
    """

    __slots__ = ("_table",)

    def __init__(self, table: Optional[Mapping[str, MeshAxes]] = None):
        merged = dict(DEFAULT_RULES)
        if table:
            merged.update({k: _norm(v) for k, v in table.items()})
        object.__setattr__(self, "_table", merged)

    @classmethod
    def make(cls, overrides: Optional[Mapping] = None) -> "Rules":
        return cls(overrides)

    def __setattr__(self, name, value):
        raise AttributeError("Rules is immutable; use Rules.make({...})")

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return _norm(self._table.get(logical))

    @property
    def table(self) -> Mapping[str, MeshAxes]:
        return dict(self._table)

    def __repr__(self):
        return f"Rules({self._table!r})"

    def __eq__(self, other):
        return isinstance(other, Rules) and self._table == other._table

    def __hash__(self):
        return hash(tuple(sorted(self._table.items())))


def spec_for(axes: Sequence[Optional[str]], mesh, rules: Rules) -> P:
    """Resolve logical ``axes`` to a ``PartitionSpec`` for ``mesh``.

    Mesh axes the mesh lacks are dropped (``pod`` on a single-pod mesh);
    a mesh axis already consumed earlier in the same spec is dropped too
    (first occurrence wins), so override sets like sequence parallelism
    (``seq -> model``) never produce an invalid double-use spec. An entry
    whose mesh axes all drop becomes ``None`` (replicated).
    """
    present = set(getattr(mesh, "axis_names", ()) or mesh.shape.keys())
    used: set = set()
    entries = []
    for logical in axes:
        ax = rules.mesh_axes(logical)
        if ax is None:
            entries.append(None)
            continue
        kept = tuple(a for a in ax if a in present and a not in used)
        used.update(kept)
        entries.append(kept if kept else None)
    return P(*entries)


def batch_axes_for(batch: int, mesh, rules: Rules) -> P:
    """Sharding for a length-``batch`` leading dim: ``P((dp_axes,))`` when
    ``batch`` divides the DP product, else ``P(None)`` (replicated — never
    an error, so odd shapes like a batch-1 long-context probe still lower).
    """
    spec = spec_for(("batch",), mesh, rules)
    ax = spec[0]
    if ax is None:
        return P(None)
    dp = math.prod(int(mesh.shape[a]) for a in ax)
    if dp <= 1 or batch % dp != 0:
        return P(None)
    return spec


def shard_put(x, mesh, rules: Rules, axes: Sequence[Optional[str]]):
    """``device_put`` ``x`` with the sharding its logical ``axes`` resolve to.

    One logical axis (or ``None``) per array dim. A ``"batch"`` entry is
    guarded like ``batch_axes_for``: when the dim does not divide the DP
    product it degrades to replicated instead of erroring — serve-path
    state (slot batches of arbitrary ``n_slots``) must always place. This
    is the HOST-side complement of ``constrain``: backends use it to pin
    persistent device state (caches, pools, sampling rows) before any
    jitted program consumes it, so jit input shardings match the
    constraints traced inside.
    """
    x = jax.numpy.asarray(x)
    if len(axes) != x.ndim:
        raise ValueError(f"shard_put: {len(axes)} axes for rank-{x.ndim} "
                         f"array of shape {x.shape}")
    entries = list(spec_for(axes, mesh, rules))
    for i, (logical, entry) in enumerate(zip(axes, entries)):
        if logical == "batch" and entry is not None:
            ax = entry if isinstance(entry, tuple) else (entry,)
            dp = math.prod(int(mesh.shape[a]) for a in ax)
            if dp > 1 and int(x.shape[i]) % dp != 0:
                entries[i] = None
        elif entry is not None:        # non-divisible dims replicate too
            ax = entry if isinstance(entry, tuple) else (entry,)
            n = math.prod(int(mesh.shape[a]) for a in ax)
            if n > 1 and int(x.shape[i]) % n != 0:
                entries[i] = None
    return jax.device_put(x, NamedSharding(mesh, P(*entries)))


# ---------------------------------------------------------------------------
# Ambient (mesh, rules) context backing ``constrain``
# ---------------------------------------------------------------------------

_ACTIVE = threading.local()


def get_active_mesh() -> Optional[Tuple[object, Rules]]:
    """Innermost ``use_mesh_rules`` (mesh, rules) pair, or ``None``."""
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh_rules(mesh, rules: Rules):
    """Make (mesh, rules) ambient for ``constrain``/``get_active_mesh``.

    Nests: the innermost pair wins and the outer one is restored on exit.
    """
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    stack.append((mesh, rules))
    try:
        yield mesh
    finally:
        stack.pop()


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Pin ``x`` to the sharding its logical axes resolve to.

    Identity when no mesh is active, so layer code calls this
    unconditionally. Rank must match: one logical axis (or ``None``) per
    array dim.
    """
    ctx = get_active_mesh()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:     # not assert: must survive python -O
        raise ValueError(
            f"constrain: {len(logical_axes)} logical axes {logical_axes} "
            f"for rank-{x.ndim} array of shape {x.shape}")
    spec = spec_for(logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
