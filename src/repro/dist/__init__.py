"""repro.dist — the sharding layer between model code and the mesh.

Model templates and layer code never name mesh axes directly; they name
*logical* axes and this package resolves them against whatever mesh is in
play. That indirection is what lets one codebase lower on a single CPU
device (everything replicated, ``constrain`` a no-op), a 2D ``(data,
model)`` 256-chip pod, and a 3D ``(pod, data, model)`` multi-pod mesh
without touching model code — mesh axes a mesh lacks simply drop out of
the resolved ``PartitionSpec``.

Logical-axis vocabulary (defaults; override via ``Rules.make``):

- ``batch``    -> ``("pod", "data")`` — data parallelism; the pod axis
  composes into the DP product and vanishes on single-pod meshes.
- ``seq``      -> replicated — sequence/context parallelism is an override
  (``Rules.make({"seq": ("model",)})``).
- ``fsdp``     -> ``("data",)`` — weight ``d_model`` dims, ZeRO-3 style.
- ``heads`` / ``kv_heads`` / ``mlp`` / ``vocab`` / ``expert``
  -> ``("model",)`` — tensor/expert parallelism.
- ``kv_seq``   -> replicated — decode KV-cache sequence dim (hillclimb
  lever).
- ``layers``   -> replicated — the scanned-layers stack dim.

Typical flow (see ``launch/dryrun.py``)::

    rules = Rules()                        # or Rules.make({...}) overrides
    pshard = param_shardings(tmpl, mesh, rules)   # via spec_for
    with use_mesh_rules(mesh, rules):      # makes constrain() live
        jax.jit(step, in_shardings=...).lower(...).compile()
"""
from repro.dist.sharding import (
    DEFAULT_RULES,
    Rules,
    batch_axes_for,
    constrain,
    get_active_mesh,
    shard_put,
    spec_for,
    use_mesh_rules,
)

__all__ = ["Rules", "spec_for", "batch_axes_for", "use_mesh_rules",
           "get_active_mesh", "constrain", "shard_put", "DEFAULT_RULES"]
