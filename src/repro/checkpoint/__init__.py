"""Checkpointing: atomic manifest-based save/restore with elastic resharding."""
from repro.checkpoint.manifest import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]
