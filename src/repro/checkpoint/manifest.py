"""Atomic, manifest-based checkpointing with mesh-elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure + leaf metadata + user extras
        leaf_00000.npy       # one file per array leaf (global view)
        ...

Guarantees:

- **Atomicity**: written into ``step_X.tmp-<pid>`` then ``os.rename``d —
  a crash mid-save never corrupts the latest checkpoint.
- **Elasticity**: leaves are saved as *global* arrays; ``restore_checkpoint``
  accepts a target sharding tree, so a run saved on mesh A restores onto
  mesh B (different device count / topology) — the elastic-scaling path.
  (At 1000+ nodes the per-leaf files would become per-shard chunks with the
  same manifest; the interface is unchanged — DESIGN.md §Fault tolerance.)
- **Retention**: ``keep_n`` prunes older steps after a successful commit.
- **Self-describing**: tree structure is serialized with the manifest, so a
  checkpoint restores without a template (shapes/dtypes validated if a
  template is supplied).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import jax.numpy as jnp
        return np.dtype(getattr(jnp, name))  # bfloat16, float8_*, ...


def _flatten_with_names(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
             for path, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]
    return names, leaves


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    extras: Optional[dict] = None, keep_n: int = 3) -> str:
    """Save ``tree`` (any pytree of arrays/scalars) atomically. Returns path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves = _flatten_with_names(tree)
    treedef = jax.tree.structure(tree)
    manifest = {"step": step, "extras": extras or {},
                "treedef": str(treedef), "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            # extension dtypes (bfloat16, float8_*) don't survive np.save;
            # store raw bits + the logical dtype in the manifest
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": dtype_name})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    steps = sorted(all_steps(directory))
    for old in steps[:-keep_n]:
        shutil.rmtree(os.path.join(directory, f"step_{old:08d}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith((".tmp-%d" % 0,)) \
                and ".tmp-" not in d:
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int], template: Any, *,
                       shardings: Any = None):
    """Restore into the structure of ``template``.

    ``shardings`` (optional pytree of NamedSharding matching template) puts
    each leaf onto the *current* mesh — this is the elastic restore: the
    saved mesh is irrelevant because leaves are global arrays.

    Returns (tree, extras).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    tmpl_names, tmpl_leaves = _flatten_with_names(template)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    assert set(tmpl_names) == set(by_name), (
        "checkpoint/template structure mismatch: "
        f"missing={set(tmpl_names) - set(by_name)} "
        f"extra={set(by_name) - set(tmpl_names)}")

    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(tmpl_leaves))
    out_leaves = []
    for name, tmpl_leaf, shd in zip(tmpl_names, tmpl_leaves, shard_leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        want = _resolve_dtype(entry["dtype"])
        if arr.dtype != want:
            arr = arr.view(want)          # bit-exact extension-dtype restore
        if hasattr(tmpl_leaf, "shape"):
            assert tuple(arr.shape) == tuple(tmpl_leaf.shape), (
                name, arr.shape, tmpl_leaf.shape)
        if shd is not None:
            arr = jax.device_put(arr, shd)
        out_leaves.append(arr)
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, out_leaves), manifest["extras"]
