"""llama4-scout-17b-a16e [moe] — 16 experts, top-1 routing, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. Expert dim 16 == TP=16
(one expert per model shard). Heads pad 40 -> 48 (groups 5 -> 6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    top_k=1,
    bias_kind="alibi",
    remat="full",  # dots remat stores >16GB temps at this batch (§Perf)
    grad_accum=8,
    notes="16e top-1; EP maps one expert per model shard",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
    n_experts=4, top_k=1, tp=1, remat="none", dtype="float32",
)
