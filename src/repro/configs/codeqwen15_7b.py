"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA).

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf]. FlashBias-ALiBi (R=2). No padding needed.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    bias_kind="alibi",
    grad_accum=4,
    remat="full",   # dots stores >16GB temps at this batch (EXPERIMENTS §Perf)
    notes="qwen1.5-arch; MHA",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4, d_ff=192, vocab=160,
    tp=1, remat="none", dtype="float32",
)
