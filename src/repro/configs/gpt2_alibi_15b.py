"""GPT-2 1.5B + ALiBi — the paper's LLM experiment (Sec. 4.2, Table 3).

48 decoder layers, 1600 channels, 50 heads, FFN 6400, causal mask + ALiBi.
FlashBias uses the exact rank-2 decomposition (Example 3.4) — bit-equivalent
to dense ALiBi. Heads pad 50 -> 64 for TP=16; vocab 50257 -> 50272.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-alibi-1.5b",
    family="dense",
    n_layers=48,
    d_model=1600,
    n_heads=50,
    n_kv_heads=50,
    d_ff=6400,
    vocab=50257,
    head_dim=32,
    bias_kind="alibi",
    grad_accum=4,
    notes="paper Sec 4.2; exact R=2 ALiBi decomposition",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    head_dim=16, tp=1, remat="none", dtype="float32",
)
