"""command-r-plus-104b [dense] — GQA, no-bias(-terms in projections).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]. FlashBias-ALiBi (R=2).
Heads 96 / kv 8 divide TP=16 cleanly — no padding.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    bias_kind="alibi",
    grad_accum=16,
    remat="full",       # 104B params: save nothing inside the layer scan
    notes="GQA 12:1; largest assigned arch",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=256,
    tp=1, remat="none", dtype="float32",
)
