"""minicpm-2b [dense] — llama-like, trained with a WSD schedule.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753 [arXiv:2404.06395; hf].
The WSD (warmup-stable-decay) schedule lives in repro.optim.schedules and is
selected by this config. Heads pad 36 -> 48; vocab pads 122753 -> 122768.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    bias_kind="alibi",
    remat="full",  # dots remat stores >16GB temps at this batch (§Perf)
    grad_accum=4,
    notes="WSD schedule (optim); arch is llama-like MHA",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=6, d_ff=144, vocab=160,
    tp=1, remat="none", dtype="float32",
)
