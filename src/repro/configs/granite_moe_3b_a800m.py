"""granite-moe-3b-a800m [moe] — 40 experts, top-8 routing.

32L d_model=1536 24H (GQA kv=8) d_ff=512(per-expert) vocab=49155,
MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
Experts pad 40 -> 48 (router logits for pads = -inf); heads pad 24 -> 32;
vocab pads 49155 -> 49168.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    bias_kind="alibi",
    grad_accum=4,
    notes="40e top-8; experts padded to 48 with -inf router logits",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
    n_experts=5, top_k=2, tp=1, remat="none", dtype="float32",
)
