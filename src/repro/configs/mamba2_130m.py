"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]. d_inner = 2*768 = 1536, headdim 64 ->
24 SSM heads, padded to 32 for TP=16; vocab pads 50280 -> 50288.

FlashBias is INAPPLICABLE here (no q k^T logits to bias) — implemented
without the technique per DESIGN.md §Arch-applicability; the SSD decay
mask L is itself the low-rank-structured attention surrogate.
``long_500k`` RUNS: decode state is constant-size.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    bias_kind="none",
    grad_accum=4,
    notes="attention-free; FlashBias N/A (documented); SSD chunked scan",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, vocab=128, ssm_state=16, ssm_head_dim=16,
    tp=1, remat="none", dtype="float32",
)
