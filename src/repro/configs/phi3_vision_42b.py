"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf]. The CLIP image tower is a
stub: ``input_specs`` provides 576 precomputed patch embeddings prepended to
the text tokens. FlashBias-ALiBi over the joint sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    bias_kind="alibi",
    remat="full",  # dots remat stores >16GB temps at this batch (§Perf)
    grad_accum=4,
    frontend="vision",
    frontend_len=576,
    notes="CLIP patch embeddings stubbed as precomputed frontend inputs",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    frontend_len=16, tp=1, remat="none", dtype="float32",
)
