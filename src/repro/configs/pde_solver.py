"""Transformer PDE solver with spatial-distance bias (Sec. 4.4, Table 5).

8 layers, 128 hidden channels, 8 heads, FFN 256; bias
f(x_i, x_j) = alpha_i * ||x_i - x_j||^2 with per-query learnable alpha
(the "adaptive mesh" weight). FlashBias uses the exact rank-9 decomposition
(Example 3.5) with alpha folded into phi_q — this is the configuration where
FlashBias is the ONLY method that trains at 32186 points (paper Table 5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pde-solver",
    family="pde",
    n_layers=8,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=256,
    vocab=0,
    coord_dim=3,
    bias_kind="sqdist",
    tp=1,
    notes="paper Sec 4.4; exact R=3d decomposition, learnable alpha",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
    remat="none", dtype="float32",
)
