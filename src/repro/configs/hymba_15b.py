"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]. Simplifications recorded in DESIGN.md: all layers
use sliding-window attention (window 1024) + parallel SSM branch (the
published model keeps 3 full-attention layers and meta tokens; the hybrid
compute pattern is identical). Heads pad 25 -> 48 with kv 5 -> 6 so the
(kv x group) grid divides TP=16; vocab pads 32001 -> 32016.

``long_500k`` RUNS for this arch: the KV cache is bounded by the window and
the SSM state is constant-size.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    window=1024,
    pad_heads=48,
    pad_kv_heads=6,
    bias_kind="alibi",
    remat="full",  # dots remat stores >16GB temps at this batch (§Perf)
    grad_accum=8,   # accum 4 leaves >16GB activation temps (§Perf)
    notes="parallel attn+mamba heads; SWA everywhere (3 global-attn layers "
          "of the published model homogenized for the layer scan)",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=128,
    window=32, pad_heads=0, pad_kv_heads=0, ssm_state=8,
    tp=1, remat="none", dtype="float32",
)
