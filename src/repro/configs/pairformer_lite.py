"""Pairformer-lite — the paper's AlphaFold-3 experiment (Sec. 4.4, Table 6).

A faithful-in-structure reduction of AF3's Pairformer: single-representation
attention whose bias is PROJECTED FROM THE PAIR REPRESENTATION (the dynamic,
data-dependent bias that needs the paper's *neural decomposition*), plus
triangle-multiplication pair updates. 16 blocks, d_single=384, d_pair=128,
4 heads (AF3 pair-bias attention uses 4 heads; App. H Table 12: neural
factors R=96 per head, 3 linear layers with tanh).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pairformer-lite",
    family="pairformer",
    n_layers=16,
    d_model=384,
    n_heads=4,
    n_kv_heads=4,
    d_ff=1536,
    vocab=0,
    d_pair=128,
    bias_kind="pair",
    bias_rank=96,
    tp=1,
    notes="paper Sec 4.4; neural decomposition of pair-projected bias",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, d_pair=32,
    bias_rank=8, remat="none", dtype="float32",
)
