"""SwinV2-B window-attention stack — the paper's vision experiment (Sec. 4.3).

The FlashBias-relevant core of SwinV2-B at 384x384 / window 24: 24 layers of
WindowAttention over sequences of 576 tokens, each layer holding a learnable
relative-position bias table (heads x 576 x 576 worth of logical bias,
parameterized by relative offsets). FlashBias applies the SVD decomposition
to the trained tables (paper: last 8 layers, R=16..32 keeping >=99% energy).

The hierarchical patch-merging pyramid is orthogonal to the bias speedup and
is not modeled; ``window`` holds the per-window sequence length.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="swinv2-b",
    family="swin",
    n_layers=24,
    d_model=512,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2048,
    vocab=0,
    window=576,            # 24 x 24 window -> sequence length per window
    bias_kind="none",      # bias comes from the learnable table, not ALiBi
    bias_rank=16,
    tp=1,
    notes="paper Sec 4.3; SVD decomposition of learnable relpos tables",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, window=36,
    bias_rank=4, remat="none", dtype="float32",
)
