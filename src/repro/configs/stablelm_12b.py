"""stablelm-12b [dense] — GQA.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-1_6b; hf]. head_dim = 160 (5120/32).
FlashBias-ALiBi (R=2). No padding needed (32 and 8 divide/replicate fine).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    bias_kind="alibi",
    grad_accum=8,   # accum 4 leaves >16GB activation temps (§Perf)
    remat="full",   # dots stores >16GB temps at this batch (EXPERIMENTS §Perf)
    notes="GQA 4:1, head_dim 160 (not a 128 multiple; kernels pad lanes)",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=160, n_heads=4, n_kv_heads=2, d_ff=320, vocab=256,
    tp=1, remat="none", dtype="float32",
)
