"""Config schema + shape registry + arch registry.

Every assigned architecture lives in its own ``configs/<id>.py`` defining
``CONFIG`` (exact published figures) and ``SMOKE`` (reduced same-family
variant for CPU tests). This module holds the shared dataclass, the
assigned input-shape set, and the (arch x shape) cell enumeration with the
skip rules from DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "get_config",
           "smoke_config", "list_archs", "cells"]


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture. Exact figures from the assignment; padding derived.

    ``tp`` is the tensor-parallel degree the padded dims target (16 on the
    production mesh, 1 for smoke configs so tests stay small).
    """

    name: str
    family: str                   # dense | moe | ssm | hybrid | swin | pde | pairformer
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0             # 0 -> d_model // n_heads

    # --- attention bias / positional (the paper's technique) ---
    bias_kind: str = "alibi"      # "alibi" | "none"
    bias_mode: str = "flashbias"  # "flashbias" (factored) | "dense" (baseline)
    rope: bool = False
    window: int = 0               # sliding-window size; 0 = full attention

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # --- frontends (audio/vision stubs: precomputed embeddings) ---
    frontend: str = "none"        # "none" | "audio" | "vision"
    frontend_len: int = 0

    # --- paper-model extras ---
    coord_dim: int = 3            # pde: spatial dimension of mesh points
    d_pair: int = 0               # pairformer: pair-representation channels
    bias_rank: int = 0            # svd/neural decomposition rank R

    # --- parallelism / numerics ---
    pad_heads: int = 0            # explicit override of heads_padded
    pad_kv_heads: int = 0         # explicit override of kv_heads_padded
    tp: int = 16
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "dots"           # "none" | "dots" | "full"
    attn_chunk: int = 512         # kv chunk of the XLA flash path
    attn_impl: str = "auto"       # "auto" | "xla" | "pallas" | "pallas_interpret"
    cache_layout: str = "kernel"  # "kernel" (kv-head-major, zero-copy decode)
                                  # | "legacy" (canonical (B,S,KVH,hd); kept as
                                  # the layout_vs_legacy A/B + parity reference)
    ssd_chunk: int = 256          # SSD intra-chunk quadratic block
    grad_accum: int = 1           # microbatches per train step (activation fit)
    grad_rs: bool = False         # pin grads to param shardings (forces the
                                  # DP reduction to reduce-scatter, ZeRO-2)

    notes: str = ""

    # ---- derived (TP padding; zero-padded weights keep math exact) ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def kv_groups(self) -> int:
        """Padded q-heads per padded kv head."""
        if self.n_kv_heads == 0:
            return 1
        return self.heads_padded // self.kv_heads_padded

    @property
    def kv_heads_padded(self) -> int:
        if self.pad_kv_heads:
            return self.pad_kv_heads
        if self.n_kv_heads == 0:
            return 0
        if self.n_kv_heads == self.n_heads:     # MHA: pad kv with q
            return self.heads_padded
        return self.n_kv_heads                  # GQA kv stays (replicated)

    @property
    def heads_padded(self) -> int:
        if self.pad_heads:
            return self.pad_heads
        if self.n_heads == 0:
            return 0
        if self.n_kv_heads and self.n_kv_heads != self.n_heads:
            # keep the (kv, group) structure: pad groups so kv*g % tp == 0
            kv = self.pad_kv_heads or self.n_kv_heads
            g = _ceil_to(self.n_heads, kv) // kv
            while (kv * g) % self.tp:
                g += 1
            return kv * g
        return _ceil_to(self.n_heads, self.tp)

    @property
    def vocab_padded(self) -> int:
        return _ceil_to(self.vocab, self.tp) if self.vocab else 0

    @property
    def experts_padded(self) -> int:
        return _ceil_to(self.n_experts, self.tp) if self.n_experts else 0

    @property
    def ssm_heads(self) -> int:
        if not self.ssm_state:
            return 0
        d_inner = self.ssm_expand * self.d_model
        return d_inner // self.ssm_head_dim

    @property
    def ssm_heads_padded(self) -> int:
        return _ceil_to(self.ssm_heads, self.tp) if self.ssm_state else 0

    @property
    def d_inner_padded(self) -> int:
        return self.ssm_heads_padded * self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate *logical* (unpadded) parameter count."""
        d, v, l = self.d_model, self.vocab, self.n_layers
        hd = self.resolved_head_dim
        n = v * d                                     # embedding (+ tied head)
        attn = d * hd * (self.n_heads * 2) + d * hd * (self.n_kv_heads or self.n_heads) * 2
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        elif self.family == "ssm":
            d_in = self.ssm_expand * d
            ffn = 0
            attn = d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads) + d_in * d
        else:
            ffn = 3 * d * self.d_ff
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            attn += d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads) + d_in * d
        return n + l * (attn + ffn + 2 * d)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "musicgen_medium",
    "command_r_plus_104b",
    "minicpm_2b",
    "stablelm_12b",
    "codeqwen15_7b",
    "phi3_vision_42b",
    "llama4_scout_17b_a16e",
    "granite_moe_3b_a800m",
    "hymba_15b",
    "mamba2_130m",
]

PAPER_IDS = ["gpt2_alibi_15b", "swinv2_b", "pde_solver", "pairformer_lite"]


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def list_archs(include_paper: bool = False):
    return list(ARCH_IDS) + (list(PAPER_IDS) if include_paper else [])


def cells():
    """All (arch_id, shape_name) dry-run cells, with the documented skips.

    ``long_500k`` needs sub-quadratic attention: it runs only for the SSM
    (mamba2) and hybrid (hymba, sliding-window + constant state) archs —
    pure full-attention archs skip it (DESIGN.md §Arch-applicability).
    """
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
                continue
            out.append((a, s.name))
    return out
