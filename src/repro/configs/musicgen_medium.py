"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec/text-conditioning frontend is a stub: ``input_specs`` provides a
64-frame precomputed conditioning-embedding prefix. FlashBias-ALiBi bias
(exact decomposition, R=2). Heads pad 24 -> 32 for TP=16.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    bias_kind="alibi",
    remat="full",  # dots remat stores >16GB temps at this batch (§Perf)
    grad_accum=4,
    frontend="audio",
    frontend_len=64,
    notes="decoder-only over EnCodec tokens; conditioning prefix stubbed",
)

SMOKE = CONFIG.replace(
    grad_accum=1,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    frontend_len=8, tp=1, remat="none", dtype="float32",
)
