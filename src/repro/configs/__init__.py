"""Architecture configs: the 10 assigned archs + the paper's own models.

``get_config(name)`` returns the full production config; ``smoke`` variants
are reduced same-family configs for CPU tests. ``SHAPES`` is the assigned
input-shape set; ``cells()`` enumerates the (arch x shape) dry-run grid.
"""
from repro.configs.base import (
    SHAPES,
    ArchConfig,
    ShapeSpec,
    cells,
    get_config,
    list_archs,
    smoke_config,
)

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_config", "smoke_config",
           "list_archs", "cells"]
