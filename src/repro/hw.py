"""Hardware model for the target platform: TPU v5e.

All roofline math in ``repro.analysis`` reads these constants. The container
itself runs on CPU; these numbers describe the TARGET accelerator, per the
assignment (197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s per ICI link).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bandwidth: float    # bytes/s
    hbm_bytes: int          # capacity
    ici_link_bandwidth: float  # bytes/s per link (assignment constant)
    vmem_bytes: int         # on-chip vector memory (the TPU analogue of SRAM)
    mxu_dim: int            # systolic array side; matmul dims should align

    @property
    def arithmetic_intensity_knee(self) -> float:
        """FLOP/byte at which a kernel moves from memory- to compute-bound."""
        return self.peak_flops_bf16 / self.hbm_bandwidth


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    vmem_bytes=128 * 1024**2,  # ~128 MiB VMEM on v5e (shared scalar+vector)
    mxu_dim=128,
)

# Lane/sublane tiling granularity for fp32/bf16 on TPU. BlockSpec shapes in
# kernels/ are multiples of these.
TPU_LANE = 128
TPU_SUBLANE_F32 = 8
TPU_SUBLANE_BF16 = 16
